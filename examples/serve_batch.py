"""Serving example: continuous batching over the slot scheduler.

    PYTHONPATH=src python examples/serve_batch.py

Runs a reduced mixtral (MoE decode path with ring-buffer SWA caches)
through the production serving driver: 12 requests over 4 decode slots.
"""
from repro.launch import serve

serve.main(["--arch", "mixtral-8x7b", "--reduced", "--slots", "4",
            "--requests", "12", "--prompt-len", "10", "--max-new", "12",
            "--max-len", "48"])
