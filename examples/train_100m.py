"""End-to-end driver: train a ~100M-parameter qwen-family model for a few
hundred steps with checkpointing and the TaxoNN engine.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

This is the deliverable-(b) end-to-end run: a real config (qwen1.5-0.5b
family, width-reduced to ~100M params), the straggler-tolerant loader,
cosine schedule, async checkpoints, and quantized training enabled.
On the CPU container a step takes a few seconds; on a v5e pod the same
driver runs the full config via launch/train.py.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/taxonn_100m")
    args = ap.parse_args()

    base = get_config("qwen1.5-0.5b")
    # ~100M params: 12 layers, d_model 640, vocab 32k
    cfg = dataclasses.replace(
        base, num_layers=12, d_model=640, num_heads=10, num_kv_heads=10,
        head_dim=64, d_ff=1792, vocab_size=32_000, compute_dtype="float32")
    print(f"target size: {cfg.param_count()/1e6:.1f}M params")

    argv = ["--arch", "qwen1.5-0.5b", "--steps", str(args.steps),
            "--seq-len", "256", "--global-batch", "8",
            "--lr", "1e-2", "--optimizer", "momentum", "--quantize",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25",
            "--log-every", "5"]

    # drive launch/train with the custom config
    old = train_mod._reduce
    train_mod._reduce = lambda _cfg: cfg
    try:
        argv.append("--reduced")
        losses = train_mod.main(argv)
    finally:
        train_mod._reduce = old
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
