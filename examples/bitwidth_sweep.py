"""Per-layer bitwidth design-point sweep (the paper's Fig. 5 methodology).

    PYTHONPATH=src python examples/bitwidth_sweep.py

Sweeps fractional bits F for the paper's 5-layer network and prints the
accuracy frontier — reproducing the paper's observation that there is a
sharp lower bitwidth threshold below which training under-fits, while
anything above it matches full precision.  Because bit schedules are
runtime data, the sweep reuses ONE compiled train step.
"""
import sys

sys.path.insert(0, ".")  # for benchmarks.*

from benchmarks.convergence import run_mlp  # noqa: E402
from repro.quant import make_bit_schedule  # noqa: E402

STEPS = 200

print(f"{'format':>12s} {'test_acc':>9s} {'final_loss':>11s}")
fp32 = run_mlp("fp32", make_bit_schedule(3, enabled=False), enabled=False,
               steps=STEPS)
print(f"{'fp32':>12s} {fp32['test_acc']:9.4f} {fp32['loss_last']:11.4f}")

for f_bits in (12, 10, 8, 6, 5, 4, 3):
    sched = make_bit_schedule(3, weight=(2, f_bits), act=(4, f_bits),
                              grad=(2, f_bits), ramp=False)
    r = run_mlp(f"(2,{f_bits})", sched, enabled=True, steps=STEPS)
    marker = "  <- under-fitting threshold" if \
        r["test_acc"] < fp32["test_acc"] - 0.05 else ""
    print(f"{f'(2,{f_bits})':>12s} {r['test_acc']:9.4f} "
          f"{r['loss_last']:11.4f}{marker}")
