"""Quickstart: train a tiny LM with the TaxoNN engine in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.data import SyntheticLMDataset
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import Hyper, OptimizerConfig

cfg = ModelConfig(name="quickstart", family="dense", num_layers=4,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=512, compute_dtype="float32")

params = lm.init_params(jax.random.key(0), cfg)
ocfg = OptimizerConfig(kind="momentum")
opt = init_train_state(params, ocfg)

# the paper's per-layer (I,F) schedule — runtime data, no recompiles
bits = default_bits(cfg, enabled=True)
policy = QuantPolicy(grad_scale=64.0)

step = jax.jit(make_train_step(cfg, policy, ocfg,
                               StepOptions(engine="taxonn")))
ds = SyntheticLMDataset(cfg.vocab_size, seq_len=64, global_batch=8)

for i in range(50):
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
    hyper = Hyper(lr=jnp.float32(2e-2), step=jnp.int32(i))
    params, opt, metrics = step(params, opt, batch, hyper, bits)
    if i % 10 == 0 or i == 49:
        print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
              f"grad_norm {float(metrics['grad_norm']):.3f}")

print("quantized TaxoNN training: done")
