"""Fig. 3 analogue: the fused per-layer BP pipeline vs monolithic autodiff,
plus the multi-device pipeline-schedule matrix.

TaxoNN's pipeline overlaps G-propagation with weight updates; the gradient
for layer i exists only while layer i is being processed.  Measured here:

  * peak gradient-residency: the engine's backward scan carries one layer's
    dW vs autodiff's full gradient tree (analytical, from shapes)
  * per-layer DP all-reduce placement: engine issues the dW reduction
    INSIDE the backward scan body (overlappable), autodiff reduces the
    whole tree AFTER backward (counted from HLO text)
  * measured step walltime, engine vs autodiff (CPU, reduced config)
  * per-schedule rows (gpipe / 1f1b / interleaved): fwd+grad walltime of
    ``dist.pipeline.pipeline_apply`` plus the schedule's modeled bubble
    fraction, tick count, and peak-activation microbatches — written to
    BENCH_pipeline.json in CI and gated by benchmarks/check_regression.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.dist.pipeline import get_schedule, pipeline_apply
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import Hyper, OptimizerConfig


def _cfg(L=6):
    return ModelConfig(
        name="bench-pipe", family="dense", num_layers=L, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=2048,
        compute_dtype="float32", logit_chunk=256)


def _fam_cfg(family):
    """Reduced per-family config with 4 engine units (stage-shardable)."""
    base = dict(name=f"bench-pipe-{family}", family=family, num_layers=4,
                d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                vocab_size=512, compute_dtype="float32", logit_chunk=64)
    if family == "moe":
        base.update(num_kv_heads=4, d_ff=0, num_experts=4,
                    experts_per_token=2, num_shared_experts=1, moe_d_ff=96)
    if family == "ssm":
        base.update(num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=16,
                    ssm_head_dim=8, ssm_chunk=16)
    if family == "hybrid":
        base.update(num_layers=8, num_kv_heads=4, ssm_state=16,
                    ssm_head_dim=8, ssm_chunk=16, attn_every=2)
    if family == "encdec":
        base.update(num_kv_heads=4, num_encoder_layers=2, encoder_seq=32,
                    use_rope=False, norm_kind="layernorm", mlp_kind="gelu")
    if family == "vlm":
        base.update(num_patches=8)
    return ModelConfig(**base)


def _fam_batch(cfg, b, t):
    ks = jax.random.split(jax.random.key(2), 4)
    batch = {"tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, t), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[3], (b, cfg.num_patches, cfg.d_model))
    return batch


def run(quick: bool = False):
    cfg = _cfg()
    params = lm.init_params(jax.random.key(0), cfg)
    ks = jax.random.split(jax.random.key(1), 2)
    b, t = 8, 256
    batch = {"tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, t), 0, cfg.vocab_size)}
    ocfg = OptimizerConfig(kind="sgd")
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(1e-2), step=jnp.int32(0))
    opt = init_train_state(params, ocfg)

    # --- peak gradient residency (bytes) ---------------------------------
    layer_bytes = sum(
        int(np.prod(x.shape[1:])) * 4
        for x in jax.tree.leaves(params["blocks"]))
    full_bytes = sum(x.size * 4 for x in jax.tree.leaves(params))

    rows = [{
        "name": "pipeline/peak_gradient_bytes",
        "us_per_call": 0.0,
        "engine_one_layer": layer_bytes,
        "autodiff_full_tree": full_bytes,
        "reduction": full_bytes / layer_bytes,
    }]

    # --- step walltime ----------------------------------------------------
    reps = 3 if quick else 10
    for engine in ("taxonn", "autodiff"):
        step = jax.jit(make_train_step(cfg, QuantPolicy.off(), ocfg,
                                       StepOptions(engine=engine)))
        p, o, m = step(params, opt, batch, hyper, bits)  # compile+warm
        jax.block_until_ready(m["loss"])
        t0 = time.time()
        for _ in range(reps):
            p, o, m = step(p, o, batch, hyper, bits)
        jax.block_until_ready(m["loss"])
        us = (time.time() - t0) / reps * 1e6
        rows.append({
            "name": f"pipeline/step_walltime_{engine}",
            "us_per_call": us,
            "loss": float(m["loss"]),
        })

    # --- pipeline schedules: measured walltime + modeled bubble/memory ----
    S, M, MB, D = 4, 8 if quick else 16, 4, 64
    key = jax.random.key(0)
    w = jax.random.normal(key, (S, D, D)) * D ** -0.5
    xs = jax.random.normal(jax.random.key(1), (M, MB, D))

    def stage_body(stage_w, h):
        return jnp.tanh(h @ stage_w)

    sched_reps = 3 if quick else 10
    # gpipe and 1f1b share identity stage placement, so their executed
    # program is IDENTICAL (the schedules differ in the tick-table cost
    # model, not the computed function) — time that program once and reuse
    # the measurement, rather than committing timer noise as a phantom
    # schedule speedup for the regression gate to chase.  interleaved's
    # storage permutation changes the HLO and gets its own timing.
    us_by_placement = {}
    for label, spec, virt in (("gpipe", "gpipe", None),
                              ("1f1b", "1f1b", None),
                              ("interleaved_v2", "interleaved", 2)):
        sched = get_schedule(spec, num_virtual=virt)
        placement = tuple(sched.stage_of_slot(S))
        if placement not in us_by_placement:
            def loss(w_, sched=sched):
                return jnp.sum(
                    pipeline_apply(w_, xs, stage_body, schedule=sched) ** 2)

            gfn = jax.jit(jax.grad(loss))
            g = gfn(w)
            jax.block_until_ready(g)
            t0 = time.time()
            for _ in range(sched_reps):
                g = gfn(w)
            jax.block_until_ready(g)
            us_by_placement[placement] = (time.time() - t0) / sched_reps * 1e6
        plan = sched.plan(S, M)
        rows.append({
            "name": f"pipeline/schedule_{label}",
            "us_per_call": us_by_placement[placement],
            "bubble_fraction": plan.bubble,
            "ticks": plan.num_ticks,
            "peak_activation_microbatches": plan.peak_activation_microbatches,
            "num_devices": plan.num_devices,
            "num_stages": S,
            "num_microbatches": M,
            "note": "walltime shared across identity-placement schedules; "
                    "bubble/ticks/peak are the modeled schedule columns",
        })

    # --- per-family stage-sharded execution rows --------------------------
    # every model family through the pipeline path (1f1b, 4 stages x 4
    # microbatches, quantized engine): measured step walltime plus a
    # loss-parity canary against the single-device scan engine — the
    # regression gate tracks the walltime, the canary rides along so a
    # numerics break is visible in the committed JSON, not just in tests
    fam_reps = 2 if quick else 5
    for family in ("dense", "ssm", "vlm", "hybrid", "encdec", "moe"):
        fcfg = _fam_cfg(family)
        fparams = lm.init_params(jax.random.key(0), fcfg)
        fbatch = _fam_batch(fcfg, b=8, t=64)
        fbits = default_bits(fcfg, enabled=True)
        fopt = init_train_state(fparams, ocfg)
        pol = QuantPolicy(grad_scale=16.0)
        scan_step = jax.jit(make_train_step(fcfg, pol, ocfg))
        _, _, m_scan = scan_step(fparams, fopt, fbatch, hyper, fbits)
        pipe_step = jax.jit(make_train_step(
            fcfg, pol, ocfg, StepOptions(pipeline_schedule="1f1b",
                                         pipeline_stages=4,
                                         num_microbatches=4)))
        p, o, m = pipe_step(fparams, fopt, fbatch, hyper, fbits)
        jax.block_until_ready(m["loss"])
        bit_exact = int(float(m["loss"]) == float(m_scan["loss"]))
        # min over reps, each timed individually: these ~100ms rows sit
        # close to the regression gate's noise floor and a CPU-contention
        # spike inside a mean would read as a phantom regression; the
        # minimum is the contention-free estimate of the same workload
        best = float("inf")
        for _ in range(fam_reps):
            t0 = time.time()
            p, o, m = pipe_step(p, o, fbatch, hyper, fbits)
            jax.block_until_ready(m["loss"])
            best = min(best, time.time() - t0)
        us = best * 1e6
        rows.append({
            "name": f"pipeline/family_{family}",
            "us_per_call": us,
            "schedule": "1f1b", "stages": 4, "microbatches": 4,
            "loss": float(m_scan["loss"]),
            "loss_bit_exact_vs_scan": bit_exact,
        })

    # --- update placement: inside-scan vs post-hoc ------------------------
    # engine: the weight update ops live in the backward scan body ->
    # the jaxpr has no full-tree gradient outputs outside scans.
    tax = jax.make_jaxpr(
        make_train_step(cfg, QuantPolicy.off(), ocfg,
                        StepOptions(engine="taxonn")))(
        params, opt, batch, hyper, bits)
    scans = [e for e in tax.jaxpr.eqns if e.primitive.name == "scan"]
    rows.append({
        "name": "pipeline/update_inside_scan",
        "us_per_call": 0.0,
        "engine_scan_count": len(scans),
        "bwd_scan_emits_updated_params": int(any(
            any(v.aval.shape[:1] == (cfg.num_layers,) for v in e.outvars)
            for e in scans)),
    })
    return rows
