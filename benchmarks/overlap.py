"""Communication-overlapped backward scan: measured step time + HLO overlap.

Three measurements around ``QuantPolicy.overlap`` (core.taxonn /
dist.async_collectives):

  * ``overlap/step_walltime_{off,on}`` — the engine's train step inside a
    shard_map over all host devices with the per-layer dW all-reduce on the
    data axis: "off" is the blocking in-scan psum, "on" the software-
    pipelined bucketed ring (layer i's hops overlap layer i-1's VJP).  The
    "on" row carries ``speedup`` = t_off / t_on — the measured step-time
    change from the schedule alone.
  * ``overlap/hlo_overlap_fraction_{off,on}`` — ``dist.hlo_analysis.
    overlap_fraction`` of the two compiled modules: how many collectives
    have real compute scheduled inside their latency window.  The
    overlapped scan's cross-iteration windows (the hops riding the carry)
    are exactly the ones that show compute — the metric must be > 0 with
    overlap on.
  * ``overlap/ring_vs_psum`` — the transport alone: blocking bucketed-ring
    all-reduce vs one fused ``lax.psum`` for a dW-sized tensor.

The "on" row also carries ``modeled_hidden_comm_us``: the per-step
interconnect time the overlapped schedule can hide on real hardware (dW
ring bytes per layer x (L-1) overlappable layers / ICI bandwidth, the
``hlo_analysis`` accelerator model).  Host-CPU "devices" share one memory
system — the emulated ring has no DMA engine to overlap into — so the
MEASURED speedup on CPU hovers at/below 1.0 while the modeled number is
what the schedule buys on a pod; both land in the JSON so the regression
gate tracks the schedule's cost and the model tracks its value.

With fewer than 2 host devices the multi-device rows degrade to the
single-device schedule comparison (axes=(), the ring is the identity) so
the suite still produces comparable wall-times everywhere.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import QuantPolicy, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.dist.async_collectives import ring_all_reduce
from repro.dist.hlo_analysis import (ICI_BANDWIDTH, collective_stats,
                                     overlap_fraction)
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import Hyper, OptimizerConfig


def _cfg(L=6):
    return ModelConfig(
        name="bench-overlap", family="dense", num_layers=L, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=2048,
        compute_dtype="float32", logit_chunk=256)


def _time(fn, args, reps):
    out = fn(*args)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return (time.time() - t0) / reps * 1e6


def run(quick: bool = False):
    n_dev = len(jax.devices())
    multi = n_dev >= 2
    cfg = _cfg()
    params = lm.init_params(jax.random.key(0), cfg)
    ks = jax.random.split(jax.random.key(1), 2)
    b, t = 8, 128
    batch = {"tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, t), 0, cfg.vocab_size)}
    ocfg = OptimizerConfig(kind="sgd")
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(1e-2), step=jnp.int32(0))
    opt = init_train_state(params, ocfg)
    axes = ("data",) if multi else ()
    mesh = jax.make_mesh((n_dev,), ("data",)) if multi else None
    reps = 3 if quick else 10

    rows = []
    us, hlo_ov = {}, {}
    for overlap in ("off", "on"):
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          dw_psum_axes=axes, dw_num_replicas=n_dev or None,
                          overlap=overlap)
        step = make_train_step(cfg, pol, ocfg)
        if multi:
            fn = jax.jit(jax.shard_map(
                lambda p, s, bb: step(p, s, bb, hyper, bits),
                mesh=mesh, in_specs=(P(), P(), P("data")),
                out_specs=(P(), P(), P()), check_vma=False))
        else:
            fn = jax.jit(lambda p, s, bb: step(p, s, bb, hyper, bits))
        us[overlap] = _time(fn, (params, opt, batch), reps)
        hlo = fn.lower(params, opt, batch).compile().as_text()
        hlo_ov[overlap] = overlap_fraction(hlo)
        hlo_ov[overlap]["counts"] = collective_stats(hlo)["counts"]

    for overlap in ("off", "on"):
        row = {
            "name": f"overlap/step_walltime_{overlap}",
            "us_per_call": us[overlap],
            "n_devices": n_dev,
            "dw_psum_axes": "data" if multi else "none",
        }
        if overlap == "on":
            row["speedup"] = us["off"] / us["on"]
            # ring bytes per layer dW, hideable for all but the drain layer
            layer_bytes = sum(
                int(jnp.asarray(x).size / cfg.num_layers) * 4
                for x in jax.tree.leaves(params["blocks"]))
            ring_factor = 2.0 * (n_dev - 1) / n_dev if n_dev > 1 else 0.0
            row["modeled_hidden_comm_us"] = (
                layer_bytes * ring_factor * (cfg.num_layers - 1)
                / ICI_BANDWIDTH * 1e6)
        rows.append(row)
        ov = hlo_ov[overlap]
        rows.append({
            "name": f"overlap/hlo_overlap_fraction_{overlap}",
            "us_per_call": 0.0,
            "overlap_fraction": ov["overlap_fraction"],
            "collectives": ov["collectives"],
            "overlapped": ov["overlapped"],
            "compute_ops_in_windows": ov["compute_ops_in_windows"],
        })

    # --- transport alone: bucketed ring vs fused psum ---------------------
    if multi:
        x = jax.random.normal(jax.random.key(2), (1024, 1024))

        def ring(v):
            return ring_all_reduce(v, ("data",), num_replicas=n_dev,
                                   num_buckets=4)

        def psum(v):
            return jax.lax.psum(v, ("data",))

        for label, f in (("ring", ring), ("psum", psum)):
            g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(),
                                      out_specs=P(), check_vma=False))
            rows.append({
                "name": f"overlap/allreduce_{label}_4mb",
                # ms-scale collective rendezvous jitters hard; extra reps
                # keep the committed baseline stable for the gate
                "us_per_call": _time(g, (x,), 5 * reps),
                "n_devices": n_dev,
            })
    return rows
