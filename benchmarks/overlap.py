"""Communication-overlapped backward scan: measured step time + HLO overlap.

Three measurements around ``QuantPolicy.overlap`` (core.taxonn /
dist.async_collectives):

  * ``overlap/step_walltime_{off,on}`` — the engine's train step inside a
    shard_map over all host devices with the per-layer dW all-reduce on the
    data axis: "off" is the blocking in-scan psum; "on" follows the
    autotuned per-leaf transports — ring leaves ride the software
    pipeline, blocking leaves land same-iteration with fused-psum /
    sharded-scatter updates (on this host the autotuner picks scatter for
    the big dW leaves, so the win is the ZeRO-style 1/g update).  The two
    steps are timed as PAIRED interleaved reps and the "on" row carries
    ``speedup`` = median of per-pair t_off / t_on — robust to the host
    load drift that two separate timing loops would alias into the gate.
  * ``overlap/hlo_overlap_fraction_{off,on}`` — ``dist.hlo_analysis.
    overlap_fraction`` of the two compiled modules: how many collectives
    have real compute scheduled inside their latency window.  The
    overlapped scan's cross-iteration windows (the hops riding the carry)
    are exactly the ones that show compute — the metric must be > 0 with
    overlap on.
  * ``overlap/allreduce_{ring,psum}_4mb`` — the transport alone: blocking
    bucketed-ring all-reduce vs one fused ``lax.psum`` for a dW-sized
    tensor (legacy row names, kept stable for the regression gate).
  * ``overlap/transport_auto_*`` — the per-bucket TRANSPORT AUTOTUNER's
    decisions (``dist.async_collectives.decide_transport``): the suite
    primes the decision cache for every dW leaf size the step will reduce
    (plus the 4MB probe) and emits one non-timing row per size bucket
    with the measured ring/psum/scatter composite microseconds (reduce +
    optimizer-update tail) and which transport won.  The cache itself is
    dumped to ``artifacts/transport_cache.fresh.json`` for the CI artifact.

The step rows run with the policy defaults — ``dw_transport="auto"``
(primed, so the decisions are measured, not modeled) — so ``speedup``
on the "on" row is the number the CI speedup gate
(``benchmarks/check_overlap_speedup.py``) holds above 1.0.  The row also carries ``modeled_hidden_comm_us``: the
per-step interconnect time the overlapped schedule can hide on real
hardware (dW ring bytes per layer x (L-1) overlappable layers / ICI
bandwidth, the ``hlo_analysis`` accelerator model) — the autotuner keeps
the measured side honest on emulated host-CPU device groups (where it
picks the fused psum) while the model tracks what the schedule buys on a
pod.

With fewer than 2 host devices the multi-device rows degrade to the
single-device schedule comparison (axes=(), the ring is the identity) so
the suite still produces comparable wall-times everywhere.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import QuantPolicy, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.dist.async_collectives import (clear_transport_cache,
                                          dump_transport_cache,
                                          prime_transport_cache,
                                          ring_all_reduce,
                                          transport_cache_snapshot)
from repro.dist.hlo_analysis import (ICI_BANDWIDTH, collective_stats,
                                     overlap_fraction)
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import Hyper, OptimizerConfig


def _cfg(L=6):
    return ModelConfig(
        name="bench-overlap", family="dense", num_layers=L, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=2048,
        compute_dtype="float32", logit_chunk=256)


def _time(fn, args, reps):
    jax.block_until_ready(jax.tree.leaves(fn(*args))[0])
    t0 = time.time()
    for _ in range(reps):
        # block every rep: letting collective modules pile up in flight
        # can interleave their rendezvous participants on the CPU backend
        # and deadlock the emulated device group
        jax.block_until_ready(jax.tree.leaves(fn(*args))[0])
    return (time.time() - t0) / reps * 1e6


def _time_paired(fn_a, fn_b, args, reps):
    """Interleaved A/B timing: alternate one blocking rep of each function
    and report (median_a_us, median_b_us, median of per-pair a/b ratios).

    The step rows compare two ~1.4s programs whose difference is a few
    percent, on a host whose load drifts by more than that between two
    back-to-back measurement loops — pairing puts both programs under the
    same drift and the per-pair ratio cancels it; medians drop straggler
    reps (GC, scheduler hiccups) that a mean would smear into the gate."""
    def one(fn):
        t0 = time.time()
        jax.block_until_ready(jax.tree.leaves(fn(*args))[0])
        return time.time() - t0

    one(fn_a), one(fn_b)                  # compile + warm both
    ta, tb = [], []
    for _ in range(reps):
        ta.append(one(fn_a))
        tb.append(one(fn_b))
    med = sorted(a / b for a, b in zip(ta, tb))[reps // 2]
    return (sorted(ta)[reps // 2] * 1e6, sorted(tb)[reps // 2] * 1e6, med)


def run(quick: bool = False):
    n_dev = len(jax.devices())
    multi = n_dev >= 2
    cfg = _cfg()
    params = lm.init_params(jax.random.key(0), cfg)
    ks = jax.random.split(jax.random.key(1), 2)
    b, t = 8, 128
    batch = {"tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, t), 0, cfg.vocab_size)}
    ocfg = OptimizerConfig(kind="sgd")
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(1e-2), step=jnp.int32(0))
    opt = init_train_state(params, ocfg)
    axes = ("data",) if multi else ()
    mesh = jax.make_mesh((n_dev,), ("data",)) if multi else None
    reps = 3 if quick else 10

    rows = []
    if multi:
        # measure the autotuner's decisions EAGERLY for every dW leaf size
        # the overlapped step will reduce (+ the 4MB transport probe), so
        # the traced step consults measured decisions instead of the
        # platform model
        clear_transport_cache()
        leaf_bytes = [int(jnp.asarray(x).size / cfg.num_layers) * 4
                      for x in jax.tree.leaves(params["blocks"])]
        prime_transport_cache(leaf_bytes + [4 << 20], n_dev)
        for key, rec in sorted(transport_cache_snapshot().items()):
            if rec["source"] != "measured":
                continue
            nbytes = int(key.split("bytes=")[1].split(",")[0])
            rows.append({
                "name": f"overlap/transport_auto_{nbytes // 1024}kb",
                "us_per_call": 0.0,      # decision row, not a timing row
                "picked": rec["transport"],
                "source": rec["source"],
                "ring_us": rec["us"].get("ring", 0.0),
                "psum_us": rec["us"].get("psum", 0.0),
                "scatter_us": rec["us"].get("scatter", 0.0),
                "n_devices": n_dev,
            })

    us, hlo_ov, fns = {}, {}, {}
    for overlap in ("off", "on"):
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          dw_psum_axes=axes, dw_num_replicas=n_dev or None,
                          overlap=overlap)
        step = make_train_step(cfg, pol, ocfg)
        if multi:
            fn = jax.jit(jax.shard_map(
                lambda p, s, bb, _step=step: _step(p, s, bb, hyper, bits),
                mesh=mesh, in_specs=(P(), P(), P("data")),
                out_specs=(P(), P(), P()), check_vma=False))
        else:
            fn = jax.jit(
                lambda p, s, bb, _step=step: _step(p, s, bb, hyper, bits))
        fns[overlap] = fn
        hlo = fn.lower(params, opt, batch).compile().as_text()
        hlo_ov[overlap] = overlap_fraction(hlo)
        hlo_ov[overlap]["counts"] = collective_stats(hlo)["counts"]

    # paired interleaved timing: the off/on difference is a few percent,
    # smaller than this host's load drift between two separate timing
    # loops — the per-pair median ratio is what the speedup gate holds
    us["off"], us["on"], speedup = _time_paired(
        fns["off"], fns["on"], (params, opt, batch), 2 * reps + 1)

    for overlap in ("off", "on"):
        row = {
            "name": f"overlap/step_walltime_{overlap}",
            "us_per_call": us[overlap],
            "n_devices": n_dev,
            "dw_psum_axes": "data" if multi else "none",
        }
        if overlap == "on":
            row["speedup"] = speedup
            # ring bytes per layer dW, hideable for all but the drain layer
            layer_bytes = sum(
                int(jnp.asarray(x).size / cfg.num_layers) * 4
                for x in jax.tree.leaves(params["blocks"]))
            ring_factor = 2.0 * (n_dev - 1) / n_dev if n_dev > 1 else 0.0
            row["modeled_hidden_comm_us"] = (
                layer_bytes * ring_factor * (cfg.num_layers - 1)
                / ICI_BANDWIDTH * 1e6)
        rows.append(row)
        ov = hlo_ov[overlap]
        rows.append({
            "name": f"overlap/hlo_overlap_fraction_{overlap}",
            "us_per_call": 0.0,
            "overlap_fraction": ov["overlap_fraction"],
            "collectives": ov["collectives"],
            "overlapped": ov["overlapped"],
            "compute_ops_in_windows": ov["compute_ops_in_windows"],
        })

    # --- transport alone: bucketed ring vs fused psum ---------------------
    if multi:
        x = jax.random.normal(jax.random.key(2), (1024, 1024))

        def ring(v):
            return ring_all_reduce(v, ("data",), num_replicas=n_dev,
                                   num_buckets=4)

        def psum(v):
            return jax.lax.psum(v, ("data",))

        for label, f in (("ring", ring), ("psum", psum)):
            g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(),
                                      out_specs=P(), check_vma=False))
            rows.append({
                "name": f"overlap/allreduce_{label}_4mb",
                # ms-scale collective rendezvous jitters hard; extra reps
                # keep the committed baseline stable for the gate
                "us_per_call": _time(g, (x,), 5 * reps),
                "n_devices": n_dev,
            })
        dump_transport_cache("artifacts/transport_cache.fresh.json")
    return rows
