"""Hard overlap gate: the measured ``overlap=on`` step must be a WIN.

    python benchmarks/check_overlap_speedup.py --fresh BENCH_overlap.fresh.json

Reads the fresh overlap-suite JSON and fails (exit 1) when the
``overlap/step_walltime_on`` row's ``speedup`` (= t_off / t_on, measured
in the same run) is not above the threshold — the successor of the old
``overlap_fraction``-based check, which only proved the compiler
SCHEDULED compute into the collective windows, not that the schedule paid
off.  A 0.87x "overlap" is a regression, not a tuning artifact; this gate
makes it fail loudly.

Runner escape hatches, both explicit in the output:

  * fewer than ``--min-devices`` devices in the recorded row (single-
    device CI shards, laptops): the ring/psum tradeoff is not measurable,
    so the gate WARNS and exits 0 instead of failing — same warn-only
    stance as ``check_regression.py``'s missing-baseline path
  * a fresh file with no ``step_walltime_on`` row at all is an error:
    the suite silently not emitting the row must not read as a pass

``--min-speedup`` defaults to 1.0; REPRO_OVERLAP_MIN_SPEEDUP overrides
it (CI escape hatch, mirroring REPRO_BENCH_TOLERANCE).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def find_row(rows: list, name: str):
    for r in rows:
        if r.get("name") == name:
            return r
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="fresh overlap-suite JSON (benchmarks.run --json)")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="required overlap-on speedup t_off/t_on "
                         "(default 1.0: overlap must not lose)")
    ap.add_argument("--min-devices", type=int, default=4,
                    help="below this device count the gate warns instead "
                         "of failing (transport tradeoff not measurable)")
    args = ap.parse_args(argv)
    min_speedup = float(os.environ.get("REPRO_OVERLAP_MIN_SPEEDUP",
                                       args.min_speedup))

    with open(args.fresh) as f:
        rows = json.load(f)
    row = find_row(rows, "overlap/step_walltime_on")
    if row is None:
        print("error: no overlap/step_walltime_on row in the fresh run — "
              "the overlap suite did not produce the gated measurement")
        return 1
    speedup = row.get("speedup")
    n_dev = int(row.get("n_devices", 0))
    if speedup is None:
        print("error: overlap/step_walltime_on row carries no speedup "
              "field — cannot gate")
        return 1
    if n_dev < args.min_devices:
        print(f"warning: overlap speedup gate ran on {n_dev} device(s) "
              f"(< {args.min_devices}) — speedup x{speedup:.3f} recorded "
              f"but NOT gated (transport tradeoff needs a device group)")
        return 0
    if speedup < min_speedup:
        print(f"FAIL overlap/step_walltime_on: speedup x{speedup:.3f} < "
              f"x{min_speedup:.2f} on {n_dev} devices — overlap=on is a "
              f"measured slowdown (transport autotuner or pipeline depth "
              f"regressed)")
        return 1
    print(f"overlap speedup gate OK: x{speedup:.3f} >= x{min_speedup:.2f} "
          f"on {n_dev} devices")
    return 0


if __name__ == "__main__":
    sys.exit(main())
