"""Benchmark regression gate: compare a fresh benchmark JSON against the
committed baseline and fail on wall-time regressions.

    python benchmarks/check_regression.py \
        --baseline BENCH_kernels.json --fresh BENCH_kernels.fresh.json

Designed to survive CI noise and machine drift:

  * rows are matched by (suite, name); rows present on only one side
    never fail the gate (new benches don't need a baseline in the same PR
    that adds them), but a BASELINE row that disappears from the fresh
    run is warned about LOUDLY — the gate can no longer see that row, so
    its absence must not read as a pass — and so is a run whose
    comparable set is empty (the gate verified nothing); a --baseline
    FILE that does not exist yet (a whole new suite landing in this PR)
    warns and skips the gate instead of crashing CI
  * rows whose baseline wall-time is under ``--min-us`` are skipped — the
    timer jitter on micro-rows swamps any signal
  * the per-row ratio is normalized by the MINIMUM ratio across all
    comparable rows (floored at 1.0), so a uniformly slower CI machine
    shifts the whole distribution without tripping the gate; only rows
    that regress ``--tolerance`` beyond that shared shift fail.  The
    minimum — not the median — is the shift estimate so a regression
    shared by most rows (e.g. a slowdown in a helper they all call) still
    trips on every affected row as long as ONE unaffected row anchors the
    machine speed; only a regression uniform across ALL rows is
    indistinguishable from a slower machine, which is the inherent limit
    of a self-normalizing gate

REPRO_BENCH_TOLERANCE overrides --tolerance (CI escape hatch).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    out = {}
    for r in rows:
        if not isinstance(r.get("us_per_call"), (int, float)):
            continue
        out[(r.get("suite", ""), r.get("name", ""))] = float(r["us_per_call"])
    return out


def check(baseline: dict, fresh: dict, tolerance: float,
          min_us: float) -> list:
    """Return [(key, base_us, fresh_us, ratio, limit)] for failing rows.

    Rows with a non-positive baseline are skipped UNCONDITIONALLY, not
    just via the --min-us floor: non-timing rows (the hlo_overlap_fraction
    and speedup rows report us_per_call 0.0 by convention) must never
    enter the ratio math, where a 0.0 baseline is a divide-by-zero that a
    --min-us 0 run would otherwise trip.
    """
    comparable = {k: (baseline[k], fresh[k]) for k in baseline.keys() & fresh
                  if baseline[k] > 0 and baseline[k] >= min_us
                  and fresh[k] > 0}
    if not comparable:
        return []
    ratios = {k: f / b for k, (b, f) in comparable.items()}
    shift = max(1.0, min(ratios.values()))
    limit = shift * (1.0 + tolerance)
    return sorted((k, comparable[k][0], comparable[k][1], r, limit)
                  for k, r in ratios.items() if r > limit)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional wall-time regression beyond "
                         "the shared machine-speed shift (default 0.25)")
    ap.add_argument("--min-us", type=float, default=100.0,
                    help="skip rows whose baseline wall-time is below this "
                         "(timer noise floor)")
    args = ap.parse_args(argv)
    tol = float(os.environ.get("REPRO_BENCH_TOLERANCE", args.tolerance))

    if not os.path.exists(args.baseline):
        print(f"warning: no committed baseline at {args.baseline} (new "
              f"benchmark suite in this PR?); skipping the regression gate")
        return 0

    baseline = load_rows(args.baseline)
    fresh = load_rows(args.fresh)
    if not baseline:
        # the file exists but contains no timed rows (truncated regen,
        # schema drift): same verified-nothing hazard as every baseline
        # row disappearing — warn, and do NOT print the green OK line
        print(f"warning: {args.baseline} exists but contains no rows with "
              f"a numeric us_per_call — this gate run verified nothing")
        return 0
    only_base = sorted(baseline.keys() - fresh.keys())
    only_fresh = sorted(fresh.keys() - baseline.keys())
    for k in only_base:
        # a row the baseline promises but the fresh run no longer reports
        # is NOT a pass — the gate simply cannot see it anymore.  A rename
        # or a benchmark that silently stopped emitting rows would
        # otherwise green-wash a regression, so shout.
        print(f"warning: baseline row {'/'.join(k)} DISAPPEARED from the "
              f"fresh run — the gate cannot check it; if the row was "
              f"renamed or removed on purpose, refresh {args.baseline}")
    for k in only_fresh:
        print(f"note: {'/'.join(k)} has no committed baseline yet")

    failures = check(baseline, fresh, tol, args.min_us)
    n_cmp = len([k for k in baseline.keys() & fresh.keys()
                 if baseline[k] > 0 and baseline[k] >= args.min_us])
    if baseline and not n_cmp:
        # an empty comparable set means the gate verified NOTHING; today
        # that is a warning (rows on one side are informational by
        # design), but it must never read as a meaningful green result —
        # so return WITHOUT printing the "gate OK" line below
        print(f"warning: 0 of {len(baseline)} baseline rows were "
              f"comparable (disappeared or below --min-us "
              f"{args.min_us:.0f}us) — this gate run verified nothing")
        return 0
    if failures:
        print(f"\n{len(failures)} of {n_cmp} rows regressed beyond "
              f"{tol:.0%} (after machine-shift normalization):")
        for k, b, f, r, limit in failures:
            print(f"  FAIL {'/'.join(k)}: {b:.0f}us -> {f:.0f}us "
                  f"(x{r:.2f}, limit x{limit:.2f})")
        return 1
    print(f"benchmark gate OK: {n_cmp} rows within {tol:.0%} of "
          f"{args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
