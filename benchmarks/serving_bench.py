"""Serving-path cost: what the paged redesign buys over contiguous slots.

Replays one seeded open-loop arrival trace — every prompt shares a long
system prefix, then diverges — through three scheduler variants built on
the SAME model params:

  serving/contiguous     legacy per-slot contiguous KV, whole-prompt
                         prefill on admission (the pre-redesign baseline,
                         float32 as it shipped)
  serving/paged_chunked  the redesign's serving design point: paged block
                         pool + chunked prefill + prefix sharing + int8
                         KV (per-token scales); carries ``speedup`` = its
                         tokens/sec over the contiguous row's (gated
                         >= 1.0 by benchmarks/check_serving_speedup.py)
  serving/kv_f32         dtype ablation: same paged path, float32 KV
  serving/kv_bf16        dtype ablation: bfloat16 KV; carries
                         ``int8_speedup`` (design point over this row) —
                         on CPU CI bf16 is emulated, so this overstates
                         the int8 win vs real accelerator bf16
  serving/decode_fused   the design point again with the fused decode-
                         prologue kernel on (kernel_backend="emulate");
                         carries ``prologue_speedup`` = its tokens/sec
                         over the paged_chunked row's, gated >= 1.0 by
                         benchmarks/check_decode_speedup.py — warn-only
                         when ``interpret`` is true (CPU interpret-mode
                         Pallas measures structure, not speed)

Every row reports tokens/sec and per-request completion-latency p50/p99
(submit-to-done, milliseconds).  ``us_per_call`` is per generated token.
Each variant drains a short warmup trace first so the jitted
prefill/decode closures are compiled before the measured replay, and the
measured replay runs twice with the best wall-clock kept (CPU CI noise).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig

PREFIX_LEN = 448         # shared system prefix (the prefix-sharing payload)
SUFFIX_LEN = 64          # per-request unique tail
MAX_NEW = 8
BLOCK = 32
CHUNK = 256              # prefill token budget per tick (paged)


def _cfg():
    return ModelConfig(
        name="bench-serve", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
        compute_dtype="float32", logit_chunk=64)


def _trace(cfg, n_req, seed=0):
    """Open-loop arrival trace: fixed-length prompts, shared prefix."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    sys_prefix = rng.integers(0, cfg.vocab_size,
                              size=(PREFIX_LEN,)).astype(np.int32)
    reqs = []
    for i in range(n_req):
        tail = rng.integers(0, cfg.vocab_size,
                            size=(SUFFIX_LEN,)).astype(np.int32)
        reqs.append(Request(uid=i,
                            prompt=np.concatenate([sys_prefix, tail]),
                            max_new_tokens=MAX_NEW))
    return reqs


def _drain(sched, reqs):
    """Submit the whole trace, step to drained; per-request latencies."""
    for r in reqs:
        sched.submit(r)
    t0 = time.perf_counter()
    lat, live = [], list(reqs)
    while live:
        sched.step()
        now = time.perf_counter()
        lat += [now - t0 for r in live if r.done]
        live = [r for r in live if not r.done]
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in reqs)
    return wall, toks, sorted(lat)


def _variant(params, cfg, serve, n_req, seed):
    """Warmed, best-of-2 replay of the trace through one scheduler config.

    One EngineHooks (= one set of jitted closures) serves the warmup and
    both measured replays, so compile time never lands in the numbers.
    """
    from repro.serving import BatchScheduler, EngineHooks
    hooks = EngineHooks.for_model(params, cfg, serve)

    def replay(n, seed):
        sched = BatchScheduler(serve, EngineHooks(
            prefill=hooks.prefill, decode=hooks.decode, merge=hooks.merge,
            prefill_chunk=hooks.prefill_chunk, copy_block=hooks.copy_block,
            init_state=jax.tree.map(lambda x: x.copy(), hooks.init_state)))
        wall, toks, lat = _drain(sched, _trace(cfg, n, seed))
        return wall, toks, lat, sched

    replay(2, seed=99)                      # compile warmup, tiny trace
    best = None
    for _ in range(2):
        wall, toks, lat, sched = replay(n_req, seed)
        if best is None or wall < best[0]:
            best = (wall, toks, lat, sched)
    wall, toks, lat, sched = best
    return {"us_per_call": wall * 1e6 / toks,
            "tok_per_s": round(toks / wall, 2),
            "p50_ms": round(1e3 * lat[len(lat) // 2], 1),
            "p99_ms": round(1e3 * lat[min(len(lat) - 1,
                                          int(len(lat) * 0.99))], 1),
            "n_requests": len(lat),
            "tokens": toks}, sched


def run(quick: bool = False):
    from repro.serving import ServeConfig

    cfg = _cfg()
    params = lm.init_params(jax.random.key(0), cfg)
    n_req = 6 if quick else 12
    max_len = 576
    common = dict(num_slots=4, eos_id=None, max_len=max_len)
    rows = []

    contig = ServeConfig(mode="contiguous", cache_dtype="float32", **common)
    r_c, _ = _variant(params, cfg, contig, n_req, seed=0)
    rows.append({"name": "serving/contiguous",
                 "cache_dtype": "float32", **r_c})

    # pool sized for the trace: per-slot footprints + the prefix index,
    # which retains the shared prefix AND each request's registered tail
    # blocks until release_prefix_cache()
    n_blocks = (1 + 4 * (max_len // BLOCK + 2)
                + n_req * (-(-SUFFIX_LEN // BLOCK) + 1)
                + PREFIX_LEN // BLOCK)
    paged = ServeConfig(mode="paged", cache_dtype="int8",
                        block_size=BLOCK, prefill_chunk=CHUNK,
                        num_blocks=n_blocks, **common)
    r_p, sched = _variant(params, cfg, paged, n_req, seed=0)
    rows.append({"name": "serving/paged_chunked", "cache_dtype": "int8",
                 **r_p,
                 "speedup": round(r_p["tok_per_s"] / r_c["tok_per_s"], 3),
                 "prefix_hits": sched.stats["prefix_hits"],
                 "reused_tokens": sched.stats["reused_tokens"],
                 "cow_copies": sched.stats["cow_copies"]})

    r_f32, _ = _variant(
        params, cfg, paged.replace(cache_dtype="float32"), n_req, seed=0)
    rows.append({"name": "serving/kv_f32", "cache_dtype": "float32",
                 **r_f32})
    r_bf, _ = _variant(
        params, cfg, paged.replace(cache_dtype="bfloat16"), n_req, seed=0)
    rows.append({"name": "serving/kv_bf16", "cache_dtype": "bfloat16",
                 **r_bf,
                 "int8_speedup": round(r_p["tok_per_s"] / r_bf["tok_per_s"],
                                       3)})

    from repro.kernels import ops as kops
    r_fu, _ = _variant(
        params, cfg, paged.replace(kernel_backend="emulate"), n_req, seed=0)
    rows.append({"name": "serving/decode_fused", "cache_dtype": "int8",
                 **r_fu,
                 "prologue_speedup": round(r_fu["tok_per_s"]
                                           / r_p["tok_per_s"], 3),
                 "interpret": bool(kops._on_cpu())})
    return rows
