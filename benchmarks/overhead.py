"""Tables II/III analogue: the cost of ADDING TRAINING to inference.

TaxoNN's claim: training support costs ~9.5% area / ~6.4% power over the
inference-only baseline PE.  The TPU-native analogues are per-step resource
ratios between the TaxoNN train step and the forward (inference) pass, from
compiled artifacts on a reduced config with every scan unrolled (exact
counts):

  * FLOPs ratio        (Table II analogue: compute-resource overhead)
  * HBM bytes ratio    (Table III analogue: data-movement/energy overhead)

The paper's separate claim that BP cycles ~= feed-forward cycles maps to
the FLOPs ratio of backward-only vs forward.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import QuantPolicy, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.models import lm
from repro.optim import Hyper, OptimizerConfig
from repro.util.scan import unrolled_scans_ctx
from repro.models.config import ModelConfig


def _cfg():
    return ModelConfig(
        name="bench-dense", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=2048,
        compute_dtype="float32", logit_chunk=256)


def _cost(fn, *args):
    with unrolled_scans_ctx():
        compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))


def run(quick: bool = False):
    cfg = _cfg()
    params = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    b, t = 8, 256
    batch = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, t), jnp.int32)}

    t0 = time.time()
    fwd_flops, fwd_bytes = _cost(
        lambda p, bt: lm.forward_hidden(p, cfg, bt), params, batch)

    ocfg = OptimizerConfig(kind="sgd")
    step = make_train_step(cfg, QuantPolicy(grad_scale=64.0), ocfg)
    opt = jax.eval_shape(lambda p: init_train_state(p, ocfg), params)
    bits = default_bits(cfg, enabled=True)
    hyper = jax.eval_shape(lambda: Hyper(lr=jnp.float32(1e-2),
                                         step=jnp.int32(0)))
    bits_s = jax.eval_shape(lambda: bits)
    train_flops, train_bytes = _cost(step, params, opt, batch, hyper, bits_s)

    # fp32 train step (no quantization ops) — isolates the (I,F) emulation cost
    step_fp = make_train_step(cfg, QuantPolicy.off(), ocfg)
    fp_flops, fp_bytes = _cost(step_fp, params, opt, batch, hyper, bits_s)

    us = (time.time() - t0) * 1e6 / 3
    return [{
        "name": "overhead/train_vs_inference_flops",
        "us_per_call": us,
        "inference_flops": fwd_flops,
        "train_flops": train_flops,
        "ratio": train_flops / fwd_flops,
        # paper: BP cycle count ~ feed-forward cycle count (with remat the
        # engine's backward = fwd recompute + 2x backward matmuls)
        "backward_over_forward": (train_flops - fwd_flops) / fwd_flops,
    }, {
        "name": "overhead/train_vs_inference_bytes",
        "us_per_call": us,
        "inference_bytes": fwd_bytes,
        "train_bytes": train_bytes,
        "ratio": train_bytes / fwd_bytes,
    }, {
        "name": "overhead/quant_emulation_cost",
        "us_per_call": us,
        "train_flops_fp32": fp_flops,
        "train_flops_quant": train_flops,
        "flops_overhead": (train_flops - fp_flops) / fp_flops,
        "bytes_overhead": (train_bytes - fp_bytes) / fp_bytes,
    }]
