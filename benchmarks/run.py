"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
                                            [--json [PATH]]

Prints ``name,us_per_call,derived`` CSV (derived = key=value pairs) and,
with ``--json``, persists the rows as a JSON list (default path
``BENCH_kernels.json``) so the perf trajectory is tracked across PRs.  CI
runs the kernels and pipeline suites into fresh JSONs, gates them against
the committed ``BENCH_kernels.json`` / ``BENCH_pipeline.json`` baselines
via ``benchmarks/check_regression.py``, and uploads both as artifacts.

  convergence — Fig. 5 / Table I   (per-layer (I,F) vs fp32 accuracy)
  overhead    — Tables II/III     (train-support cost over inference)
  savings     — Table IV          (low-bitwidth savings vs full precision)
  pipeline    — Fig. 3            (fused per-layer BP vs monolithic)
  kernels     — PE datapath       (Pallas kernel microbenches, emulate+int8)
  overlap     — (beyond paper)    (comm-overlapped backward scan, ring vs
                                   psum, HLO overlap_fraction)
  roofline    — (beyond paper)    (dry-run roofline summary)
  ckpt        — (beyond paper)    (async save overhead per step, restore
                                   latency, integrity-scan cost)
  serving     — (beyond paper)    (paged+chunked+prefix-shared continuous
                                   batching vs contiguous slots; int8 vs
                                   bf16 KV; tokens/sec and p50/p99)
  bitwidth    — ROADMAP item 4    (per-layer (I,F) sensitivity sweep +
                                   train->serve int8 export parity)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?",
                    const="artifacts/BENCH_kernels.fresh.json",
                    default=None, metavar="PATH",
                    help="write results as a JSON list (default "
                         "artifacts/BENCH_kernels.fresh.json; pass an "
                         "explicit path to regenerate a committed baseline)")
    args = ap.parse_args()

    from benchmarks import (bitwidth, ckpt_bench, convergence, kernels_bench,
                            overhead, overlap, pipeline, roofline, savings,
                            serving_bench)
    suites = {
        "convergence": convergence.run,
        "overhead": overhead.run,
        "savings": savings.run,
        "pipeline": pipeline.run,
        "kernels": kernels_bench.run,
        "overlap": overlap.run,
        "roofline": roofline.run,
        "ckpt": ckpt_bench.run,
        "serving": serving_bench.run,
        "bitwidth": bitwidth.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    print("name,us_per_call,derived")
    results = []
    failures = 0
    for name, fn in suites.items():
        try:
            rows = fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"{name},0,ERROR={type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            failures += 1
            continue
        for r in rows:
            derived = ";".join(
                f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in r.items() if k not in ("name", "us_per_call"))
            print(f"{r['name']},{r['us_per_call']:.1f},{derived}", flush=True)
            results.append({"suite": name, **r})
    if args.json:
        parent = os.path.dirname(args.json)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {len(results)} rows to {args.json}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
