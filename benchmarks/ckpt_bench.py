"""Checkpoint-path cost: what fault tolerance charges the training loop.

Times the four operations on a synthetic multi-leaf pytree sized like a
reduced-config model:

  ckpt/sync_save        full atomic save (write + fsync + rename), the
                        cost a SYNCHRONOUS checkpointer would charge
  ckpt/async_overhead   per-step time ``AsyncCheckpointer.save`` blocks the
                        loop (host snapshot + join of the previous write)
                        when compute covers the write — the number that
                        belongs in the training-step budget
  ckpt/restore          restore_checkpoint (verify + load + host->device)
  ckpt/verify           standalone integrity scan (crc32 over every leaf)

Derived fields carry the tree size so MB/s trends survive size changes.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np


def make_tree(total_mb: float, n_leaves: int = 16) -> dict:
    rng = np.random.default_rng(0)
    per = max(1, int(total_mb * 1e6 / 4 / n_leaves))
    return {f"layer{i:02d}": {"w": rng.standard_normal(per)
                              .astype(np.float32)}
            for i in range(n_leaves)}


def run(quick: bool = False):
    from repro.ckpt import (AsyncCheckpointer, restore_checkpoint,
                            save_checkpoint, verify_checkpoint)

    total_mb = 8.0 if quick else 64.0
    n_saves = 4 if quick else 6
    tree = make_tree(total_mb)
    rows = []

    with tempfile.TemporaryDirectory() as d:
        # sync save: the cost fault tolerance charges without async
        t0 = time.perf_counter()
        save_checkpoint(d, 1, tree)
        sync_s = time.perf_counter() - t0
        rows.append({"name": "ckpt/sync_save",
                     "us_per_call": sync_s * 1e6,
                     "mb": total_mb,
                     "mb_per_s": round(total_mb / sync_s, 1)})

        t0 = time.perf_counter()
        problems = verify_checkpoint(d, 1)
        verify_s = time.perf_counter() - t0
        assert problems == []
        rows.append({"name": "ckpt/verify",
                     "us_per_call": verify_s * 1e6,
                     "mb": total_mb,
                     "mb_per_s": round(total_mb / verify_s, 1)})

        t0 = time.perf_counter()
        restored, step, _ = restore_checkpoint(d, tree)
        restore_s = time.perf_counter() - t0
        assert step == 1
        rows.append({"name": "ckpt/restore",
                     "us_per_call": restore_s * 1e6,
                     "mb": total_mb,
                     "mb_per_s": round(total_mb / restore_s, 1)})

    # async overhead: per-step blocked time when inter-save compute covers
    # the background write (the steady-state training case)
    with tempfile.TemporaryDirectory() as d:
        compute_s = sync_s * 1.3
        blocked = []
        with AsyncCheckpointer(d, keep_n=2) as ck:
            for step in range(1, n_saves + 1):
                t0 = time.perf_counter()
                ck.save(step, tree)
                blocked.append(time.perf_counter() - t0)
                time.sleep(compute_s)  # stand-in for the training step
        # first save has no prior write to join; steady state is the rest
        steady = blocked[1:] or blocked
        rows.append({"name": "ckpt/async_overhead",
                     "us_per_call": float(np.mean(steady)) * 1e6,
                     "mb": total_mb,
                     "saves": n_saves,
                     "vs_sync_pct": round(100 * float(np.mean(steady))
                                          / sync_s, 1)})
    return rows
