"""Roofline summary table from the dry-run records (results/dryrun/*.json).

Not a compile pass itself — renders EXPERIMENTS.md §Roofline from the
records produced by ``python -m repro.launch.dryrun --all``.
"""
from __future__ import annotations

import glob
import json
import pathlib


def load_records(out_dir="results/dryrun"):
    recs = []
    for f in sorted(glob.glob(f"{out_dir}/*.json")):
        recs.append(json.loads(pathlib.Path(f).read_text()))
    return recs


def run(quick: bool = False):
    recs = load_records()
    # append §Perf optimized records when present (tagged by their opts)
    for r in load_records("results/perf"):
        if r.get("opts"):
            r = dict(r)
            r["arch"] = f"{r['arch']}+{'+'.join(r['opts'])}"
            recs.append(r)
    rows = []
    for r in recs:
        if r.get("mesh") != "pod_16x16":   # roofline table is single-pod
            continue
        if r["status"] != "ok":
            rows.append({
                "name": f"roofline/{r['arch']}__{r['cell']}",
                "us_per_call": 0.0,
                "status": r["status"],
                "reason": r.get("reason", r.get("error", ""))[:80],
            })
            continue
        t = r["cost"]["terms"]
        rows.append({
            "name": f"roofline/{r['arch']}__{r['cell']}",
            "us_per_call": t["step_time_lower_bound_s"] * 1e6,
            "status": "ok",
            "compute_ms": round(t["compute_s"] * 1e3, 2),
            "memory_ms": round(t["memory_s"] * 1e3, 2),
            "collective_ms": round(t["collective_s"] * 1e3, 2),
            "dominant": t["dominant"],
            "useful_flops_ratio": round(r.get("useful_flops_ratio") or 0, 3),
        })
    return rows
