"""Hard decode gate: the fused decode prologue must not be a slowdown.

    python benchmarks/check_decode_speedup.py --fresh BENCH_serving.fresh.json

Reads the fresh serving-suite JSON and fails (exit 1) when the
``serving/decode_fused`` row's ``prologue_speedup`` (= its tokens/sec
over the unfused ``serving/paged_chunked`` row's, measured on the same
arrival trace in the same run) is below the threshold.  The fused
RMSNorm+QKV+rope prologue exists to cut one HBM round-trip per decode
layer; if turning it on loses throughput, that must fail loudly instead
of shipping as a row nobody reads.

When the row carries ``interpret: true`` the kernels ran through the
Pallas CPU interpreter, which measures structure, not speed — the gate
degrades to warn-only (printed, exit 0), mirroring the overlap gate's
device-count escape hatch.  A fresh file with no ``serving/decode_fused``
row, or a row with no ``prologue_speedup`` field, is an error: the suite
silently not emitting the gated measurement must not read as a pass.

``--min-speedup`` defaults to 1.0; REPRO_DECODE_MIN_SPEEDUP overrides it
(CI escape hatch, mirroring REPRO_SERVING_MIN_SPEEDUP).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def find_row(rows: list, name: str):
    for r in rows:
        if r.get("name") == name:
            return r
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="fresh serving-suite JSON (benchmarks.run --json)")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="required fused-over-unfused tokens/sec ratio "
                         "(default 1.0: the fused prologue must not lose)")
    args = ap.parse_args(argv)
    min_speedup = float(os.environ.get("REPRO_DECODE_MIN_SPEEDUP",
                                       args.min_speedup))

    with open(args.fresh) as f:
        rows = json.load(f)
    row = find_row(rows, "serving/decode_fused")
    if row is None:
        print("error: no serving/decode_fused row in the fresh run — "
              "the serving suite did not produce the gated measurement")
        return 1
    speedup = row.get("prologue_speedup")
    if speedup is None:
        print("error: serving/decode_fused row carries no prologue_speedup "
              "field — cannot gate")
        return 1
    if speedup < min_speedup:
        msg = (f"serving/decode_fused: prologue_speedup x{speedup:.3f} < "
               f"x{min_speedup:.2f} — the fused decode prologue is a "
               f"measured slowdown vs the unfused norm+project+rope chain")
        if row.get("interpret"):
            print(f"WARN (interpret-mode kernels, not gating) {msg}")
            return 0
        print(f"FAIL {msg}")
        return 1
    print(f"decode speedup gate OK: x{speedup:.3f} >= x{min_speedup:.2f} "
          f"({row.get('tok_per_s')} tok/s fused vs unfused paged baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
