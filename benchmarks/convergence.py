"""Fig. 5 + Table I analogue: per-layer (I,F) training vs full precision.

Trains the paper's LeNet-class 5-layer network (as an MLP classifier, built
directly on the TaxoNN engine primitives forward_stack/backward_stack) on
the synthetic classification set, with:
  * fp32 (quantization off)
  * the paper's Table-I per-layer schedules (mnist / cifar10 / svhn points)
  * a deliberately-too-coarse schedule (the paper's under-fitting regime)

and a reduced LM (qwen-family twin) fp32-vs-quantized run.  Reports final
accuracy / loss deltas — the claim under test is Table I's ~1% gap.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lenet5 import CONFIG as LENET
from repro.core.taxonn import QuantPolicy, backward_stack, forward_stack
from repro.data import SyntheticClassificationDataset
from repro.optim import Hyper, OptimizerConfig, apply_update, init_opt_state
from repro.quant import make_bit_schedule, paper_schedule


def init_mlp(key, d_in, d_h, d_out, n_hidden):
    ks = jax.random.split(key, 3)
    return {
        "w_in": jax.random.normal(ks[0], (d_in, d_h), jnp.float32) * d_in ** -0.5,
        "hidden": jax.random.normal(
            ks[1], (n_hidden, d_h, d_h), jnp.float32) * d_h ** -0.5,
        "w_out": jax.random.normal(ks[2], (d_h, d_out), jnp.float32) * d_h ** -0.5,
    }


def make_mlp_step(policy: QuantPolicy, ocfg: OptimizerConfig):
    def body(w, shared, x, b_l):
        return jax.nn.relu(x @ w), jnp.float32(0.0)

    def step(params, opt, batch, hyper, bits):
        x, y = batch

        def in_f(w):
            return jax.nn.relu(x @ w)
        h0, in_vjp = jax.vjp(in_f, params["w_in"])

        h_final, caches, _ = forward_stack(body, params["hidden"], (),
                                           h0, bits, policy)

        def head_f(w, h):
            logits = h @ w
            ls = jax.nn.log_softmax(logits)
            loss = -jnp.mean(jnp.take_along_axis(ls, y[:, None], 1))
            return loss, logits
        loss, head_vjp, logits = jax.vjp(head_f, params["w_out"], h_final,
                                         has_aux=True)
        d_wout, G = head_vjp(jnp.float32(policy.grad_scale))

        G0, new_hidden, new_opt_h, _, _ = backward_stack(
            body, params["hidden"], (), opt["hidden"], caches, bits, G,
            hyper, policy, ocfg, 0.0)

        (d_win,) = in_vjp(G0)
        inv = 1.0 / policy.grad_scale
        new_win, new_opt_in = apply_update(
            params["w_in"], d_win * inv, opt["w_in"], hyper, ocfg)
        new_wout, new_opt_out = apply_update(
            params["w_out"], jax.tree.map(lambda g: g * inv, d_wout),
            opt["w_out"], hyper, ocfg)
        acc = jnp.mean(jnp.argmax(logits, -1) == y)
        return ({"w_in": new_win, "hidden": new_hidden, "w_out": new_wout},
                {"w_in": new_opt_in, "hidden": new_opt_h, "w_out": new_opt_out},
                loss, acc)
    return step


def eval_acc(params, x, y):
    h = jax.nn.relu(x @ params["w_in"])
    for i in range(params["hidden"].shape[0]):
        h = jax.nn.relu(h @ params["hidden"][i])
    return float(jnp.mean(jnp.argmax(h @ params["w_out"], -1) == y))


def run_mlp(schedule_name: str, bits, enabled: bool, steps=400, lr=0.05,
            seed=0):
    ds = SyntheticClassificationDataset(
        input_dim=LENET.input_dim, num_classes=LENET.num_classes,
        n_train=8192, n_test=2048, noise=3.5)
    n_hidden = LENET.num_layers - 2
    params = init_mlp(jax.random.key(seed), LENET.input_dim, LENET.hidden,
                      LENET.num_classes, n_hidden)
    ocfg = OptimizerConfig(kind="sgd")
    policy = (QuantPolicy(grad_scale=64.0) if enabled else QuantPolicy.off())
    opt = {k: init_opt_state(v, ocfg) for k, v in params.items()}
    step = jax.jit(make_mlp_step(policy, ocfg))
    t0 = time.time()
    losses = []
    for i, (xb, yb) in enumerate(ds.train_batches(128, steps, seed)):
        hyper = Hyper(lr=jnp.float32(lr), step=jnp.int32(i))
        params, opt, loss, acc = step(params, opt,
                                      (jnp.asarray(xb), jnp.asarray(yb)),
                                      hyper, bits)
        losses.append(float(loss))
    test_acc = eval_acc(params, jnp.asarray(ds.test[0]), jnp.asarray(ds.test[1]))
    us = (time.time() - t0) / max(len(losses), 1) * 1e6
    return {
        "name": f"convergence/lenet5_{schedule_name}",
        "us_per_call": us,
        "loss_first": float(np.mean(losses[:20])),
        "loss_last": float(np.mean(losses[-20:])),
        "test_acc": test_acc,
    }


def run(quick: bool = False):
    steps = 150 if quick else 400
    n_hidden = LENET.num_layers - 2
    rows = []
    fp32 = run_mlp("fp32", make_bit_schedule(n_hidden, enabled=False),
                   enabled=False, steps=steps)
    rows.append(fp32)
    for name in ("mnist", "cifar10", "svhn"):
        sched = paper_schedule(name, n_hidden)
        r = run_mlp(f"tableI_{name}", sched, enabled=True, steps=steps)
        r["acc_gap_vs_fp32"] = fp32["test_acc"] - r["test_acc"]
        rows.append(r)
    # the paper's under-fitting regime: far too few fractional bits
    coarse = make_bit_schedule(n_hidden, weight=(1, 3), act=(2, 3),
                               grad=(1, 3), ramp=False)
    r = run_mlp("underfit_1_3", coarse, enabled=True, steps=steps)
    r["acc_gap_vs_fp32"] = fp32["test_acc"] - r["test_acc"]
    rows.append(r)
    return rows
