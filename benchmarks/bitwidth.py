"""ROADMAP item 4: per-layer (I,F) bitwidth as a searchable dimension.

Runs the sensitivity sweep (repro.search.sensitivity) on the paper's
LeNet-class workload: per-layer-group probes over the candidate grid,
greedy minimal-format selection against a loss-delta target, then the
train->serve int8 conformance checks on the selected plan.

Row conventions (BENCH_bitwidth.json, gated by check_regression.py):
  * ``bitwidth/sweep_lenet`` carries the timed cost (us per probe) plus
    the selection outcome — the regression gate watches the timing.
  * ``bitwidth/group*`` and ``bitwidth/export_parity`` are decision rows
    (us_per_call 0.0, skipped by the gate) recording WHAT was chosen and
    whether parity held, so plan drift shows up in the JSON diff.
"""
from __future__ import annotations

import time


def run(quick: bool = False):
    from repro.search import export as bit_export
    from repro.search.sensitivity import SweepConfig, run_sweep

    sweep = SweepConfig(num_groups=2, probe_steps=60 if quick else 120,
                        target=0.08, seed=0)
    t0 = time.time()
    plan = run_sweep(sweep)
    dt_us = (time.time() - t0) * 1e6

    rows = [{
        "name": "bitwidth/sweep_lenet",
        "us_per_call": dt_us / max(plan.probes, 1),
        "probes": plan.probes,
        "groups": len(plan.groups),
        "probe_steps": plan.probe_steps,
        "baseline_loss": plan.baseline_loss,
        "final_loss": plan.final_loss,
        "loss_delta": plan.final_loss - plan.baseline_loss,
        "target": plan.target,
        "met_target": int(plan.met_target),
    }]
    for g in plan.groups:
        rows.append({
            "name": f"bitwidth/group{g.group}",
            "us_per_call": 0.0,  # decision row: gate skips it
            "layers": len(g.layers),
            "i_bits": g.i_bits,
            "f_bits": g.f_bits,
            "bitwidth": g.bitwidth,
            "probe_loss": g.probe_loss,
            "met_target": int(g.met_target),
        })

    parity = bit_export.verify_train_serve_parity(plan)
    rows.append({
        "name": "bitwidth/export_parity",
        "us_per_call": 0.0,  # decision row: gate skips it
        "ok": int(parity["ok"]),
        "grid_ok": int(parity["grid_ok"]),
        "kv_ok": int(parity["kv_ok"]),
        "prologue_ok": int(parity["prologue_ok"]),
        "grid_msb_max_diff": parity["grid_msb_max_diff"],
        "kv_scale_max_diff": parity["kv_scale_max_diff"],
        "prologue_max_diff": parity["prologue_max_diff"],
    })
    return rows
