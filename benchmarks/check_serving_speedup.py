"""Hard serving gate: the paged redesign must be a measured WIN.

    python benchmarks/check_serving_speedup.py --fresh BENCH_serving.fresh.json

Reads the fresh serving-suite JSON and fails (exit 1) when the
``serving/paged_chunked`` row's ``speedup`` (= its tokens/sec over the
``serving/contiguous`` row's, measured in the same run) is not above the
threshold.  The redesign's pitch is throughput — chunked prefill keeps
decode ticking during admission, prefix sharing skips recomputing the
shared system prompt, int8 KV quarters the pool-gather bandwidth — and
this gate makes "paged is actually slower than the legacy contiguous
slots" fail loudly instead of shipping as a row nobody reads.

A fresh file with no ``serving/paged_chunked`` row at all is an error:
the suite silently not emitting the gated measurement must not read as a
pass.  Unlike the overlap gate there is no device-count escape hatch —
the comparison is single-process and runs anywhere the suite runs.

``--min-speedup`` defaults to 1.0; REPRO_SERVING_MIN_SPEEDUP overrides
it (CI escape hatch, mirroring REPRO_OVERLAP_MIN_SPEEDUP).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def find_row(rows: list, name: str):
    for r in rows:
        if r.get("name") == name:
            return r
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="fresh serving-suite JSON (benchmarks.run --json)")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="required paged-over-contiguous tokens/sec ratio "
                         "(default 1.0: the redesign must not lose)")
    args = ap.parse_args(argv)
    min_speedup = float(os.environ.get("REPRO_SERVING_MIN_SPEEDUP",
                                       args.min_speedup))

    with open(args.fresh) as f:
        rows = json.load(f)
    row = find_row(rows, "serving/paged_chunked")
    if row is None:
        print("error: no serving/paged_chunked row in the fresh run — "
              "the serving suite did not produce the gated measurement")
        return 1
    speedup = row.get("speedup")
    if speedup is None:
        print("error: serving/paged_chunked row carries no speedup field "
              "— cannot gate")
        return 1
    if speedup < min_speedup:
        print(f"FAIL serving/paged_chunked: speedup x{speedup:.3f} < "
              f"x{min_speedup:.2f} — the paged+chunked+prefix-shared path "
              f"is a measured slowdown vs whole-prompt contiguous slots "
              f"(pool gather, tick interleave, or admission regressed)")
        return 1
    print(f"serving speedup gate OK: x{speedup:.3f} >= x{min_speedup:.2f} "
          f"({row.get('tok_per_s')} tok/s paged vs contiguous baseline, "
          f"{row.get('prefix_hits')} prefix hits)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
