"""Kernel microbenchmarks: us_per_call of the TaxoNN Pallas kernels
(interpret mode on CPU — structural check; Mosaic-compiled on TPU) against
their XLA-fused jnp references, on both datapaths (f32 emulation and the
int8 MXU path).

Two row families:

  kernels/<op>                    the original square-shape smoke rows
  kernels/fxp_matmul/<arch>_<x>   production-shape sweep: each arch's
                                  hottest matmul at its REAL geometry
                                  (GQA QKV projections, MoE expert mats,
                                  SSD in-projection) on the int8 datapath;
                                  the note records the tune_blocks pick
  kernels/decode_prologue         the fused RMSNorm+QKV+rope decode
                                  prologue vs the unfused op chain

The run also dumps the autotuner's decision cache to
``artifacts/tune_cache.fresh.json`` (CI uploads it next to
``transport_cache.fresh.json``; REPRO_TUNE_CACHE preloads it elsewhere).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ops import (bp_fused_unit_op, bp_gstep_op,
                               dump_tune_cache, fxp_matmul_op,
                               sgd_dw_update_op, tune_blocks)


def _timeit(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def _config_sweep(quick: bool):
    """Per-arch rows at REAL production shapes (t tokens worth of rows
    against the arch's hot weight matrix), int8 MXU datapath vs the jnp
    int8 reference.  t is small — these measure the n*k weight streaming
    the decode/train hot loop actually does, not a square toy."""
    from repro.configs import get_config

    t = 8 if quick else 16
    specs = []
    for arch in ("gemma-7b", "yi-34b"):
        c = get_config(arch)
        n = (c.num_heads + 2 * c.num_kv_heads) * c.head_dim
        specs.append((arch, "qkv", t, n, c.d_model))
    for arch in ("mixtral-8x7b", "deepseek-v2-lite-16b"):
        c = get_config(arch)
        specs.append((arch, "moe_expert", t, int(c.moe_d_ff), c.d_model))
    c = get_config("mamba2-370m")
    specs.append(("mamba2-370m", "ssd_inproj", t, 2 * c.d_inner, c.d_model))

    jref = jax.jit(lambda a, b: ref.fxp_matmul_int8_ref(a, b))

    def mm_i8(a, b):
        return fxp_matmul_op(a, b, datapath="int8")

    rows = []
    for arch, kind, m, n, k in specs:
        x = jax.random.normal(jax.random.key(10), (m, k))
        w = jax.random.normal(jax.random.key(11), (k, n)) * (k ** -0.5)
        rows.append({
            "name": f"kernels/fxp_matmul/{arch}_{kind}",
            "us_per_call": _timeit(mm_i8, x, w, reps=2),
            "ref_us": _timeit(jref, x, w, reps=2),
            "shape": f"{m}x{n}x{k}",
            "note": f"tune_blocks={tune_blocks(m, n, k, itemsize=1)}",
        })
    return rows


def _prologue_row():
    """Fused decode-prologue kernel vs the unfused norm+project+rope op
    chain, at the serving bench's model geometry (B=8 decode batch)."""
    from repro.kernels import decode_prologue as DP
    from repro.models import layers as L
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="bench-prologue", family="dense", num_layers=1, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
        compute_dtype="float32")
    b, d, h, hkv, hd = 8, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.head_dim
    norm = {"scale": jnp.ones((d,), jnp.float32)}
    attn = {
        "wq": jax.random.normal(jax.random.key(20), (d, h, hd)) * 0.02,
        "wk": jax.random.normal(jax.random.key(21), (d, hkv, hd)) * 0.02,
        "wv": jax.random.normal(jax.random.key(22), (d, hkv, hd)) * 0.02,
    }
    x = jax.random.normal(jax.random.key(23), (b, 1, d), jnp.float32)
    pos = jnp.full((b,), 17, jnp.int32)

    fused = jax.jit(lambda xx: DP.decode_prologue(norm, attn, xx, cfg, pos))
    unfused = jax.jit(lambda xx: L._project_qkv(
        attn, L.apply_norm(norm, xx, cfg), cfg, pos[:, None]))
    return {
        "name": "kernels/decode_prologue",
        "us_per_call": _timeit(fused, x),
        "ref_us": _timeit(unfused, x),
        "shape": f"b{b}_d{d}_h{h}kv{hkv}x{hd}",
        "note": "fused RMSNorm+QKV+rope vs the unfused op chain",
    }


def run(quick: bool = False):
    m = 128 if quick else 256
    x = jax.random.normal(jax.random.key(0), (m, m))
    w = jax.random.normal(jax.random.key(1), (m, m))
    g = jax.random.normal(jax.random.key(2), (m, m)) * 0.1
    z = jax.random.normal(jax.random.key(3), (m, m))

    jref_mm = jax.jit(lambda a, b: ref.fxp_matmul_ref(a, b))
    jref_mm8 = jax.jit(lambda a, b: ref.fxp_matmul_int8_ref(a, b))
    jref_g = jax.jit(lambda a, b, c: ref.bp_gstep_ref(a, b, c))
    jref_u = jax.jit(lambda a, b, c: ref.sgd_dw_update_ref(a, b, c, 0.01))
    jref_f = jax.jit(lambda a, b, c, d: ref.bp_fused_unit_ref(a, b, c, d,
                                                              0.01))
    jref_f8 = jax.jit(lambda a, b, c, d: ref.bp_fused_unit_int8_ref(a, b, c,
                                                                    d, 0.01))

    def mm_i8(a, b):
        return fxp_matmul_op(a, b, datapath="int8")

    def fused(a, b, c, d):
        return bp_fused_unit_op(a, b, c, d, 0.01)

    def fused_i8(a, b, c, d):
        return bp_fused_unit_op(a, b, c, d, 0.01, datapath="int8")

    shape = f"{m}x{m}x{m}"
    rows = [{
        "name": "kernels/fxp_matmul",
        "us_per_call": _timeit(fxp_matmul_op, x, w),
        "ref_us": _timeit(jref_mm, x, w),
        "shape": shape,
        "note": "interpret-mode on CPU; Mosaic on TPU",
    }, {
        "name": "kernels/fxp_matmul_int8",
        "us_per_call": _timeit(mm_i8, x, w),
        "ref_us": _timeit(jref_mm8, x, w),
        "shape": shape,
        "note": "int8 MXU datapath (int32 wide accumulators)",
    }, {
        "name": "kernels/bp_gstep",
        "us_per_call": _timeit(bp_gstep_op, g, w, z),
        "ref_us": _timeit(jref_g, g, w, z),
        "shape": shape,
    }, {
        "name": "kernels/sgd_dw_update",
        "us_per_call": _timeit(lambda a, b, c: sgd_dw_update_op(a, b, c, 0.01),
                               x, g, w),
        "ref_us": _timeit(jref_u, x, g, w),
        "shape": shape,
    }, {
        "name": "kernels/bp_fused_unit",
        "us_per_call": _timeit(fused, g, w, x, z),
        "ref_us": _timeit(jref_f, g, w, x, z),
        "shape": shape,
        "note": "full TDM frame (Eq.8+Eq.9+Eq.1) in one pass",
    }, {
        "name": "kernels/bp_fused_unit_int8",
        "us_per_call": _timeit(fused_i8, g, w, x, z),
        "ref_us": _timeit(jref_f8, g, w, x, z),
        "shape": shape,
    }]
    rows += _config_sweep(quick)
    rows.append(_prologue_row())
    dump_tune_cache("artifacts/tune_cache.fresh.json")
    return rows
