"""Kernel microbenchmarks: us_per_call of the three TaxoNN Pallas kernels
(interpret mode on CPU — structural check; Mosaic-compiled on TPU) against
their XLA-fused jnp references."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ops import bp_gstep_op, fxp_matmul_op, sgd_dw_update_op


def _timeit(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run(quick: bool = False):
    m = 128 if quick else 256
    x = jax.random.normal(jax.random.key(0), (m, m))
    w = jax.random.normal(jax.random.key(1), (m, m))
    g = jax.random.normal(jax.random.key(2), (m, m)) * 0.1
    z = jax.random.normal(jax.random.key(3), (m, m))

    jref_mm = jax.jit(lambda a, b: ref.fxp_matmul_ref(a, b))
    jref_g = jax.jit(lambda a, b, c: ref.bp_gstep_ref(a, b, c))
    jref_u = jax.jit(lambda a, b, c: ref.sgd_dw_update_ref(a, b, c, 0.01))

    return [{
        "name": "kernels/fxp_matmul",
        "us_per_call": _timeit(fxp_matmul_op, x, w),
        "ref_us": _timeit(jref_mm, x, w),
        "shape": f"{m}x{m}x{m}",
        "note": "interpret-mode on CPU; Mosaic on TPU",
    }, {
        "name": "kernels/bp_gstep",
        "us_per_call": _timeit(bp_gstep_op, g, w, z),
        "ref_us": _timeit(jref_g, g, w, z),
        "shape": f"{m}x{m}x{m}",
    }, {
        "name": "kernels/sgd_dw_update",
        "us_per_call": _timeit(lambda a, b, c: sgd_dw_update_op(a, b, c, 0.01),
                               x, g, w),
        "ref_us": _timeit(jref_u, x, g, w),
        "shape": f"{m}x{m}x{m}",
    }]
