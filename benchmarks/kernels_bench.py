"""Kernel microbenchmarks: us_per_call of the four TaxoNN Pallas kernels
(interpret mode on CPU — structural check; Mosaic-compiled on TPU) against
their XLA-fused jnp references, on both datapaths (f32 emulation and the
int8 MXU path)."""
from __future__ import annotations

import time

import jax

from repro.kernels import ref
from repro.kernels.ops import (bp_fused_unit_op, bp_gstep_op, fxp_matmul_op,
                               sgd_dw_update_op)


def _timeit(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run(quick: bool = False):
    m = 128 if quick else 256
    x = jax.random.normal(jax.random.key(0), (m, m))
    w = jax.random.normal(jax.random.key(1), (m, m))
    g = jax.random.normal(jax.random.key(2), (m, m)) * 0.1
    z = jax.random.normal(jax.random.key(3), (m, m))

    jref_mm = jax.jit(lambda a, b: ref.fxp_matmul_ref(a, b))
    jref_mm8 = jax.jit(lambda a, b: ref.fxp_matmul_int8_ref(a, b))
    jref_g = jax.jit(lambda a, b, c: ref.bp_gstep_ref(a, b, c))
    jref_u = jax.jit(lambda a, b, c: ref.sgd_dw_update_ref(a, b, c, 0.01))
    jref_f = jax.jit(lambda a, b, c, d: ref.bp_fused_unit_ref(a, b, c, d,
                                                              0.01))
    jref_f8 = jax.jit(lambda a, b, c, d: ref.bp_fused_unit_int8_ref(a, b, c,
                                                                    d, 0.01))

    def mm_i8(a, b):
        return fxp_matmul_op(a, b, datapath="int8")

    def fused(a, b, c, d):
        return bp_fused_unit_op(a, b, c, d, 0.01)

    def fused_i8(a, b, c, d):
        return bp_fused_unit_op(a, b, c, d, 0.01, datapath="int8")

    shape = f"{m}x{m}x{m}"
    return [{
        "name": "kernels/fxp_matmul",
        "us_per_call": _timeit(fxp_matmul_op, x, w),
        "ref_us": _timeit(jref_mm, x, w),
        "shape": shape,
        "note": "interpret-mode on CPU; Mosaic on TPU",
    }, {
        "name": "kernels/fxp_matmul_int8",
        "us_per_call": _timeit(mm_i8, x, w),
        "ref_us": _timeit(jref_mm8, x, w),
        "shape": shape,
        "note": "int8 MXU datapath (int32 wide accumulators)",
    }, {
        "name": "kernels/bp_gstep",
        "us_per_call": _timeit(bp_gstep_op, g, w, z),
        "ref_us": _timeit(jref_g, g, w, z),
        "shape": shape,
    }, {
        "name": "kernels/sgd_dw_update",
        "us_per_call": _timeit(lambda a, b, c: sgd_dw_update_op(a, b, c, 0.01),
                               x, g, w),
        "ref_us": _timeit(jref_u, x, g, w),
        "shape": shape,
    }, {
        "name": "kernels/bp_fused_unit",
        "us_per_call": _timeit(fused, g, w, x, z),
        "ref_us": _timeit(jref_f, g, w, x, z),
        "shape": shape,
        "note": "full TDM frame (Eq.8+Eq.9+Eq.1) in one pass",
    }, {
        "name": "kernels/bp_fused_unit_int8",
        "us_per_call": _timeit(fused_i8, g, w, x, z),
        "ref_us": _timeit(jref_f8, g, w, x, z),
        "shape": shape,
    }]
