"""Table IV analogue: savings of low-bitwidth TaxoNN vs full precision.

Paper: 2.1x power / 1.65x area over a full-precision training
implementation.  The pod-scale analogues measured here:

  * gradient-exchange wire bytes: int8 block-scaled codec vs f32/bf16
    dense all-reduce (per-layer DP reduction = the paper's dominant
    data movement)
  * serving cache bytes: int8 vs bf16 vs f32 KV/state caches per arch
  * weight-storage bytes: (I,F)<=8-bit fixed point vs f32 master
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.quant.compression import compress_int8, quantized_allreduce_bytes
from repro.serving import init_decode_state


def run(quick: bool = False):
    rows = []
    t0 = time.time()

    # --- gradient-exchange compression (measured codec output sizes) -----
    n = 1_000_000
    g = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    payload, scales = compress_int8(jnp.asarray(g))
    wire = payload.size * 1 + scales.size * 4
    acct = quantized_allreduce_bytes(n)
    rows.append({
        "name": "savings/gradient_exchange",
        "us_per_call": (time.time() - t0) * 1e6,
        "f32_bytes": n * 4,
        "bf16_bytes": n * 2,
        "int8_wire_bytes": int(wire),
        "reduction_vs_f32": n * 4 / wire,
        "reduction_vs_bf16": n * 2 / wire,
        "accounting_model": acct["reduction"],
    })

    # --- serving cache bytes (per arch, decode_32k working set) ----------
    archs = ("qwen1.5-0.5b", "mamba2-370m") if quick else (
        "mixtral-8x7b", "deepseek-v2-lite-16b", "mamba2-370m", "qwen1.5-0.5b")
    for arch in archs:
        cfg = get_config(arch)
        st = jax.eval_shape(lambda c=cfg: init_decode_state(c, 8, 4096,
                                                            jnp.bfloat16))
        bf16 = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                   for s in jax.tree.leaves(st["caches"]))
        st8 = jax.eval_shape(lambda c=cfg: init_decode_state(c, 8, 4096,
                                                             jnp.int8))
        i8 = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                 for s in jax.tree.leaves(st8["caches"]))
        rows.append({
            "name": f"savings/cache_bytes_{arch}",
            "us_per_call": 0.0,
            "bf16_cache_bytes": bf16,
            "int8_cache_bytes": i8,
            "reduction": bf16 / i8,
        })

    # --- weight storage at paper formats ---------------------------------
    cfg = get_config("qwen1.5-0.5b")
    n_params = cfg.param_count()
    rows.append({
        "name": "savings/weight_storage",
        "us_per_call": 0.0,
        "f32_bytes": n_params * 4,
        "fxp15_bytes": n_params * 15 // 8,   # (2,12) = 15-bit
        "fxp8_bytes": n_params,
        "reduction_15bit": 4 / (15 / 8),
        "reduction_8bit": 4.0,
    })
    return rows
