"""Direct unit tests for repro.dist.api and repro.dist.sharding."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax.numpy as jnp

from repro.dist.api import (activation_sharding_ctx, constrain,
                            make_default_rules, model_axis_size_ctx,
                            perf_opt, perf_options_ctx)

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 2, timeout=300):
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT/'src'}:{ROOT/'tests'}",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

def test_make_default_rules_table():
    r = make_default_rules(("data",))
    assert r["b"] == ("data",)
    assert r["t"] is None          # sequence replicated without seq_parallel
    assert r["d"] is None          # residual stream TP-replicated
    assert r["v"] == "model"       # vocab-parallel CE head


def test_make_default_rules_seq_parallel():
    r = make_default_rules(("pod", "data"), seq_parallel=True)
    assert r["b"] == ("pod", "data")
    assert r["t"] == "model"       # the one thing seq_parallel changes
    assert make_default_rules(("pod", "data"))["t"] is None


def test_seq_parallel_never_steals_vocab_axis():
    """Under seq_parallel both 't' and 'v' want "model"; vocab must win —
    the CE head's masked-target pick is collective-free only with V
    sharded (see lm.ce_from_weight)."""
    from types import SimpleNamespace
    from jax.sharding import PartitionSpec as P
    from repro.dist.api import _spec_for

    mesh = SimpleNamespace(axis_names=("data", "model"),
                           shape={"data": 2, "model": 2})
    rules = make_default_rules(("data",), seq_parallel=True)
    assert _spec_for("btv", 3, rules, mesh, (4, 8, 128)) == \
        P("data", None, "model")
    # without a vocab dim, seq_parallel does shard the sequence
    assert _spec_for("btd", 3, rules, mesh, (4, 8, 128)) == \
        P("data", "model", None)


# ---------------------------------------------------------------------------
# constrain outside any mesh context
# ---------------------------------------------------------------------------

def test_constrain_noop_outside_mesh():
    x = jnp.arange(12.0).reshape(3, 4)
    assert constrain(x, "btd") is x                      # no rules, no mesh
    with activation_sharding_ctx(make_default_rules(("data",))):
        assert constrain(x, "btd") is x                  # rules but no mesh
    assert model_axis_size_ctx() == 1


def test_perf_options_scoping():
    assert not perf_opt("ce_bf16")
    with perf_options_ctx({"ce_bf16", "seq_parallel"}):
        assert perf_opt("ce_bf16") and perf_opt("seq_parallel")
        assert not perf_opt("moe_rowcombine")
    assert not perf_opt("ce_bf16")
    try:
        with perf_options_ctx({"not_a_real_option"}):
            pass
        raise AssertionError("unknown option accepted")
    except ValueError:
        pass


# ---------------------------------------------------------------------------
# pspecs on a 1x2 host mesh (subprocess: needs 2 devices)
# ---------------------------------------------------------------------------

def test_param_and_batch_pspecs_1x2_mesh():
    out = run_py("""
import jax, jax.numpy as jnp
from jax.sharding import AxisType, PartitionSpec as P
from repro.dist.sharding import batch_pspecs, param_pspecs, to_named
from repro.models import lm
from test_models import tiny, make_batch

cfg = tiny("dense")
params = lm.init_params(jax.random.key(0), cfg)
mesh = jax.make_mesh((1, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)

specs = param_pspecs(cfg, params, mesh)
# same structure as the params tree
assert jax.tree.structure(specs, is_leaf=lambda s: isinstance(s, P)) \\
    .num_leaves == len(jax.tree.leaves(params))
# vocab-sharded embedding, head-sharded attention, col/row-parallel MLP
assert specs["embed"] == P("model", None)
assert specs["blocks"]["attn"]["wq"] == P(None, None, "model", None)
assert specs["blocks"]["attn"]["wo"] == P(None, "model", None, None)
assert specs["blocks"]["mlp"]["w_up"] == P(None, None, "model")
assert specs["blocks"]["mlp"]["w_down"] == P(None, "model", None)
assert specs["final_norm"]["scale"] == P()
# every spec is realizable: device_put the whole tree
placed = jax.device_put(params, to_named(specs, mesh))
for a, b in zip(jax.tree.leaves(placed), jax.tree.leaves(params)):
    assert a.shape == b.shape

batch = make_batch(cfg, b=2, t=16)
bspecs = batch_pspecs(batch, mesh)
assert bspecs["tokens"] == P("data", None)
assert bspecs["labels"] == P("data", None)
print("PSPECS OK")
""")
    assert "PSPECS OK" in out


def test_constrain_applies_inside_mesh():
    out = run_py("""
import jax, jax.numpy as jnp
from jax.sharding import AxisType
from repro.dist.api import (activation_sharding_ctx, constrain,
                            make_default_rules, model_axis_size_ctx)

mesh = jax.make_mesh((1, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
rules = make_default_rules(("data",))
x = jnp.arange(2.0 * 8 * 128).reshape(2, 8, 128)
with jax.set_mesh(mesh), activation_sharding_ctx(rules):
    assert model_axis_size_ctx() == 2
    y = jax.jit(lambda v: constrain(v, "btv") * 1.0)(x)
# vocab dim sharded over the 2-way model axis
import numpy as np
np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
shards = {s.device for s in y.addressable_shards}
assert len(shards) == 2
print("CONSTRAIN OK")
""")
    assert "CONSTRAIN OK" in out
