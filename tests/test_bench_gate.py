"""benchmarks/check_regression.py gate semantics: disappeared baseline
rows and empty comparable sets must WARN explicitly (an empty per-family
row set is not a pass), regressions must fail, shifts must not."""
import json
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
GATE = ROOT / "benchmarks" / "check_regression.py"


def _rows(named_us):
    return [{"suite": "pipeline", "name": n, "us_per_call": us}
            for n, us in named_us.items()]


def run_gate(tmp_path, base, fresh, extra=()):
    b = tmp_path / "base.json"
    f = tmp_path / "fresh.json"
    b.write_text(json.dumps(_rows(base)))
    f.write_text(json.dumps(_rows(fresh)))
    out = subprocess.run(
        [sys.executable, str(GATE), "--baseline", str(b), "--fresh", str(f),
         *extra], capture_output=True, text=True, cwd=ROOT)
    return out.returncode, out.stdout + out.stderr


def test_disappeared_baseline_row_warns(tmp_path):
    code, out = run_gate(
        tmp_path,
        {"family_dense": 1000.0, "family_moe": 1000.0},
        {"family_dense": 1000.0})
    assert code == 0
    assert "DISAPPEARED" in out and "family_moe" in out


def test_empty_comparable_set_warns_verified_nothing(tmp_path):
    """Every per-family baseline row vanished: the gate exits 0 (rows on
    one side are informational by design) but must say it checked
    NOTHING, not print a green 'rows within tolerance' line."""
    code, out = run_gate(
        tmp_path,
        {"family_dense": 1000.0, "family_moe": 1000.0},
        {"family_renamed": 1000.0})
    assert code == 0
    assert "verified nothing" in out
    assert "DISAPPEARED" in out
    assert "gate OK" not in out


def test_empty_baseline_content_warns(tmp_path):
    """A baseline FILE that parses to zero timed rows (truncated regen)
    must warn and suppress the green OK line, like the disappeared case."""
    code, out = run_gate(tmp_path, {}, {"family_dense": 1000.0})
    assert code == 0
    assert "verified nothing" in out
    assert "gate OK" not in out


def test_regression_still_fails(tmp_path):
    code, out = run_gate(
        tmp_path,
        {"family_dense": 1000.0, "family_moe": 1000.0},
        {"family_dense": 1000.0, "family_moe": 2000.0})
    assert code == 1
    assert "family_moe" in out


def test_uniform_shift_passes(tmp_path):
    code, out = run_gate(
        tmp_path,
        {"family_dense": 1000.0, "family_moe": 1000.0},
        {"family_dense": 1900.0, "family_moe": 2000.0})
    assert code == 0, out
