"""benchmarks/check_regression.py gate semantics: disappeared baseline
rows and empty comparable sets must WARN explicitly (an empty per-family
row set is not a pass), regressions must fail, shifts must not."""
import json
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
GATE = ROOT / "benchmarks" / "check_regression.py"


def _rows(named_us):
    return [{"suite": "pipeline", "name": n, "us_per_call": us}
            for n, us in named_us.items()]


def run_gate(tmp_path, base, fresh, extra=()):
    b = tmp_path / "base.json"
    f = tmp_path / "fresh.json"
    b.write_text(json.dumps(_rows(base)))
    f.write_text(json.dumps(_rows(fresh)))
    out = subprocess.run(
        [sys.executable, str(GATE), "--baseline", str(b), "--fresh", str(f),
         *extra], capture_output=True, text=True, cwd=ROOT)
    return out.returncode, out.stdout + out.stderr


def test_disappeared_baseline_row_warns(tmp_path):
    code, out = run_gate(
        tmp_path,
        {"family_dense": 1000.0, "family_moe": 1000.0},
        {"family_dense": 1000.0})
    assert code == 0
    assert "DISAPPEARED" in out and "family_moe" in out


def test_empty_comparable_set_warns_verified_nothing(tmp_path):
    """Every per-family baseline row vanished: the gate exits 0 (rows on
    one side are informational by design) but must say it checked
    NOTHING, not print a green 'rows within tolerance' line."""
    code, out = run_gate(
        tmp_path,
        {"family_dense": 1000.0, "family_moe": 1000.0},
        {"family_renamed": 1000.0})
    assert code == 0
    assert "verified nothing" in out
    assert "DISAPPEARED" in out
    assert "gate OK" not in out


def test_empty_baseline_content_warns(tmp_path):
    """A baseline FILE that parses to zero timed rows (truncated regen)
    must warn and suppress the green OK line, like the disappeared case."""
    code, out = run_gate(tmp_path, {}, {"family_dense": 1000.0})
    assert code == 0
    assert "verified nothing" in out
    assert "gate OK" not in out


def test_regression_still_fails(tmp_path):
    code, out = run_gate(
        tmp_path,
        {"family_dense": 1000.0, "family_moe": 1000.0},
        {"family_dense": 1000.0, "family_moe": 2000.0})
    assert code == 1
    assert "family_moe" in out


def test_uniform_shift_passes(tmp_path):
    code, out = run_gate(
        tmp_path,
        {"family_dense": 1000.0, "family_moe": 1000.0},
        {"family_dense": 1900.0, "family_moe": 2000.0})
    assert code == 0, out


def test_zero_baseline_row_skipped_even_at_min_us_zero(tmp_path):
    """Non-timing rows (speedup / hlo-fraction / transport-decision rows
    use us_per_call 0.0 by convention) must never enter the ratio math:
    with --min-us 0 a 0.0 baseline used to divide by zero."""
    code, out = run_gate(
        tmp_path,
        {"step_walltime_on": 1000.0, "transport_auto_64kb": 0.0},
        {"step_walltime_on": 1000.0, "transport_auto_64kb": 0.0},
        extra=("--min-us", "0"))
    assert code == 0, out
    assert "Traceback" not in out and "ZeroDivisionError" not in out
    assert "1 rows within" in out      # only the timed row was compared


# ---------------------------------------------------------------------------
# check_overlap_speedup.py: the hard overlap=on speedup gate
# ---------------------------------------------------------------------------

SPEEDUP_GATE = ROOT / "benchmarks" / "check_overlap_speedup.py"


def run_speedup_gate(tmp_path, rows, extra=()):
    f = tmp_path / "fresh.json"
    f.write_text(json.dumps(rows))
    out = subprocess.run(
        [sys.executable, str(SPEEDUP_GATE), "--fresh", str(f), *extra],
        capture_output=True, text=True, cwd=ROOT)
    return out.returncode, out.stdout + out.stderr


def _on_row(speedup, n_devices=4):
    return {"suite": "overlap", "name": "overlap/step_walltime_on",
            "us_per_call": 1000.0, "speedup": speedup,
            "n_devices": n_devices}


def test_speedup_gate_passes_on_win(tmp_path):
    code, out = run_speedup_gate(tmp_path, [_on_row(1.12)])
    assert code == 0, out
    assert "gate OK" in out


def test_speedup_gate_fails_on_measured_slowdown(tmp_path):
    """The 0.87x regression this PR fixes must FAIL the gate loudly."""
    code, out = run_speedup_gate(tmp_path, [_on_row(0.87)])
    assert code == 1
    assert "measured slowdown" in out


def test_speedup_gate_warn_only_below_min_devices(tmp_path):
    """Single-device CI shards cannot measure the transport tradeoff: the
    gate records the number but does not fail."""
    code, out = run_speedup_gate(tmp_path, [_on_row(0.5, n_devices=1)])
    assert code == 0, out
    assert "NOT gated" in out


def test_speedup_gate_missing_row_is_an_error(tmp_path):
    """A fresh file without the gated row must not read as a pass."""
    code, out = run_speedup_gate(
        tmp_path, [{"suite": "overlap", "name": "overlap/step_walltime_off",
                    "us_per_call": 1000.0, "n_devices": 4}])
    assert code == 1
    assert "step_walltime_on" in out


def test_speedup_gate_env_override(tmp_path, monkeypatch):
    f = tmp_path / "fresh.json"
    f.write_text(json.dumps([_on_row(1.05)]))
    out = subprocess.run(
        [sys.executable, str(SPEEDUP_GATE), "--fresh", str(f)],
        capture_output=True, text=True, cwd=ROOT,
        env={**dict(__import__("os").environ),
             "REPRO_OVERLAP_MIN_SPEEDUP": "1.5"})
    assert out.returncode == 1
    assert "x1.50" in out.stdout
