"""§Perf options are function-preserving (subprocess tests on small meshes).

Each option changes sharding/layout/scheduling, never math:
  pad_heads      — dead-head allocation, masked wo (exact)
  seq_parallel   — residual-stream constraint only (exact)
  moe_rowcombine — shard_map expert path == pjit expert path (exact)
  ce_bf16        — bf16 CE head (approximate: loss tolerance)
"""
import os
import pathlib
import subprocess
import sys
import textwrap


ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 2, timeout=600):
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT/'src'}:{ROOT/'tests'}",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import AxisType
from repro.dist.api import (perf_options_ctx, activation_sharding_ctx,
                            make_default_rules)
from repro.models import lm
from test_models import tiny, make_batch
jax.config.update("jax_default_matmul_precision", "highest")

def loss_with(cfg, params, batch, opts, seq_parallel=False, mesh_shape=(1, 2)):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    rules = make_default_rules(("data",), seq_parallel=seq_parallel)
    with jax.set_mesh(mesh), activation_sharding_ctx(rules), \\
            perf_options_ctx(set(opts)):
        loss, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(params, batch)
    return float(loss)
"""


def test_seq_parallel_exact():
    out = run_py(COMMON + """
cfg = tiny("dense")
params = lm.init_params(jax.random.key(0), cfg)
batch = make_batch(cfg, t=32)
base = float(lm.loss_fn(params, cfg, batch)[0])
sp = loss_with(cfg, params, batch, {"seq_parallel"}, seq_parallel=True)
print("DELTA", abs(base - sp))
assert abs(base - sp) < 1e-5, (base, sp)
""")
    assert "DELTA" in out


def test_pad_heads_exact():
    out = run_py(COMMON + """
import numpy as np
cfg = tiny("dense")          # 4 heads, kv=2
cfgp = dataclasses.replace(cfg, padded_heads=8)   # pad groups 2->4
params = lm.init_params(jax.random.key(0), cfg)
pp = lm.init_params(jax.random.key(1), cfgp)
# copy live weights into the padded layout (group-wise)
def pad_q(w):
    w4 = np.asarray(w).reshape(w.shape[0], w.shape[1], 2, 2, -1)
    out = np.zeros(w4.shape[:2] + (2, 4, w4.shape[-1]), np.float32)
    out[..., :2, :] = w4
    return jnp.asarray(out.reshape(w.shape[0], w.shape[1], 8, -1))
def pad_o(w):
    w4 = np.asarray(w).reshape(w.shape[0], 2, 2, w.shape[-2], w.shape[-1])
    out = np.zeros((w.shape[0], 2, 4) + w4.shape[-2:], np.float32)
    out[:, :, :2] = w4
    return jnp.asarray(out.reshape(w.shape[0], 8, w.shape[-2], w.shape[-1]))
blocks = dict(pp["blocks"]); attn = dict(params["blocks"]["attn"])
attn["wq"] = pad_q(params["blocks"]["attn"]["wq"])
attn["wo"] = pad_o(params["blocks"]["attn"]["wo"])
if "bq" in attn:
    b3 = np.asarray(params["blocks"]["attn"]["bq"]).reshape(
        params["blocks"]["attn"]["bq"].shape[0], 2, 2, -1)
    out = np.zeros((b3.shape[0], 2, 4, b3.shape[-1]), np.float32)
    out[:, :, :2] = b3
    attn["bq"] = jnp.asarray(out.reshape(b3.shape[0], 8, -1))
padded_params = {**params, "blocks": {**params["blocks"], "attn": attn}}
batch = make_batch(cfg, t=32)
base = float(lm.loss_fn(params, cfg, batch)[0])
pad = float(lm.loss_fn(padded_params, cfgp, batch)[0])
print("DELTA", abs(base - pad))
assert abs(base - pad) < 1e-5, (base, pad)
""")
    assert "DELTA" in out


def test_moe_rowcombine_exact_both_branches():
    out = run_py(COMMON + """
from repro.models import layers as L
for name, kw in [("EP", {}), ("Fsharded", {"num_experts": 3,
                                           "experts_per_token": 2})]:
    cfg = tiny("moe", **kw)
    p = L.init_moe(jax.random.key(7), cfg)
    x = jax.random.normal(jax.random.key(8), (2, 16, cfg.d_model))
    base, _ = L.moe(p, x, cfg)
    mesh = jax.make_mesh((1, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    rules = make_default_rules(("data",))
    with jax.set_mesh(mesh), activation_sharding_ctx(rules), \\
            perf_options_ctx({"moe_rowcombine"}):
        opt, _ = jax.jit(lambda p_, x_: L.moe(p_, x_, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt),
                               atol=2e-5, rtol=2e-5)
    print(name, "OK")
""")
    assert "EP OK" in out and "Fsharded OK" in out


def test_moe_rowcombine_gradients_match():
    """The shard_map expert path must be differentiable and match pjit
    gradients (it sits inside the TaxoNN engine's per-layer VJP)."""
    out = run_py(COMMON + """
from repro.models import layers as L
cfg = tiny("moe")
p = L.init_moe(jax.random.key(7), cfg)
x = jax.random.normal(jax.random.key(8), (2, 16, cfg.d_model))

def loss(p_, x_):
    out, aux = L.moe(p_, x_, cfg)
    return jnp.sum(out ** 2) + aux

g_base = jax.grad(loss, argnums=(0, 1))(p, x)
mesh = jax.make_mesh((1, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
rules = make_default_rules(("data",))
with jax.set_mesh(mesh), activation_sharding_ctx(rules), \\
        perf_options_ctx({"moe_rowcombine"}):
    g_opt = jax.jit(jax.grad(loss, argnums=(0, 1)))(p, x)
for a, b in zip(jax.tree.leaves(g_base), jax.tree.leaves(g_opt)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=5e-5, rtol=5e-4)
print("GRADS OK")
""")
    assert "GRADS OK" in out


def test_ce_bf16_close():
    out = run_py(COMMON + """
cfg = tiny("dense")
params = lm.init_params(jax.random.key(0), cfg)
batch = make_batch(cfg, t=32)
base = float(lm.loss_fn(params, cfg, batch)[0])
with perf_options_ctx({"ce_bf16"}):
    approx = float(lm.loss_fn(params, cfg, batch)[0])
print("DELTA", abs(base - approx))
assert abs(base - approx) < 0.03 * abs(base), (base, approx)
""", devices=1)
    assert "DELTA" in out


def test_dryrun_machinery_small_mesh():
    """End-to-end dryrun cell on an in-process 8-device mesh: lower, compile,
    roofline-extract — the exact machinery behind results/dryrun/."""
    out = run_py("""
import os, json, pathlib, tempfile
import repro.launch.mesh as mesh_mod
import jax
from jax.sharding import AxisType

# shrink the production mesh for the 8-device test process
mesh_mod.make_production_mesh = lambda multi_pod=False: (
    jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                  axis_types=(AxisType.Auto,) * 3) if multi_pod else
    jax.make_mesh((4, 2), ("data", "model"),
                  axis_types=(AxisType.Auto,) * 2))
import repro.launch.dryrun as dr
dr.make_production_mesh = mesh_mod.make_production_mesh
import repro.configs as C
import dataclasses
real_get = C.get_config
def small_get(name):
    cfg = real_get(name)
    return dataclasses.replace(cfg, num_layers=4, d_model=64, num_heads=4,
                               num_kv_heads=4, head_dim=16, d_ff=128,
                               vocab_size=256, compute_dtype="float32")
dr.get_config = small_get
import repro.models.config as MC
cell = MC.ShapeCell("train_4k", 64, 8, "train")
MC.SHAPES_BY_NAME["train_4k"] = cell
dr.SHAPES_BY_NAME = MC.SHAPES_BY_NAME

with tempfile.TemporaryDirectory() as d:
    for multi in (False, True):
        rec = dr.run_cell("qwen1.5-0.5b", "train_4k", multi, pathlib.Path(d),
                          verbose=False)
        assert rec["status"] == "ok", rec.get("error")
        t = rec["cost"]["terms"]
        assert t["compute_s"] > 0 and t["memory_s"] > 0
        assert rec["useful_flops_ratio"] > 0
        print("MESH", rec["mesh"], "dominant", t["dominant"])
print("DRYRUN OK")
""", devices=8, timeout=900)
    assert "DRYRUN OK" in out
