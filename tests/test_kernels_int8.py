"""int8 MXU datapath validation: the quant.int8 format mapping, the int8
kernels vs their jnp oracles (property sweeps across shapes, bitwidths and
activations — including non-128-divisible shapes through the autotuner's
ref fallback), and the fused TDM frame vs the sequential kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.bp_fused_unit import bp_fused_unit
from repro.kernels.bp_gstep import bp_gstep
from repro.kernels.sgd_dw_update import sgd_dw_update
from repro.kernels.ops import (bp_fused_unit_op, bp_gstep_op, fxp_matmul_op,
                               sgd_dw_update_op, tune_blocks, tune_fused)
from repro.quant.int8 import (int8_spec, quantize_int8_auto,
                              quantize_int8_fxp, quantize_int8_tiles,
                              transport_bits)
from repro.quant.fixed_point import quantize

jax.config.update("jax_default_matmul_precision", "highest")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.key(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Format mapping
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(bits=st.tuples(st.integers(1, 4), st.integers(1, 10)),
       seed=st.integers(0, 1000))
def test_narrow_formats_embed_exactly(bits, seed):
    """(I,F) with bitwidth <= 8: int8 payload * scale == kq(x) exactly."""
    i, f = bits
    if i + f + 1 > 8:
        f = 8 - 1 - i
    x = rand(seed, (64,), scale=4.0)
    q, s = quantize_int8_fxp(x, i, f)
    np.testing.assert_array_equal(
        np.asarray(q.astype(jnp.float32) * s), np.asarray(quantize(x, i, f)))


def test_wide_format_drops_low_bits():
    spec = int8_spec(2, 12)  # 15-bit format -> shift 7
    assert spec.shift == 7 and not spec.exact
    assert spec.scale == 2.0 ** -5
    assert (spec.qmin, spec.qmax) == (-128, 127)
    # transport rule: wide formats travel absmax-scaled instead
    assert transport_bits((2, 12)) is None
    assert transport_bits((3, 4)) == (3, 4)
    assert transport_bits(None) is None


@settings(max_examples=20, deadline=None)
@given(r=st.integers(1, 40), c=st.integers(1, 40), seed=st.integers(0, 99))
def test_tiled_storage_roundtrip(r, c, seed):
    x = rand(seed, (r, c), scale=3.0)
    t = quantize_int8_tiles(x, tile=(16, 16))
    assert t.payload.dtype == jnp.int8
    y = np.asarray(t.dequantize())
    assert y.shape == (r, c)
    # absmax per tile: error <= absmax/127/2 per element, absmax <= global
    tol = float(jnp.max(jnp.abs(x))) / 127.0 * 0.5 + 1e-7
    assert np.max(np.abs(y - np.asarray(x))) <= tol


def test_tiled_storage_format_grid():
    """With a narrow (I,F), in-range tiles sit on the exact format grid."""
    x = jnp.asarray([[0.25, -0.5], [1.0, -1.25]], jnp.float32)
    t = quantize_int8_tiles(x, 2, 4, tile=(2, 2))  # (2,4): step 1/16, max ~4
    np.testing.assert_array_equal(np.asarray(t.dequantize()), np.asarray(x))


# ---------------------------------------------------------------------------
# int8 kernels vs int8 oracles (property sweeps)
# ---------------------------------------------------------------------------

ACTS = ["identity", "relu", "sigmoid", "tanh", "silu", "gelu"]


@settings(max_examples=12, deadline=None)
@given(
    mexp=st.integers(3, 5), kexp=st.integers(3, 5), nexp=st.integers(3, 5),
    ibits=st.integers(1, 5), fbits=st.integers(2, 12),
    seed=st.integers(0, 1000),
)
def test_fxp_matmul_int8_property(mexp, kexp, nexp, ibits, fbits, seed):
    m, k, n = 2 ** mexp, 2 ** kexp, 2 ** nexp
    act = ACTS[seed % len(ACTS)]
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n), scale=0.5)
    bits = (ibits, fbits)
    got = fxp_matmul_op(x, w, xa_bits=bits, w_bits=bits, out_bits=None,
                        act=act, datapath="int8")
    want = ref.fxp_matmul_int8_ref(x, w, xa_bits=bits, w_bits=bits,
                                   out_bits=None, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(1, 64), din=st.integers(1, 48), dout=st.integers(1, 48),
    seed=st.integers(0, 1000),
)
def test_int8_ops_any_shape(t, din, dout, seed):
    """Arbitrary (incl. odd / non-128-divisible) shapes: wrappers must agree
    with the oracle either through the kernel or the ref fallback."""
    g = rand(seed, (t, dout), scale=0.5)
    w = rand(seed + 1, (din, dout))
    z = rand(seed + 2, (t, din), scale=2.0)
    x = rand(seed + 3, (t, din))
    # jit-vs-eager f32 rescale reorders can flip a .5-ulp tie of the (2,12)
    # output grid -> tolerance of one output-resolution step
    got = bp_gstep_op(g, w, z, datapath="int8")
    want = ref.bp_gstep_int8_ref(g, w, z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2.0 ** -12 + 1e-6, rtol=1e-5)
    got = sgd_dw_update_op(x, g, w, 0.05, datapath="int8")
    want = ref.sgd_dw_update_int8_ref(x, g, w, 0.05)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("act", ["relu", "tanh"])
@pytest.mark.parametrize("t,din,dout,bm,bn,bk", [
    (16, 24, 32, 8, 8, 16),
    (32, 16, 16, 16, 16, 8),
])
def test_bp_gstep_int8_blocks(act, t, din, dout, bm, bn, bk):
    """Direct kernel call (explicit blocks) on the int8 datapath."""
    g = rand(7, (t, dout), scale=0.5)
    w = rand(8, (din, dout))
    z = rand(9, (t, din), scale=2.0)
    qg, sg = quantize_int8_auto(g, (2, 5))
    qw, sw = quantize_int8_auto(w, (2, 5))
    got = bp_gstep(qg, qw, z, g_bits=None, act=act, bm=bm, bn=bn, bk=bk,
                   datapath="int8", scale=sg * sw, interpret=True)
    want = ref.bp_gstep_int8_ref(g, w, z, g_in_bits=(2, 5), w_bits=(2, 5),
                                 g_bits=None, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_sgd_dw_update_dw_only_mode():
    """w=None returns the raw outer product (the custom_vjp dW form)."""
    x = rand(13, (32, 24))
    g = rand(14, (32, 16), scale=0.1)
    got = sgd_dw_update(x, g, None, 0.0, bm=8, bn=8, bk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x.T @ g),
                               atol=1e-5, rtol=1e-5)
    qx, sx = quantize_int8_auto(x, None)
    qg, sg = quantize_int8_auto(g, None)
    got8 = sgd_dw_update(qx, qg, None, 0.0, bm=8, bn=8, bk=8,
                         datapath="int8", scale=sx * sg, interpret=True)
    want8 = ref.sgd_dw_update_int8_ref(x, g, None, 0.0, xa_bits=None,
                                       g_in_bits=None)
    np.testing.assert_allclose(np.asarray(got8), np.asarray(want8),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# bp_fused_unit: the TDM frame vs the sequential kernels
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    texp=st.integers(3, 6), din=st.integers(2, 6), dout=st.integers(2, 6),
    seed=st.integers(0, 1000),
)
def test_bp_fused_unit_matches_sequential(texp, din, dout, seed):
    """The one-pass frame == bp_gstep + sgd_dw_update run sequentially."""
    t, din, dout = 2 ** texp, 8 * din, 8 * dout
    g = rand(seed, (t, dout), scale=0.3)
    w = rand(seed + 1, (din, dout))
    x = rand(seed + 2, (t, din))
    z = rand(seed + 3, (t, din), scale=2.0)
    g_bits, w_bits = (2, 12), (2, 12)

    go, wn = bp_fused_unit(g, w, x, z, 0.05, g_bits=g_bits, w_bits=w_bits,
                           bt=min(t, 16), interpret=True)
    # sequential: Eq. 8 against q_w(W), then Eq. 9 + Eq. 1 on the master
    from repro.kernels.common import kq
    want_go = ref.bp_gstep_ref(g, kq(w, *w_bits), z, g_bits=g_bits,
                               act="relu")
    want_wn = ref.sgd_dw_update_ref(x, g, w, 0.05, w_bits=None)
    np.testing.assert_allclose(np.asarray(go), np.asarray(want_go),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(want_wn),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("act", ["relu", "silu"])
def test_bp_fused_unit_int8(act):
    t, din, dout = 32, 24, 16
    g = rand(20, (t, dout), scale=0.3)
    w = rand(21, (din, dout))
    x = rand(22, (t, din))
    z = rand(23, (t, din), scale=2.0)
    go, wn = bp_fused_unit_op(g, w, x, z, 0.05, act=act, datapath="int8")
    want_go, want_wn = ref.bp_fused_unit_int8_ref(g, w, x, z, 0.05, act=act)
    np.testing.assert_allclose(np.asarray(go), np.asarray(want_go),
                               atol=2.0 ** -12 + 1e-6, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(want_wn),
                               atol=1e-6, rtol=1e-6)


def test_bp_fused_unit_odd_shape_falls_back():
    """Odd token count: the op must fall back to the jnp frame, same math."""
    t, din, dout = 17, 24, 16
    g = rand(24, (t, dout), scale=0.3)
    w = rand(25, (din, dout))
    x = rand(26, (t, din))
    z = rand(27, (t, din), scale=2.0)
    assert tune_fused(t, din, dout) is None
    go, wn = bp_fused_unit_op(g, w, x, z, 0.05)
    want_go, want_wn = ref.bp_fused_unit_ref(g, w, x, z, 0.05)
    np.testing.assert_allclose(np.asarray(go), np.asarray(want_go),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(want_wn),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------

def test_tuner_rejects_untileable_dims():
    assert tune_blocks(17, 9, 23) is None      # primes/odd: no aligned block
    assert tune_blocks(12, 16, 16) is None     # 12 has no multiple-of-8 divisor
    assert tune_fused(33, 48, 16) is None


def test_tuner_prefers_mxu_alignment():
    bm, bn, bk = tune_blocks(256, 256, 256)
    assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0


def test_tuner_respects_vmem_budget():
    from repro.kernels.ops import VMEM_BUDGET_BYTES
    bm, bn, bk = tune_blocks(4096, 4096, 4096)
    assert (2 * (bm * bk + bk * bn) * 4 + bm * bn * 8) <= VMEM_BUDGET_BYTES
    # full-dim blocks on small shapes (single launch, exact ref numerics)
    assert tune_blocks(32, 16, 48) == (32, 16, 48)


def test_tuner_is_cached():
    a = tune_blocks(640, 384, 512)
    b = tune_blocks(640, 384, 512)
    assert a is b  # lru_cache identity


def test_no_degenerate_one_wide_blocks():
    """The old _pick degraded odd dims to 1-wide blocks; the tuner must
    never emit a block below the 8-sublane alignment."""
    for dims in [(24, 40, 56), (8, 8, 8), (2048, 8, 136)]:
        blocks = tune_blocks(*dims)
        assert blocks is not None
        assert all(b >= 8 for b in blocks), (dims, blocks)
