"""Property tests for the fixed-point (I,F) quantizers.

Runs under real hypothesis when installed, else the vendored
deterministic fallback (tests/_vendor/hypothesis.py — see conftest.py).
Each property is the algebraic contract the search/anneal/export
subsystem builds on:

  * idempotence — a value already on the (I,F) grid is a fixed point of
    ``quantize`` (the sweep re-quantizes cached activations freely);
  * saturation — out-of-range values clip to exactly +/- the format
    bounds (the export path's int8 embedding assumes the same clip);
  * STE — forward equals ``quantize``, backward passes gradients through
    in-range inputs and masks saturated ones;
  * stochastic rounding — per-row batched draws are mean-unbiased within
    a seeded tolerance (what keeps low-F gradient descent convergent).
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.quant.fixed_point import (fxp_max, fxp_resolution, quantize,
                                     quantize_ste, stochastic_round_batched)

jax.config.update("jax_default_matmul_precision", "highest")

BITS = st.tuples(st.integers(1, 4), st.integers(2, 12))  # (I, F)


@settings(max_examples=30, deadline=None)
@given(bits=BITS, k=st.integers(-1024, 1023))
def test_quantize_idempotent_on_grid(bits, k):
    i_b, f_b = bits
    # clamp k into the format's integer range so x starts ON the grid
    lo, hi = -(2 ** (i_b + f_b)), 2 ** (i_b + f_b) - 1
    k = int(np.clip(k, lo, hi))
    x = jnp.float32(k) * fxp_resolution(f_b)
    q = quantize(x, i_b, f_b)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x))
    # and quantize o quantize == quantize for arbitrary inputs
    y = jnp.float32(k) * 0.137
    np.testing.assert_array_equal(
        np.asarray(quantize(quantize(y, i_b, f_b), i_b, f_b)),
        np.asarray(quantize(y, i_b, f_b)))


@settings(max_examples=30, deadline=None)
@given(bits=BITS, mag=st.floats(1.0, 100.0, width=32))
def test_quantize_saturates_at_fxp_max(bits, mag):
    i_b, f_b = bits
    bound = float(fxp_max(i_b, f_b))
    step = float(fxp_resolution(f_b))
    x = jnp.float32(bound + mag)  # beyond the positive edge
    np.testing.assert_allclose(float(quantize(x, i_b, f_b)), bound, rtol=0)
    # negative side clips one step lower (two's-complement asymmetry)
    np.testing.assert_allclose(float(quantize(-x, i_b, f_b)),
                               -(bound + step), rtol=0)


@settings(max_examples=30, deadline=None)
@given(bits=BITS, x=st.floats(-40.0, 40.0, width=32))
def test_ste_forward_matches_quantize(bits, x):
    i_b, f_b = bits
    xj = jnp.float32(x)
    np.testing.assert_array_equal(
        np.asarray(quantize_ste(xj, jnp.int32(i_b), jnp.int32(f_b))),
        np.asarray(quantize(xj, i_b, f_b)))


@settings(max_examples=30, deadline=None)
@given(bits=BITS, x=st.floats(-40.0, 40.0, width=32))
def test_ste_gradient_passthrough_and_mask(bits, x):
    i_b, f_b = bits
    xj = jnp.float32(x)
    g = jax.grad(
        lambda v: jnp.sum(quantize_ste(v, jnp.int32(i_b), jnp.int32(f_b))))(xj)
    in_range = abs(x) <= float(fxp_max(i_b, f_b))
    np.testing.assert_array_equal(np.asarray(g),
                                  np.float32(1.0 if in_range else 0.0))


@settings(max_examples=10, deadline=None)
@given(bits=st.tuples(st.integers(2, 4), st.integers(3, 8)),
       seed=st.integers(0, 1000))
def test_stochastic_round_batched_mean_unbiased(bits, seed):
    i_b, f_b = bits
    # a value mid-way between grid points, repeated across many rows:
    # E[q(x)] = x for in-range x, so the per-row mean converges on x
    step = float(fxp_resolution(f_b))
    x_val = 0.5 + 0.3 * step
    rows = 4096
    x = jnp.full((rows, 4), x_val, jnp.float32)
    q = stochastic_round_batched(x, jnp.int32(i_b), jnp.int32(f_b),
                                 jax.random.key(seed), 0)
    # each draw is one of the two neighbours
    lo, hi = np.floor(x_val / step) * step, np.ceil(x_val / step) * step
    vals = np.unique(np.asarray(q))
    assert all(np.isclose(v, lo, atol=1e-6) or np.isclose(v, hi, atol=1e-6)
               for v in vals), vals
    # mean unbiasedness: SE of the mean is step/2/sqrt(n); allow 5 sigma
    tol = 5 * step / 2 / np.sqrt(rows * 4)
    assert abs(float(jnp.mean(q)) - x_val) < tol


@settings(max_examples=10, deadline=None)
@given(bits=st.tuples(st.integers(2, 4), st.integers(3, 8)),
       seed=st.integers(0, 1000))
def test_stochastic_round_batched_slice_reproducible(bits, seed):
    """Slicing the batch and passing the slice's offset reproduces the
    full-batch draws (the pipeline-vs-scan conformance contract)."""
    i_b, f_b = bits
    key = jax.random.key(seed)
    x = jax.random.normal(jax.random.key(seed + 1), (8, 3), jnp.float32)
    full = stochastic_round_batched(x, jnp.int32(i_b), jnp.int32(f_b), key, 0)
    part = stochastic_round_batched(x[3:], jnp.int32(i_b), jnp.int32(f_b),
                                    key, 3)
    np.testing.assert_array_equal(np.asarray(full[3:]), np.asarray(part))
