"""Property tests (hypothesis; vendored fallback in tests/_vendor) for the
``Schedule`` tick tables over random (S, M) pairs.

Three invariants of every schedule's plan:

  1. causality — each (stage, microbatch) unit's forward tick strictly
     precedes its backward tick, forwards flow down the stage axis and
     backwards flow up it;
  2. occupancy — a device never co-issues two forward units or two
     backward units in one tick (the TDM fused frame allows exactly one F
     plus one B per device-tick, which is how 1F1B beats GPipe's bubble);
  3. closed forms — GPipe's span is the two diagonals (2(M+S-1) ticks,
     bubble (S-1)/(M+S-1)) for every (S, M); 1F1B's interleaved diagonals
     span M+2S-1 ticks with bubble (S-1)/(M+2S-1) once the steady state
     exists (M >= 2S-1).
"""
from hypothesis import given, settings, strategies as st

import pytest

from repro.dist.pipeline import (GPipeSchedule, OneFOneBSchedule,
                                 get_schedule)


def _plans(S, M, virtuals=(1, 2, 4)):
    """All schedule plans valid at (S, M), including interleaved ones."""
    plans = [get_schedule("gpipe").plan(S, M),
             get_schedule("1f1b").plan(S, M)]
    for v in virtuals:
        if v > 1 and S % v == 0:
            plans.append(get_schedule("interleaved", num_virtual=v)
                         .plan(S, M))
    return plans


@settings(max_examples=40, deadline=None)
@given(S=st.integers(1, 10), M=st.integers(1, 40))
def test_forward_precedes_backward_and_flows(S, M):
    for plan in _plans(S, M):
        for s in range(plan.num_stages):
            for m in range(plan.num_microbatches):
                f, b = int(plan.fwd_tick[s, m]), int(plan.bwd_tick[s, m])
                assert 0 <= f < b < plan.num_ticks, (plan, s, m)
                if s > 0:
                    assert plan.fwd_tick[s - 1, m] < f
                if s < plan.num_stages - 1:
                    assert plan.bwd_tick[s + 1, m] < b


@settings(max_examples=40, deadline=None)
@given(S=st.integers(1, 10), M=st.integers(1, 40))
def test_device_tick_occupancy_at_most_one(S, M):
    """<= 1 forward and <= 1 backward unit per (device, tick)."""
    for plan in _plans(S, M):
        seen_f, seen_b = set(), set()
        for s in range(plan.num_stages):
            d = plan.stage_device(s)
            for m in range(plan.num_microbatches):
                kf = (d, int(plan.fwd_tick[s, m]))
                kb = (d, int(plan.bwd_tick[s, m]))
                assert kf not in seen_f, (plan.num_virtual, kf)
                assert kb not in seen_b, (plan.num_virtual, kb)
                seen_f.add(kf)
                seen_b.add(kb)


@settings(max_examples=60, deadline=None)
@given(S=st.integers(1, 12), M=st.integers(1, 64))
def test_gpipe_closed_forms(S, M):
    plan = GPipeSchedule().plan(S, M)
    assert plan.num_ticks == 2 * (M + S - 1)
    assert plan.bubble == pytest.approx((S - 1) / (M + S - 1))
    assert plan.peak_activation_microbatches == M


@settings(max_examples=60, deadline=None)
@given(S=st.integers(1, 12), extra=st.integers(0, 48))
def test_1f1b_closed_forms_in_steady_state(S, extra):
    """With M >= 2S-1 the 1F1B diagonals reach steady state: span M+2S-1
    ticks, bubble (S-1)/(M+2S-1), peak activations min(M, 2S-1)."""
    M = 2 * S - 1 + extra
    plan = OneFOneBSchedule().plan(S, M)
    assert plan.num_ticks == M + 2 * S - 1
    assert plan.bubble == pytest.approx((S - 1) / (M + 2 * S - 1))
    assert plan.peak_activation_microbatches == min(M, 2 * S - 1)


@settings(max_examples=30, deadline=None)
@given(S=st.integers(2, 10), M=st.integers(1, 40))
def test_tick_counts_consistent_with_bubble(S, M):
    """bubble == 1 - busy/(ticks * devices) exactly, for every plan: the
    tick count and the bubble fraction are two views of one table."""
    for plan in _plans(S, M):
        assert plan.bubble == pytest.approx(
            1.0 - plan.busy_slots / (plan.num_ticks * plan.num_devices))
        assert 0.0 <= plan.bubble < 1.0
