"""Minimal deterministic stand-in for the ``hypothesis`` property-testing
library, used ONLY when the real package is not installed (see
tests/conftest.py — the container for this repo does not ship hypothesis
and the toolchain is pinned, so vendoring a fallback keeps the property
tests executing instead of skipping).

Implements the tiny surface the test-suite uses:

    @settings(max_examples=N, deadline=None)
    @given(x=st.integers(a, b), y=st.floats(a, b, width=32),
           z=st.lists(elem, min_size=a, max_size=b), w=st.tuples(...))

Each test runs ``max_examples`` times on a per-test deterministic RNG
(seeded from the test name), with the first examples biased to interval
boundaries.  Failures report the generated arguments like hypothesis does.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

__version__ = "0.0-repro-vendored"


class _Strategy:
    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self._boundaries = tuple(boundaries)

    def example(self, rng, index: int):
        if index < len(self._boundaries):
            return self._boundaries[index]
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            boundaries=(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float, width: int = 64,
               **_kw) -> _Strategy:
        cast = np.float32 if width == 32 else np.float64

        def draw(rng):
            return float(cast(rng.uniform(min_value, max_value)))

        bounds = [float(cast(min_value)), float(cast(max_value))]
        if min_value <= 0.0 <= max_value:
            bounds.append(0.0)
        return _Strategy(draw, boundaries=bounds)

    @staticmethod
    def tuples(*strats: _Strategy) -> _Strategy:
        def draw(rng):
            return tuple(s._draw(rng) for s in strats)

        bounds = []
        if all(s._boundaries for s in strats):
            bounds = [tuple(s._boundaries[0] for s in strats),
                      tuple(s._boundaries[-1] for s in strats)]
        return _Strategy(draw, boundaries=bounds)

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements._draw(rng) for _ in range(n)]

        bounds = []
        if min_size >= 1:  # boundary lists must respect min_size
            bounds = [[b] * min_size for b in elements._boundaries]
        return _Strategy(draw, boundaries=bounds)


st = strategies


class settings:
    def __init__(self, max_examples: int = 100, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._hyp_max_examples = self.max_examples
        return fn


def given(**named_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", 100)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = {name: strat.example(rng, i)
                         for name, strat in named_strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} falsified on example {i}: "
                        f"{drawn!r}") from e

        # pytest must not see the property arguments as fixtures
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorate


__all__ = ["given", "settings", "strategies", "st"]
