"""StepOptions + the deprecation shims of the PR-8 API redesign.

``make_train_step`` consolidated seven per-knob keywords into one frozen
``StepOptions`` value; ``BatchScheduler`` replaced its positional callable
triple with (ServeConfig, EngineHooks).  Both old surfaces must keep
working — through adapters that emit DeprecationWarnings — and the old
``eos_id=-1`` sentinel must warn and map to an explicit ``None``.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.models import lm
from repro.optim import Hyper, OptimizerConfig
from repro.serving import BatchScheduler, EngineHooks, Request, ServeConfig
from test_models import make_batch, tiny


# ---------------------------------------------------------------------------
# StepOptions the value
# ---------------------------------------------------------------------------

def test_step_options_validation():
    with pytest.raises(ValueError, match="engine"):
        StepOptions(engine="magic")
    with pytest.raises(ValueError, match="kernel_backend"):
        StepOptions(kernel_backend="fpga")
    with pytest.raises(ValueError, match="overlap"):
        StepOptions(overlap="sometimes")
    with pytest.raises(ValueError, match="transport"):
        StepOptions(transport="smoke-signal")


def test_step_options_from_policy_and_replace():
    pol = QuantPolicy(kernel_backend="emulate", overlap="on",
                      dw_transport="psum")
    opts = StepOptions.from_policy(pol)
    assert (opts.kernel_backend, opts.overlap, opts.transport) == \
        ("emulate", "on", "psum")
    over = StepOptions.from_policy(pol, transport="ring", engine="autodiff")
    assert over.transport == "ring" and over.engine == "autodiff"
    assert over.overlap == "on"
    rep = opts.replace(overlap="off")
    assert rep.overlap == "off" and opts.overlap == "on"  # frozen original


def _train_one(step_builder):
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    ocfg = OptimizerConfig()
    step = jax.jit(step_builder(cfg, ocfg))
    p, o, m = step(params, init_train_state(params, ocfg),
                   make_batch(cfg, t=32),
                   Hyper(lr=jnp.float32(0.01), step=jnp.int32(0)),
                   default_bits(cfg, enabled=False))
    return float(m["loss"])


def test_options_equivalent_to_legacy_kwargs():
    """The same knobs through options= and through the deprecated kwargs
    build identical steps (same loss on the same batch)."""
    loss_opts = _train_one(lambda cfg, ocfg: make_train_step(
        cfg, QuantPolicy.off(), ocfg,
        StepOptions(engine="taxonn", kernel_backend="off")))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        loss_kw = _train_one(lambda cfg, ocfg: make_train_step(
            cfg, QuantPolicy.off(), ocfg, engine="taxonn",
            kernel_backend="off"))
    assert loss_opts == loss_kw


# ---------------------------------------------------------------------------
# The deprecation shims
# ---------------------------------------------------------------------------

def test_legacy_step_kwargs_warn_but_work():
    cfg = tiny("dense")
    with pytest.warns(DeprecationWarning, match="options=StepOptions"):
        step = make_train_step(cfg, QuantPolicy.off(), OptimizerConfig(),
                               engine="autodiff")
    assert callable(step)


def test_legacy_step_kwargs_reject_unknown_and_clash():
    cfg = tiny("dense")
    with pytest.raises(TypeError, match="unexpected keyword"):
        make_train_step(cfg, QuantPolicy.off(), OptimizerConfig(),
                        turbo=True)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="both options="):
            make_train_step(cfg, QuantPolicy.off(), OptimizerConfig(),
                            StepOptions(overlap="on"), overlap="off")


def test_new_step_api_emits_no_warnings():
    cfg = tiny("dense")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        make_train_step(cfg, QuantPolicy.off(), OptimizerConfig(),
                        StepOptions())


# ---------------------------------------------------------------------------
# Scheduler ctor adapter + eos sentinel
# ---------------------------------------------------------------------------

def _contiguous_hooks(cfg, params, num_slots, max_len=32):
    sc = ServeConfig(num_slots=num_slots, eos_id=None, max_len=max_len,
                     mode="contiguous", cache_dtype="float32")
    return EngineHooks.for_model(params, cfg, sc)


def test_legacy_scheduler_ctor_warns_and_runs():
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    h = _contiguous_hooks(cfg, params, 2)
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        sched = BatchScheduler(2, h.prefill, h.decode, h.merge, h.init_state)
    assert sched.eos_id is None          # the -1 sentinel became explicit
    rng = np.random.default_rng(0)
    for i in range(2):
        sched.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32),
            max_new_tokens=4))
    done = sched.run_until_drained()
    assert len(done) == 2 and all(len(r.generated) == 4 for r in done)


def test_eos_sentinel_warns_everywhere():
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    h = _contiguous_hooks(cfg, params, 1)
    with pytest.warns(DeprecationWarning, match="sentinel"):
        BatchScheduler(1, h.prefill, h.decode, h.merge, h.init_state,
                       eos_id=-1)
    with pytest.warns(DeprecationWarning, match="sentinel"):
        sc = ServeConfig(num_slots=1, eos_id=-1, mode="contiguous")
    assert sc.eos_id is None
    # a real eos id passes through the legacy ctor without the sentinel warn
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        BatchScheduler(1, h.prefill, h.decode, h.merge, h.init_state,
                       eos_id=7)
    assert not any("sentinel" in str(w.message) for w in rec)


def test_new_scheduler_api_emits_no_warnings():
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    sc = ServeConfig(num_slots=1, eos_id=None, max_len=32,
                     mode="contiguous", cache_dtype="float32")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        BatchScheduler(sc, EngineHooks.for_model(params, cfg, sc))


def test_serve_config_validation():
    with pytest.raises(ValueError, match="mode"):
        ServeConfig(num_slots=1, eos_id=None, mode="virtual")
    with pytest.raises(ValueError, match="admission"):
        ServeConfig(num_slots=1, eos_id=None, admission="lottery")
    with pytest.raises(ValueError, match="multiple"):
        ServeConfig(num_slots=1, eos_id=None, max_len=60, block_size=8)
    with pytest.raises(ValueError, match="cache_dtype"):
        ServeConfig(num_slots=1, eos_id=None, cache_dtype="fp4")
    sc = ServeConfig(num_slots=3, eos_id=None, max_len=64, block_size=8)
    assert sc.max_blocks_per_seq == 8
    assert sc.resolved_num_blocks == 1 + 3 * (8 + 2)  # +2 COW/admission slack
    assert sc.chunk_tokens == 8
