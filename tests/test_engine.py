"""TaxoNN engine validation: the unrolled G-chain must equal autodiff.

With quantization OFF, one engine step (per-layer fused updates) must produce
exactly the same new parameters as jax.grad + a monolithic SGD update: both
compute all gradients at the step-start weights (Eq. 2-9 ARE the chain rule).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.models import lm
from repro.optim import Hyper, OptimizerConfig

from test_models import tiny, make_batch

jax.config.update("jax_default_matmul_precision", "highest")

FAMILIES = ["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


def run_both(family, optim_kind="sgd", steps=1, lr=0.05):
    cfg = tiny(family)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    ocfg = OptimizerConfig(kind=optim_kind)
    policy = QuantPolicy.off()
    bits = default_bits(cfg, enabled=False)

    tax_step = jax.jit(make_train_step(cfg, policy, ocfg,
                                       StepOptions(engine="taxonn")))
    auto_step = jax.jit(make_train_step(cfg, policy, ocfg,
                                        StepOptions(engine="autodiff")))

    pt, po = params, init_train_state(params, ocfg)
    pa, ao = params, init_train_state(params, ocfg)
    mt = ma = None
    for s in range(steps):
        hyper = Hyper(lr=jnp.float32(lr), step=jnp.int32(s))
        pt, po, mt = tax_step(pt, po, batch, hyper, bits)
        pa, ao, ma = auto_step(pa, ao, batch, hyper, bits)
    return cfg, (pt, mt), (pa, ma)


@pytest.mark.parametrize("family", FAMILIES)
def test_engine_matches_autodiff_sgd(family):
    cfg, (pt, mt), (pa, ma) = run_both(family)
    flat_t = jax.tree_util.tree_leaves_with_path(pt)
    flat_a = {jax.tree_util.keystr(k): v
              for k, v in jax.tree_util.tree_leaves_with_path(pa)}
    for k, v in flat_t:
        ks = jax.tree_util.keystr(k)
        ref = flat_a[ks]
        np.testing.assert_allclose(
            np.asarray(v), np.asarray(ref), atol=2e-5, rtol=2e-4,
            err_msg=f"{family}: param mismatch at {ks}")
    assert float(mt["loss"]) == pytest.approx(float(ma["loss"]), rel=1e-5)
    assert float(mt["grad_norm"]) == pytest.approx(
        float(ma["grad_norm"]), rel=1e-3)


@pytest.mark.parametrize("optim_kind", ["momentum", "adam", "momentum8"])
def test_engine_matches_autodiff_stateful_opt(optim_kind):
    """Multi-step with stateful optimizers: per-layer state slicing in the
    scan must track the monolithic reference."""
    tol = dict(atol=5e-4, rtol=5e-3) if optim_kind == "momentum8" else dict(
        atol=2e-5, rtol=2e-4)
    cfg, (pt, mt), (pa, ma) = run_both("dense", optim_kind, steps=3, lr=0.01)
    flat_a = {jax.tree_util.keystr(k): v
              for k, v in jax.tree_util.tree_leaves_with_path(pa)}
    for k, v in jax.tree_util.tree_leaves_with_path(pt):
        ks = jax.tree_util.keystr(k)
        np.testing.assert_allclose(np.asarray(v), np.asarray(flat_a[ks]),
                                   err_msg=f"{optim_kind}: {ks}", **tol)


def test_quantized_step_runs_and_descends():
    """Quantization ON: the engine must keep training (loss decreases over a
    few steps on a learnable toy task) at paper-scale bitwidths."""
    cfg = tiny("dense", num_layers=3)
    params = lm.init_params(jax.random.key(0), cfg)
    # learnable task: predict token identity (copy task labels = tokens)
    tok = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    ocfg = OptimizerConfig(kind="sgd")
    policy = QuantPolicy(quantize_weights=True, quantize_acts=True,
                         quantize_grads=True, grad_scale=64.0)
    bits = default_bits(cfg, enabled=True)
    step = jax.jit(make_train_step(cfg, policy, ocfg))
    state = init_train_state(params, ocfg)
    losses = []
    p = params
    for s in range(30):
        hyper = Hyper(lr=jnp.float32(0.5), step=jnp.int32(s))
        p, state, m = step(p, state, batch, hyper, bits)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.7, losses


def test_bits_are_runtime_data_no_recompile():
    """One compiled step must serve different (I,F) schedules AND the
    enabled/disabled toggle (TaxoNN loads formats into registers; we pass
    them as arrays)."""
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    ocfg = OptimizerConfig()
    step = make_train_step(cfg, QuantPolicy(), ocfg)
    jstep = jax.jit(step)
    hyper = Hyper(lr=jnp.float32(0.1), step=jnp.int32(0))

    from repro.quant import make_bit_schedule
    b1 = {"blocks": make_bit_schedule(cfg.num_layers, weight=(2, 12))}
    b2 = {"blocks": make_bit_schedule(cfg.num_layers, weight=(1, 4))}
    b3 = {"blocks": make_bit_schedule(cfg.num_layers, enabled=False)}
    state = init_train_state(params, ocfg)
    r1 = jstep(params, state, batch, hyper, b1)
    r2 = jstep(params, state, batch, hyper, b2)
    r3 = jstep(params, state, batch, hyper, b3)
    # compiled exactly once
    assert jstep._cache_size() == 1
    # and coarser bits must actually change the result
    l1 = np.asarray(jax.tree.leaves(r1[0])[0])
    l2 = np.asarray(jax.tree.leaves(r2[0])[0])
    assert not np.allclose(l1, l2)


def test_gradient_lifetime_is_per_layer():
    """Structural check on the paper's memory claim: the engine never builds
    the full-model gradient tree.  We verify by jaxpr inspection that no
    output-gradient buffer with the stacked [L, ...] weight shape exists
    outside the scan (the autodiff path must have one)."""
    cfg = tiny("dense", num_layers=4)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    ocfg = OptimizerConfig()
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.1), step=jnp.int32(0))
    state = init_train_state(params, ocfg)

    # The engine's backward is a scan that carries G [B,T,D] and emits
    # updated params; the autodiff path transposes the whole forward scan.
    # Proxy check: engine jaxpr has exactly 2 scans over the stack (fwd+bwd)
    # at the top level; autodiff has a scan + its transpose inside grad.
    tax = jax.make_jaxpr(
        lambda p, s, b: make_train_step(cfg, QuantPolicy.off(), ocfg)(
            p, s, b, hyper, bits))(params, state, batch)
    scans = [e for e in tax.jaxpr.eqns if e.primitive.name == "scan"]
    assert len(scans) >= 2  # forward stack + backward G-chain (+ CE chunks)
    # the backward scan's carry contains G (B,T,D) — not a [L,...] grad tree
    bwd = scans[-1]
    carry_shapes = [v.aval.shape for v in bwd.invars]
    b, t, d = batch["tokens"].shape[0], 32, cfg.d_model
    assert (b, t, d) in carry_shapes
