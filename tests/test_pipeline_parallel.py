"""GPipe pipeline parallelism: schedule correctness + differentiability."""
import os
import pathlib
import subprocess
import sys
import textwrap

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 4, timeout=600):
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT/'src'}:{ROOT/'tests'}",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_matches_sequential_and_differentiates():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType
from repro.dist.pipeline import pipeline_apply, bubble_fraction

S, LPS, M, MB, D = 4, 2, 8, 2, 16   # 4 stages x 2 layers, 8 microbatches
mesh = jax.make_mesh((S,), ("pipe",), axis_types=(AxisType.Auto,))

key = jax.random.key(0)
w = jax.random.normal(key, (S, LPS, D, D)) * D ** -0.5
x = jax.random.normal(jax.random.key(1), (M, MB, D))

def body(stage_w, h):     # one stage = LPS tanh layers
    for i in range(LPS):
        h = jnp.tanh(h @ stage_w[i])
    return h

# sequential reference
ref = x
for s in range(S):
    ref = jax.vmap(lambda mb: body(w[s], mb))(ref)

got = pipeline_apply(w, x, body, mesh)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           atol=1e-5, rtol=1e-5)
print("FWD OK")

# differentiability: grads through the pipeline == sequential grads
def loss_pipe(w_):
    return jnp.sum(pipeline_apply(w_, x, body, mesh) ** 2)

def loss_seq(w_):
    h = x
    for s in range(S):
        h = jax.vmap(lambda mb: body(w_[s], mb))(h)
    return jnp.sum(h ** 2)

g_pipe = jax.jit(jax.grad(loss_pipe))(w)
g_seq = jax.grad(loss_seq)(w)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                           atol=1e-4, rtol=1e-4)
print("GRAD OK")
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
""")
    assert "FWD OK" in out and "GRAD OK" in out
