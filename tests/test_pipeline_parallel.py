"""Pipeline-schedule subsystem: schedule correctness, differentiability,
tick-table cost model, and edge cases (see repro.dist.pipeline)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.pipeline import (GPipeSchedule, Interleaved1F1BSchedule,
                                 OneFOneBSchedule, bubble_fraction,
                                 get_schedule, pipeline_apply)

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 4, timeout=600):
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT/'src'}:{ROOT/'tests'}",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# execution: every schedule == the sequential reference, values AND grads
# ---------------------------------------------------------------------------

def _body(stage_w, h):
    for i in range(stage_w.shape[0]):
        h = jnp.tanh(h @ stage_w[i])
    return h


def _seq(w, x):
    h = x
    for s in range(w.shape[0]):
        h = jax.vmap(lambda mb: _body(w[s], mb))(h)
    return h


def _data(S, M, LPS=2, MB=2, D=8):
    w = jax.random.normal(jax.random.key(0), (S, LPS, D, D)) * D ** -0.5
    x = jax.random.normal(jax.random.key(1), (M, MB, D))
    return w, x


@pytest.mark.parametrize("sched,S,M", [
    ("gpipe", 4, 8), ("1f1b", 4, 8), ("interleaved", 4, 8),
    # edge cases: fewer microbatches than stages, M == 1, S == 1
    ("gpipe", 4, 2), ("1f1b", 4, 2), ("interleaved", 4, 2),
    ("gpipe", 3, 1), ("1f1b", 3, 1),
    ("gpipe", 1, 5), ("1f1b", 1, 5),
])
def test_schedules_match_sequential(sched, S, M):
    w, x = _data(S, M)
    s_obj = get_schedule(sched)
    ref = _seq(w, x)
    got = pipeline_apply(w, x, _body, schedule=s_obj)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    g_pipe = jax.jit(jax.grad(
        lambda w_: jnp.sum(pipeline_apply(w_, x, _body,
                                          schedule=s_obj) ** 2)))(w)
    g_seq = jax.grad(lambda w_: jnp.sum(_seq(w_, x) ** 2))(w)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("v", [2, 3, 6])
def test_interleaved_virtual_stage_permutation(v):
    """Round-robin virtual-stage storage must not change the function."""
    S, M = 6, 4
    w, x = _data(S, M)
    s_obj = get_schedule("interleaved", num_virtual=v)
    np.testing.assert_array_equal(
        np.asarray(pipeline_apply(w, x, _body, schedule=s_obj)),
        np.asarray(_seq(w, x)))


def test_uneven_virtual_stages_raise():
    w, x = _data(5, 4)
    with pytest.raises(ValueError, match="divis"):
        pipeline_apply(w, x, _body,
                       schedule=get_schedule("interleaved", num_virtual=2))
    with pytest.raises(ValueError, match="virtual"):
        get_schedule("1f1b", num_virtual=2)
    with pytest.raises(ValueError, match="unknown pipeline schedule"):
        get_schedule("2f2b")


# ---------------------------------------------------------------------------
# cost model: bubbles, tick tables, peak activation memory
# ---------------------------------------------------------------------------

def _check_table(plan):
    """Dependencies strictly ordered; one F and one B max per device-tick."""
    S, M = plan.num_stages, plan.num_microbatches
    seen_f, seen_b = set(), set()
    for s in range(S):
        for m in range(M):
            f, b = int(plan.fwd_tick[s, m]), int(plan.bwd_tick[s, m])
            assert 0 <= f < b < plan.num_ticks
            if s > 0:
                assert plan.fwd_tick[s - 1, m] < f
            if s < S - 1:
                assert plan.bwd_tick[s + 1, m] < b
            d = plan.stage_device(s)
            assert (d, f) not in seen_f and (d, b) not in seen_b
            seen_f.add((d, f))
            seen_b.add((d, b))


@pytest.mark.parametrize("S,M", [(2, 4), (2, 8), (4, 8), (4, 16), (8, 16),
                                 (8, 32), (3, 7), (1, 1), (4, 1)])
def test_tick_tables_valid(S, M):
    for spec, v in (("gpipe", None), ("1f1b", None), ("interleaved", 2),
                    ("interleaved", 4)):
        if v is not None and S % v:
            continue
        _check_table(get_schedule(spec, num_virtual=v).plan(S, M))


def test_bubble_ordering_and_closed_forms():
    """1F1B strictly beats GPipe for S >= 2, M >= 2S (acceptance bound);
    closed forms: gpipe (S-1)/(M+S-1), 1f1b (S-1)/(M+2S-1)."""
    for S in (2, 3, 4, 8):
        for M in (2 * S, 2 * S + 1, 4 * S, 32):
            g, f = GPipeSchedule(), OneFOneBSchedule()
            bg, bf = g.bubble_fraction(S, M), f.bubble_fraction(S, M)
            assert bf < bg, (S, M, bf, bg)
            assert bg == pytest.approx((S - 1) / (M + S - 1))
            assert bg == pytest.approx(bubble_fraction(S, M))
            assert bf == pytest.approx((S - 1) / (M + 2 * S - 1))


def test_interleaved_shrinks_bubble_at_same_device_count():
    """v virtual stages per device cut the warm-up bubble vs 1F1B running
    one fat stage per device (both on D pipe devices)."""
    for D, v, M in ((2, 2, 8), (4, 2, 16), (4, 4, 32)):
        b_int = Interleaved1F1BSchedule(num_virtual=v).bubble_fraction(
            D * v, M)
        b_1f1b = OneFOneBSchedule().bubble_fraction(D, M)
        assert b_int < b_1f1b, (D, v, M, b_int, b_1f1b)


def test_peak_activation_memory():
    """GPipe holds all M microbatches; 1F1B caps at min(M, 2S-1)."""
    for S, M in ((4, 16), (8, 32)):
        g, f = GPipeSchedule(), OneFOneBSchedule()
        assert g.peak_activation_microbatches(S, M) == M
        assert f.peak_activation_microbatches(S, M) == min(M, 2 * S - 1)
        mb_bytes = 128 * 256 * 4
        assert (f.peak_activation_bytes(S, M, mb_bytes)
                < g.peak_activation_bytes(S, M, mb_bytes))


def test_schedule_summary_keys():
    s = get_schedule("interleaved", num_virtual=2).summary(8, 16)
    assert s["schedule"] == "interleaved"
    assert s["num_devices"] == 4 and s["num_virtual"] == 2
    assert 0.0 <= s["bubble_fraction"] < 1.0
    assert s["ticks"] > 0 and s["peak_activation_microbatches"] > 0


def test_train_step_threads_pipeline_metrics():
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    # pipeline_stages > 1 now EXECUTES stage-sharded, so the config must
    # divide: 4 layers / 4 stages, batch 8 / 8 microbatches
    cfg = tiny("dense", num_layers=4)
    params = lm.init_params(jax.random.key(0), cfg)
    ocfg = OptimizerConfig()
    step = jax.jit(make_train_step(
        cfg, QuantPolicy.off(), ocfg,
        StepOptions(pipeline_schedule="1f1b", pipeline_stages=4,
                    num_microbatches=8)))
    _, _, m = step(params, init_train_state(params, ocfg),
                   make_batch(cfg, b=8, t=32),
                   Hyper(lr=jnp.float32(0.01), step=jnp.int32(0)),
                   default_bits(cfg, enabled=False))
    assert float(m["pipe_bubble"]) == pytest.approx(3 / 15)
    assert int(m["pipe_ticks"]) == 8 + 2 * 4 - 1
    assert int(m["pipe_peak_mb"]) == 7
    with pytest.raises(ValueError, match="divis"):
        make_train_step(cfg, QuantPolicy.off(), ocfg,
                        StepOptions(
                            pipeline_schedule=get_schedule("interleaved",
                                                           num_virtual=2),
                            pipeline_stages=5, num_microbatches=8))


def test_pipeline_execution_build_time_validation():
    """Indivisible layer counts still fail at step-build time; the former
    family/feature allowlist is gone — every family and every QuantPolicy
    feature now BUILDS (capability detection, exercised exhaustively in
    tests/test_pipeline_conformance.py)."""
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.optim import OptimizerConfig
    from test_models import tiny

    ocfg = OptimizerConfig()
    with pytest.raises(ValueError, match="does not divide"):
        make_train_step(tiny("dense", num_layers=3), QuantPolicy.off(), ocfg,
                        StepOptions(pipeline_schedule="1f1b",
                                    pipeline_stages=2, num_microbatches=4))
    # formerly NotImplementedError: hybrid (shared attn), compress_dw,
    # overlap="on" — all supported since the shared-operand story landed
    for cfg, pol in (
            (tiny("hybrid"), QuantPolicy.off()),
            (tiny("dense", num_layers=4), QuantPolicy(compress_dw=True)),
            (tiny("dense", num_layers=4), QuantPolicy(overlap="on")),
            (tiny("encdec", num_layers=4), QuantPolicy(stochastic=True)),
            (tiny("moe", num_layers=4), QuantPolicy(quantize_updates=True))):
        step = make_train_step(cfg, pol, ocfg,
                               StepOptions(pipeline_schedule="gpipe",
                                           pipeline_stages=2,
                                           num_microbatches=4))
        assert step.pipeline_schedule is not None


# ---------------------------------------------------------------------------
# the engine's blocks stack EXECUTES through dist.pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", [False, True])
def test_engine_stack_executes_through_pipeline(quant):
    """pipeline_stages > 1 runs the TaxoNN engine's blocks stack through
    pipeline_apply: loss bit-exact and updated params within float
    reassociation of the single-device reverse scan, for all three
    schedules (incl. the quantized G-chain via the grad taps)."""
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense", num_layers=4)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig(kind="momentum", grad_clip=1.0)
    hyper = Hyper(lr=jnp.float32(0.05), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    pol = QuantPolicy(grad_scale=16.0) if quant else QuantPolicy.off()
    bits = default_bits(cfg, enabled=quant)
    p0, _, m0 = jax.jit(make_train_step(cfg, pol, ocfg))(
        params, state, batch, hyper, bits)
    for sname, virt in (("gpipe", None), ("1f1b", None), ("interleaved", 2)):
        step = jax.jit(make_train_step(
            cfg, pol, ocfg,
            StepOptions(pipeline_schedule=get_schedule(sname,
                                                       num_virtual=virt),
                        pipeline_stages=4, num_microbatches=4)))
        p1, _, m1 = step(params, state, batch, hyper, bits)
        assert float(m0["loss"]) == float(m1["loss"]), sname
        worst = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)))
        assert worst < 2e-6, (sname, quant, worst)
        assert abs(float(m0["grad_norm"])
                   - float(m1["grad_norm"])) < 1e-4, sname


def test_engine_stack_pipe_mesh_exact():
    """Stage-sharded execution on a REAL 4-device pipe mesh stays exact vs
    the single-device scan for all three schedules."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.dist.pipeline import get_schedule
    from repro.launch.mesh import make_debug_mesh
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense", num_layers=4)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig()
    hyper = Hyper(lr=jnp.float32(0.05), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    bits = default_bits(cfg, enabled=False)
    pol = QuantPolicy.off()
    p0, _, m0 = jax.jit(make_train_step(cfg, pol, ocfg))(
        params, state, batch, hyper, bits)

    mesh = make_debug_mesh(1, 1, pipe=4)
    for sname, virt in (("gpipe", None), ("1f1b", None), ("interleaved", 2)):
        step = jax.jit(make_train_step(
            cfg, pol, ocfg,
            StepOptions(pipeline_schedule=get_schedule(sname,
                                                       num_virtual=virt),
                        pipeline_stages=4, num_microbatches=4)))
        with jax.set_mesh(mesh):
            p1, _, m1 = step(params, state, batch, hyper, bits)
        assert float(m0["loss"]) == float(m1["loss"]), sname
        worst = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(p0),
                                    jax.tree.leaves(p1)))
        assert worst < 2e-6, (sname, worst)
        print(sname, "EXEC OK")
    """)
    assert ("gpipe EXEC OK" in out and "1f1b EXEC OK" in out
            and "interleaved EXEC OK" in out)


# ---------------------------------------------------------------------------
# multi-device: the "pipe" mesh axis
# ---------------------------------------------------------------------------

def test_pipeline_matches_sequential_and_differentiates():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType
from repro.dist.pipeline import pipeline_apply, bubble_fraction, get_schedule

S, LPS, M, MB, D = 4, 2, 8, 2, 16   # 4 stages x 2 layers, 8 microbatches
mesh = jax.make_mesh((S,), ("pipe",), axis_types=(AxisType.Auto,))

key = jax.random.key(0)
w = jax.random.normal(key, (S, LPS, D, D)) * D ** -0.5
x = jax.random.normal(jax.random.key(1), (M, MB, D))

def body(stage_w, h):     # one stage = LPS tanh layers
    for i in range(LPS):
        h = jnp.tanh(h @ stage_w[i])
    return h

# sequential reference
ref = x
for s in range(S):
    ref = jax.vmap(lambda mb: body(w[s], mb))(ref)

def loss_seq(w_):
    h = x
    for s in range(S):
        h = jax.vmap(lambda mb: body(w_[s], mb))(h)
    return jnp.sum(h ** 2)

g_seq = jax.grad(loss_seq)(w)

for spec, virt in (("gpipe", None), ("1f1b", None), ("interleaved", 2)):
    sched = get_schedule(spec, num_virtual=virt)
    got = pipeline_apply(w, x, body, mesh, schedule=sched)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)

    def loss_pipe(w_):
        return jnp.sum(pipeline_apply(w_, x, body, mesh,
                                      schedule=sched) ** 2)

    g_pipe = jax.jit(jax.grad(loss_pipe))(w)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               atol=1e-4, rtol=1e-4)
    print(f"{sched.name} OK")
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
""")
    assert "gpipe OK" in out and "1f1b OK" in out and "interleaved OK" in out


def test_pipe_axis_in_mesh_builders():
    out = run_py("""
import jax
from repro.launch.mesh import make_debug_mesh, pipe_axis_size, batch_axes

mesh = make_debug_mesh(2, 1, pipe=2)
assert dict(mesh.shape) == {"pipe": 2, "data": 2, "model": 1}
assert pipe_axis_size(mesh) == 2
assert batch_axes(mesh) == ("data",)
assert pipe_axis_size(make_debug_mesh(2, 2)) == 1
assert pipe_axis_size(None) == 1
print("MESH OK")
""")
    assert "MESH OK" in out


def test_production_mesh_pipe_axis_shapes():
    # shape-only: build on the dry-run's 512-device host platform
    out = run_py("""
from repro.launch.mesh import make_production_mesh, pipe_axis_size

m = make_production_mesh(pipe=4)
assert dict(m.shape) == {"pipe": 4, "data": 4, "model": 16}
assert pipe_axis_size(m) == 4
m2 = make_production_mesh(multi_pod=True, pipe=2)
assert dict(m2.shape) == {"pod": 2, "pipe": 2, "data": 8, "model": 16}
try:
    make_production_mesh(pipe=3)
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "divide" in str(e)
print("PROD OK")
""", devices=512)
    assert "PROD OK" in out
