"""Serving correctness: decode-with-cache must equal full-context forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.serving import (
    init_decode_state, decode_step, prefill, greedy_generate,
    BatchScheduler, Request,
)

from test_models import tiny, make_batch

jax.config.update("jax_default_matmul_precision", "highest")

FAMILIES = ["dense", "moe", "ssm", "hybrid", "encdec"]


def _decode_logits_via_cache(cfg, params, batch, t_ctx, n_extra, max_len,
                             cache_dtype):
    """Prefill t_ctx tokens then decode the next n_extra, returning logits."""
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :t_ctx]
    logits, state = prefill(params, cfg, pre_batch, max_len, cache_dtype)
    outs = [logits]
    for i in range(n_extra - 1):
        tok = batch["tokens"][:, t_ctx + i][:, None]
        logits, state = decode_step(params, cfg, state, tok)
        outs.append(logits)
    return jnp.stack(outs, axis=1)  # [B, n_extra, V]


def _forward_logits_all(cfg, params, batch, upto):
    x = lm.forward_hidden(params, cfg, batch)
    if cfg.family == "vlm":
        x = x[:, batch["patch_embeds"].shape[1]:]
    w = lm.head_weight(params, cfg)
    return (x[:, :upto] @ w.astype(x.dtype)).astype(jnp.float32)


@pytest.mark.parametrize("family", FAMILIES)
def test_decode_matches_forward(family):
    # MoE: capacity depends on total token count, so prefill(16) and
    # forward(24) drop different tokens at tight capacity.  Equivalence holds
    # in the drop-free regime -> raise capacity_factor for this test.
    kw = {"capacity_factor": 8.0} if family == "moe" else {}
    cfg = tiny(family, **kw)
    params = lm.init_params(jax.random.key(0), cfg)
    t_total, t_ctx = 24, 16
    batch = make_batch(cfg, b=2, t=t_total)
    # cache in f32 so the comparison isolates algorithmic divergence
    dec = _decode_logits_via_cache(cfg, params, batch, t_ctx,
                                   t_total - t_ctx, max_len=t_total,
                                   cache_dtype=jnp.float32)
    full = _forward_logits_all(cfg, params, batch, t_total)
    ref = full[:, t_ctx - 1: t_total - 1]  # logits after tokens ctx-1 .. end-1
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_decode_swa_ring_matches_forward():
    """SWA ring-buffer cache (window < context) must match the full forward
    with the same sliding-window mask."""
    cfg = tiny("dense", swa_window=8)
    params = lm.init_params(jax.random.key(0), cfg)
    t_total, t_ctx = 28, 20
    batch = make_batch(cfg, b=1, t=t_total)
    dec = _decode_logits_via_cache(cfg, params, batch, t_ctx,
                                   t_total - t_ctx, max_len=t_total,
                                   cache_dtype=jnp.float32)
    full = _forward_logits_all(cfg, params, batch, t_total)
    ref = full[:, t_ctx - 1: t_total - 1]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_long_context_state_is_constant_size_for_ssm():
    cfg = tiny("ssm")
    st8 = init_decode_state(cfg, batch=1, max_len=8)
    st64k = init_decode_state(cfg, batch=1, max_len=65536)
    sz8 = sum(x.size for x in jax.tree.leaves(st8["caches"]))
    sz64k = sum(x.size for x in jax.tree.leaves(st64k["caches"]))
    assert sz8 == sz64k  # O(1) in context length: the long_500k justification


def test_greedy_generate_shapes():
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    out = greedy_generate(params, cfg, batch, max_len=32, num_steps=5)
    assert out.shape == (2, 5)
    assert out.dtype == jnp.int32


def test_batch_scheduler_continuous_batching():
    """Slot scheduler must complete all requests and match single-request
    greedy decoding."""
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    max_len = 32

    def prefill_one(tokens):
        return prefill(params, cfg, {"tokens": jnp.asarray(tokens)}, max_len,
                       jnp.float32)

    decode_fn = jax.jit(
        lambda state, toks: decode_step(params, cfg, state, toks))

    def merge_fn(state, slot_state, i):
        # write slot i's cache rows from the (batch-1) prefill state
        def wr(dst, src):
            return dst.at[:, i].set(src[:, 0])
        new_caches = jax.tree.map(wr, state["caches"], slot_state["caches"])
        return {"caches": new_caches, "pos": slot_state["pos"]}

    n_slots = 2
    init_state = init_decode_state(cfg, batch=n_slots, max_len=max_len,
                                   cache_dtype=jnp.float32)
    sched = BatchScheduler(n_slots, prefill_one, decode_fn, merge_fn,
                           init_state)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(3)]
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    finished = sched.run_until_drained()
    assert len(finished) == 3
    assert all(len(r.generated) == 4 for r in finished)

    # first generated token must equal the single-request greedy one
    for r in finished:
        ref = greedy_generate(params, cfg,
                              {"tokens": jnp.asarray(r.prompt[None, :])},
                              max_len=max_len, num_steps=1,
                              cache_dtype=jnp.float32)
        assert r.generated[0] == int(ref[0, 0])


def test_scheduler_snapshot_resumes_identically(tmp_path):
    """The docstring's checkpointability claim, as a tested fact: snapshot
    mid-stream, round-trip the snapshot through the checkpoint layer,
    restore, and the continued decode stream must be IDENTICAL to the
    uninterrupted one."""
    from repro.ckpt import restore_checkpoint, save_checkpoint

    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    max_len = 32
    n_slots = 2

    def prefill_one(tokens):
        return prefill(params, cfg, {"tokens": jnp.asarray(tokens)}, max_len,
                       jnp.float32)

    decode_fn = jax.jit(
        lambda state, toks: decode_step(params, cfg, state, toks))

    def merge_fn(state, slot_state, i):
        def wr(dst, src):
            return dst.at[:, i].set(src[:, 0])
        new_caches = jax.tree.map(wr, state["caches"], slot_state["caches"])
        return {"caches": new_caches, "pos": slot_state["pos"]}

    def make_sched():
        init_state = init_decode_state(cfg, batch=n_slots, max_len=max_len,
                                       cache_dtype=jnp.float32)
        return BatchScheduler(n_slots, prefill_one, decode_fn, merge_fn,
                              init_state)

    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=(5,)).astype(np.int32)
               for _ in range(4)]

    # reference: uninterrupted run
    ref = make_sched()
    for i, p in enumerate(prompts):
        ref.submit(Request(uid=i, prompt=p.copy(), max_new_tokens=5))
    ref_out = {r.uid: list(r.generated) for r in ref.run_until_drained()}
    assert len(ref_out) == 4

    # interrupted run: 3 decode steps, then snapshot mid-stream
    sched = make_sched()
    originals = [Request(uid=i, prompt=p.copy(), max_new_tokens=5)
                 for i, p in enumerate(prompts)]
    for r in originals:
        sched.submit(r)
    for _ in range(3):
        sched.step()
    snap = sched.snapshot()
    assert len(snap["slot_reqs"]) > 0 and len(snap["pending"]) > 0
    assert any(not d["done"] for d in snap["slot_reqs"])  # genuinely mid-stream

    # the snapshot must survive the checkpoint layer unchanged
    save_checkpoint(tmp_path, 1, snap)
    template = jax.tree.map(np.asarray, snap)
    loaded, _, _ = restore_checkpoint(tmp_path, template)

    resumed = BatchScheduler.restore(loaded, prefill_one, decode_fn, merge_fn)
    out = {r.uid: list(r.generated) for r in originals if r.done}
    out.update({r.uid: list(r.generated)
                for r in resumed.run_until_drained()})
    assert out == ref_out
