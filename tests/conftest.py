"""Test bootstrap: put ``src`` on sys.path and install the jax compat shims
before any test module imports mesh machinery.

Subprocess tests (test_perf_options / test_pipeline_parallel / the train
driver) get the same treatment via ``src/sitecustomize.py`` — they export
PYTHONPATH=src themselves, which auto-imports it at interpreter start-up.
"""
import pathlib
import sys

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

import repro.util.jaxcompat  # noqa: E402,F401

# The pinned container has no hypothesis wheel; fall back to the vendored
# deterministic mini-implementation so the property tests still execute.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent / "_vendor"))
