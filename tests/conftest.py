"""Test bootstrap: put ``src`` on sys.path and install the jax compat shims
before any test module imports mesh machinery.

Subprocess tests (test_perf_options / test_pipeline_parallel / the train
driver) get the same treatment via ``src/sitecustomize.py`` — they export
PYTHONPATH=src themselves, which auto-imports it at interpreter start-up.

The CI matrix selects a kernel datapath per leg via REPRO_KERNEL_BACKEND
(off | int8); tests read it through the ``kernel_backend`` fixture below so
the no-kernel and int8 paths are both exercised on every push.
"""
import os
import pathlib
import sys

import pytest

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

import repro.util.jaxcompat  # noqa: E402,F401

# The pinned container has no hypothesis wheel; fall back to the vendored
# deterministic mini-implementation so the property tests still execute.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent / "_vendor"))


@pytest.fixture(scope="session")
def kernel_backend() -> str:
    """The kernel datapath selected by the CI matrix leg (default "off").

    Tests that exercise the train/serve hot paths parameterize on this so
    the {1, 4}-device x {off, int8} matrix covers every combination.
    """
    backend = os.environ.get("REPRO_KERNEL_BACKEND", "off")
    assert backend in ("off", "emulate", "int8"), backend
    return backend


@pytest.fixture(scope="session")
def overlap() -> str:
    """The backward-scan overlap mode selected by the CI matrix leg
    (default "off"; the 4-device jobs add overlap="on" legs so the
    software-pipelined dW reduce runs against a real device group)."""
    mode = os.environ.get("REPRO_OVERLAP", "off")
    assert mode in ("off", "on"), mode
    return mode
