"""Family x schedule x quant-feature conformance matrix for stage-sharded
pipeline execution.

Every cell runs ONE optimizer step of the TaxoNN engine twice — once as the
single-device reverse scan (the reference) and once stage-sharded through
``dist.pipeline`` on a 4-device "pipe" mesh — and asserts:

  * the loss is BIT-EXACT (the pipeline's remat-per-layer primal runs the
    same un-linearized forward the scan engine does), and
  * every updated parameter agrees within 2e-6 (the backward re-linearizes
    each layer at the forward's cached inputs; float reassociation across
    the microbatch split is the only difference).

The matrix is {dense, ssm, vlm, hybrid, encdec, moe} x {gpipe, 1f1b,
interleaved} x {quant off, quant on, +stochastic rounding,
+quantize_updates, +compress_dw}.  Legs skip cleanly on hosts with fewer
than 4 devices (the 4-device CI `pipeline-exec` job runs all 90 of them,
under the kernel-backend and overlap modes of its matrix axes).

The bit-exact contract applies to the kernel-off datapath.  Under
``REPRO_KERNEL_BACKEND=int8`` the matrix still runs every leg but checks
a datapath-appropriate bound instead: the int8 MXU absmax transport
quantizes per tile and tile shapes follow call shapes, so splitting the
batch into microbatches regroups rows into different absmax blocks — a
property of the kernel datapath, independent of the pipeline.

The learning rate is deliberately small (2e-3): stochastic rounding and
the int8 dW codec amplify sub-ulp backward-fusion drift into one-grid-step
jumps on unlucky elements, and the param tolerance must bound lr x jump.
A systematic parity bug (wrong PRNG threading, missing shared-operand
gradient, dropped aux seed) moves ~every quantized element and blows the
tolerance by orders of magnitude regardless of lr.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.steps import (default_bits, init_train_state,
                              num_scan_units, pipeline_exec_capabilities)
from repro.dist.pipeline import get_schedule
from repro.launch.mesh import make_debug_mesh
from repro.models import lm
from repro.optim import Hyper, OptimizerConfig
from test_models import make_batch, tiny

FAMILIES = ("dense", "ssm", "vlm", "hybrid", "encdec", "moe")
SCHEDULES = (("gpipe", None), ("1f1b", None), ("interleaved", 2))
# leg name -> (QuantPolicy kwargs, needs rng)
QUANT_LEGS = {
    "off": (dict(quantize_weights=False, quantize_acts=False,
                 quantize_grads=False), False),
    "on": (dict(grad_scale=16.0), False),
    "stochastic": (dict(grad_scale=16.0, stochastic=True), True),
    "quant_updates": (dict(grad_scale=16.0, quantize_updates=True), False),
    "compress_dw": (dict(grad_scale=16.0, compress_dw=True), False),
}
S_PIPE, M_PIPE = 4, 4
LR = 2e-3
PARAM_TOL = 2e-6

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="pipeline conformance needs a 4-device pipe mesh")


def _cfg(family):
    """Tiny per-family config with exactly S_PIPE engine units."""
    if family == "hybrid":
        return tiny("hybrid", num_layers=2 * S_PIPE, attn_every=2)
    return tiny(family, num_layers=S_PIPE)


def _fixture(family, leg, kernel_backend, overlap):
    cfg = _cfg(family)
    assert num_scan_units(cfg) == S_PIPE
    pol_kw, needs_rng = QUANT_LEGS[leg]
    pol = QuantPolicy(**pol_kw, kernel_backend=kernel_backend,
                      overlap=overlap)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=16)
    ocfg = OptimizerConfig(kind="sgd")
    hyper = Hyper(lr=jnp.float32(LR), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    bits = default_bits(cfg, enabled=pol.quantize_weights)
    rng = jax.random.key(3) if needs_rng else None
    return cfg, pol, params, batch, ocfg, hyper, state, bits, rng


_REF_CACHE = {}


def _reference(family, leg, kernel_backend, overlap):
    """Single-device scan-engine step for this (family, quant leg)."""
    key = (family, leg, kernel_backend, overlap)
    if key not in _REF_CACHE:
        (cfg, pol, params, batch, ocfg, hyper, state, bits,
         rng) = _fixture(family, leg, kernel_backend, overlap)
        step = jax.jit(make_train_step(cfg, pol, ocfg))
        p, _, m = step(params, state, batch, hyper, bits, rng)
        _REF_CACHE[key] = (jax.device_get(jax.tree.leaves(p)),
                           float(m["loss"]), float(m["grad_norm"]))
    return _REF_CACHE[key]


@needs4
@pytest.mark.parametrize("leg", sorted(QUANT_LEGS))
@pytest.mark.parametrize("sched,virt",
                         SCHEDULES, ids=[s for s, _ in SCHEDULES])
@pytest.mark.parametrize("family", FAMILIES)
def test_pipeline_conformance(family, sched, virt, leg, kernel_backend,
                              overlap):
    ref_leaves, ref_loss, ref_gnorm = _reference(family, leg,
                                                 kernel_backend, overlap)
    (cfg, pol, params, batch, ocfg, hyper, state, bits,
     rng) = _fixture(family, leg, kernel_backend, overlap)
    step = jax.jit(make_train_step(
        cfg, pol, ocfg,
        StepOptions(pipeline_schedule=get_schedule(sched, num_virtual=virt),
                    pipeline_stages=S_PIPE, num_microbatches=M_PIPE)))
    mesh = make_debug_mesh(1, 1, pipe=4)
    with jax.set_mesh(mesh):
        p, _, m = step(params, state, batch, hyper, bits, rng)
    worst = max(float(jnp.abs(jnp.asarray(a) - jnp.asarray(b)).max())
                for a, b in zip(ref_leaves, jax.tree.leaves(p)))
    if kernel_backend == "off":
        # the conformance contract: bit-exact loss, params to reassociation
        assert float(m["loss"]) == ref_loss, (family, sched, leg)
        assert worst < PARAM_TOL, (family, sched, leg, worst)
        assert abs(float(m["grad_norm"]) - ref_gnorm) <= max(
            1e-3, 1e-3 * ref_gnorm), (family, sched, leg)
    else:
        # int8 MXU datapath: the absmax transport quantizes per TILE, and
        # tile shapes follow the call shapes — a microbatch matmul and the
        # full-batch matmul group rows into different absmax blocks, so
        # the datapath itself (not the pipeline) shifts values.  The CI
        # int8 leg therefore checks a datapath-appropriate bound (absmax
        # scale granularity ~ 1/127 per tile); the bit-exact contract is
        # carried by the kernel-off legs of the tests matrix.
        assert abs(float(m["loss"]) - ref_loss) <= 5e-3 * abs(ref_loss), (
            family, sched, leg, float(m["loss"]), ref_loss)
        assert worst < 1e-3, (family, sched, leg, worst)
        assert abs(float(m["grad_norm"]) - ref_gnorm) <= max(
            0.1, 0.1 * ref_gnorm), (family, sched, leg)


# ---------------------------------------------------------------------------
# capability detection: NO family/feature combination raises at build time
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("leg", sorted(QUANT_LEGS))
@pytest.mark.parametrize("family", FAMILIES)
def test_no_family_feature_combination_raises(family, leg):
    """Regression for the old allowlist: every family and every quant
    feature (plus overlap) now BUILDS a pipelined train step; capability
    detection reports full support."""
    cfg = _cfg(family)
    pol_kw, _ = QUANT_LEGS[leg]
    for ov in ("off", "on"):
        pol = QuantPolicy(**pol_kw, overlap=ov)
        caps = pipeline_exec_capabilities(cfg, pol)
        assert all(caps.values()), (family, leg, ov, caps)
        step = make_train_step(cfg, pol, OptimizerConfig(),
                               StepOptions(pipeline_schedule="1f1b",
                                           pipeline_stages=S_PIPE,
                                           num_microbatches=M_PIPE))
        assert step.pipeline_schedule is not None


def test_unknown_family_still_detected():
    import dataclasses
    cfg = dataclasses.replace(_cfg("dense"), family="dense")
    caps = pipeline_exec_capabilities(cfg, QuantPolicy.off())
    assert caps["family:dense"]
    # an unknown family keys to False (capability DETECTION, not allowlist)
    fake = dataclasses.replace(cfg)
    object.__setattr__(fake, "family", "unobtainium")
    caps = pipeline_exec_capabilities(fake, QuantPolicy.off())
    assert not caps["family:unobtainium"]


# ---------------------------------------------------------------------------
# pipe axis composed with the data axis: dW reduced over "data" while the
# stack executes stage-sharded (compress/overlap on and off)
# ---------------------------------------------------------------------------

@needs4
@pytest.mark.parametrize("compress", [False, True], ids=["dense", "compressed"])
@pytest.mark.parametrize("overlap_mode", ["off", "on"])
def test_pipe_axis_composes_with_data_axis(compress, overlap_mode):
    """Stage-sharded execution inside a shard_map over a 2-device "data"
    axis, with each layer's dW all-reduced over it (blocking psum or the
    one-deep overlapped ring, dense or int8-compressed): the result must
    match the equivalent single-device scan run in the same shard_map."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    cfg = tiny("dense", num_layers=4)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=16)
    ocfg = OptimizerConfig(kind="sgd")
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.01), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    mesh = jax.make_mesh((2,), ("data",))

    def run(pipe):
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          compress_dw=compress, dw_psum_axes=("data",),
                          dw_num_replicas=2, overlap=overlap_mode)
        opts = (StepOptions(pipeline_schedule="1f1b", pipeline_stages=4,
                            num_microbatches=4) if pipe else StepOptions())
        step = make_train_step(cfg, pol, ocfg, opts)
        f = jax.shard_map(lambda p, s, b: step(p, s, b, hyper, bits),
                          mesh=mesh, in_specs=(P(), P(), P("data")),
                          out_specs=(P(), P(), P()), check_vma=False)
        return jax.jit(f)(params, state, batch)

    p_scan, _, m_scan = run(pipe=False)
    p_pipe, _, m_pipe = run(pipe=True)
    assert float(m_scan["loss"]) == float(m_pipe["loss"])
    worst = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(p_scan),
                                jax.tree.leaves(p_pipe)))
    assert worst < 1e-5, (compress, overlap_mode, worst)
    assert np.isfinite(float(m_pipe["grad_norm"]))
