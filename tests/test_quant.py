"""Property and unit tests for the fixed-point (I,F) quantization library."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant import (
    QFormat,
    quantize,
    quantize_ste,
    quantize_stochastic,
    fxp_max,
    fxp_resolution,
    make_bit_schedule,
    paper_schedule,
    compress_int8,
    decompress_int8,
)
from repro.quant.fixed_point import maybe_quantize


bit_strategy = st.tuples(st.integers(1, 6), st.integers(2, 14))


@settings(max_examples=50, deadline=None)
@given(bits=bit_strategy, data=st.lists(st.floats(-100, 100, width=32), min_size=1, max_size=32))
def test_quantize_idempotent(bits, data):
    """q(q(x)) == q(x): quantization is a projection onto the grid."""
    i, f = bits
    x = jnp.asarray(np.array(data, np.float32))
    q1 = quantize(x, i, f)
    q2 = quantize(q1, i, f)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=0, atol=0)


@settings(max_examples=50, deadline=None)
@given(bits=bit_strategy, data=st.lists(st.floats(-100, 100, width=32), min_size=1, max_size=32))
def test_quantize_error_bound(bits, data):
    """In-range values are within half a resolution step of their quant."""
    i, f = bits
    x = np.array(data, np.float32)
    bound = float(fxp_max(i, f))
    step = float(fxp_resolution(f))
    q = np.asarray(quantize(jnp.asarray(x), i, f))
    in_range = np.abs(x) <= bound
    assert np.all(np.abs(q[in_range] - x[in_range]) <= step / 2 + 1e-7)


@settings(max_examples=50, deadline=None)
@given(bits=bit_strategy, data=st.lists(st.floats(-1000, 1000, width=32), min_size=1, max_size=32))
def test_quantize_saturates(bits, data):
    """Out-of-range values clip to the format bounds (hardware saturation)."""
    i, f = bits
    x = jnp.asarray(np.array(data, np.float32))
    bound = float(fxp_max(i, f))
    step = float(fxp_resolution(f))
    q = np.asarray(quantize(x, i, f))
    assert np.all(q <= bound + 1e-7)
    assert np.all(q >= -bound - step - 1e-7)  # two's complement: min = -2^(I+F) * step


@settings(max_examples=30, deadline=None)
@given(bits=bit_strategy)
def test_grid_values_exact(bits):
    """Every grid point k*2^-F round-trips exactly."""
    i, f = bits
    ks = np.arange(-(2 ** min(i + f, 12)), 2 ** min(i + f, 12), max(1, 2 ** max(i + f - 6, 0)))
    x = (ks * 2.0 ** -f).astype(np.float32)
    q = np.asarray(quantize(jnp.asarray(x), i, f))
    np.testing.assert_array_equal(q, x)


def test_ste_gradient_identity_in_range():
    x = jnp.asarray([0.1, -0.2, 0.5, -0.7], jnp.float32)
    g = jax.grad(lambda v: jnp.sum(quantize_ste(v, 2, 8)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(4), atol=0)


def test_ste_gradient_zero_when_saturated():
    x = jnp.asarray([100.0, -100.0, 0.5], jnp.float32)
    g = jax.grad(lambda v: jnp.sum(quantize_ste(v, 2, 8)))(x)
    np.testing.assert_allclose(np.asarray(g), np.array([0.0, 0.0, 1.0]), atol=0)


def test_stochastic_rounding_unbiased():
    """Mean of stochastic rounding approaches the true value."""
    key = jax.random.key(0)
    x = jnp.full((20000,), 0.3, jnp.float32)  # 0.3 is off-grid for F=2 (step .25)
    q = quantize_stochastic(x, 2, 2, key)
    # E[q] = 0.3 exactly; grid points are .25 and .5
    assert abs(float(jnp.mean(q)) - 0.3) < 0.01
    vals = np.unique(np.asarray(q))
    assert set(vals).issubset({0.25, 0.5})


def test_stochastic_on_grid_exact():
    key = jax.random.key(1)
    x = jnp.asarray([0.25, -0.5, 1.0], jnp.float32)
    q = quantize_stochastic(x, 3, 2, key)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x))


def test_qformat_matches_paper_notation():
    q = QFormat(2, 12)
    assert q.bitwidth == 15
    assert repr(q) == "(2,12)"
    assert q.resolution == 2.0 ** -12


def test_bit_schedule_shapes_and_ramp():
    s = make_bit_schedule(8, weight=(2, 10), ramp=True)
    assert s.num_layers == 8
    assert int(s.w_f[0]) == 10
    assert int(s.w_f[-1]) == 12  # +2 frac bits in the tail
    assert int(s.w_i[-1]) == 3   # +1 int bit on the last layer
    lyr = s.layer(0)
    assert lyr.w_i.shape == ()


def test_paper_schedule_table1():
    s = paper_schedule("mnist", 5)
    np.testing.assert_array_equal(np.asarray(s.w_i), [2, 2, 2, 1, 3])
    np.testing.assert_array_equal(np.asarray(s.w_f), [12, 12, 12, 12, 10])


def test_maybe_quantize_toggle():
    x = jnp.asarray([0.333], jnp.float32)
    on = maybe_quantize(x, 2, 4, jnp.float32(1.0))
    off = maybe_quantize(x, 2, 4, jnp.float32(0.0))
    assert float(on[0]) != pytest.approx(0.333, abs=1e-6)
    assert float(off[0]) == pytest.approx(0.333, abs=1e-7)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2000),
    seed=st.integers(0, 2**31 - 1),
)
def test_int8_codec_roundtrip_error(n, seed):
    """Blockwise int8 codec: relative error bounded by 1/127 per block max."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32) * rng.uniform(0.01, 10)
    payload, scales = compress_int8(jnp.asarray(x))
    assert payload.dtype == jnp.int8
    y = np.asarray(decompress_int8(payload, scales, x.shape))
    blk = 256
    xp = np.pad(x, (0, (-n) % blk)).reshape(-1, blk)
    tol = np.abs(xp).max(axis=1, keepdims=True) / 127.0 * 0.5 + 1e-8
    err = np.abs(np.pad(x - y.ravel()[:n], (0, (-n) % blk)).reshape(-1, blk))
    assert np.all(err <= tol + 1e-6)


def test_codec_zero_input():
    x = jnp.zeros((100,), jnp.float32)
    p, s = compress_int8(x)
    y = decompress_int8(p, s, (100,))
    np.testing.assert_array_equal(np.asarray(y), np.zeros(100))
