"""Communication-overlapped backward scan + bucketed ring collectives.

Covers dist.async_collectives (ring == psum on a real device group, the
AsyncHandle pytree contract), the overlapped engine scan (bit-exact on one
device where the handle is the identity; <= 1e-5 vs the blocking psum on a
4-device mesh, dense AND compressed transport), the CI matrix leg fixture,
and the check_regression missing-baseline satellite.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.dist.async_collectives import (AsyncHandle, all_reduce_start,
                                          all_reduce_wait, group_size,
                                          tree_all_reduce_start,
                                          tree_all_reduce_wait)
from repro.models import lm
from repro.optim import Hyper, OptimizerConfig
from test_models import make_batch, tiny

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 4, timeout=600):
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT/'src'}:{ROOT/'tests'}",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# the AsyncHandle / ring primitives
# ---------------------------------------------------------------------------

def test_identity_handle_bit_exact():
    """No axes (or a group of one) => wait(start(x)) is x bitwise on the
    dense path, and exactly compressed_psum's codec round-trip (times the
    simulated replica count) on the compressed path."""
    from repro.dist.collectives import compressed_psum
    x = jnp.asarray(np.random.default_rng(0).standard_normal((13, 7)),
                    jnp.float32)
    for kwargs in ({}, {"num_replicas": 1}):
        h = all_reduce_start(x, (), **kwargs)
        np.testing.assert_array_equal(np.asarray(all_reduce_wait(h)),
                                      np.asarray(x))
    for n in (None, 4):
        h = all_reduce_start(x, (), compressed=True, num_replicas=n)
        np.testing.assert_array_equal(
            np.asarray(all_reduce_wait(h)),
            np.asarray(compressed_psum(x, (), num_replicas=n)))


def test_async_handle_is_scan_carry_safe():
    """Handles must survive pytree flatten/unflatten (the scan carry) with
    their in-flight arrays and static metadata intact."""
    x = jnp.arange(24.0, dtype=jnp.float32).reshape(6, 4)
    h = all_reduce_start(x, ())
    leaves, treedef = jax.tree.flatten(h)
    h2 = jax.tree.unflatten(treedef, leaves)
    assert isinstance(h2, AsyncHandle)
    assert h2.kind == h.kind and h2.shape == h.shape
    np.testing.assert_array_equal(np.asarray(all_reduce_wait(h2)),
                                  np.asarray(x))
    # and inside an actual scan carry
    def body(carry, xs):
        new = all_reduce_start(xs * 2.0, ())
        return new, all_reduce_wait(carry)
    init = all_reduce_start(jnp.zeros((4,)), ())
    last, ys = jax.lax.scan(body, init, jnp.ones((3, 4)))
    np.testing.assert_array_equal(np.asarray(all_reduce_wait(last)),
                                  2.0 * np.ones(4))


def test_tree_start_wait_roundtrip():
    tree = {"a": jnp.ones((4, 4)), "b": {"c": jnp.arange(5.0)}}
    out = tree_all_reduce_wait(tree_all_reduce_start(tree, ()))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_group_size_resolution():
    assert group_size((), None) == 1
    assert group_size(("data",), 8) == 8          # explicit override wins
    with pytest.raises(ValueError, match="pass num_replicas"):
        group_size(("nonexistent-axis",), None)


def test_ring_matches_psum_on_device_group():
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.async_collectives import ring_all_reduce

    mesh = jax.make_mesh((4,), ("data",))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((13, 7)),
                    jnp.float32)

    def run(fn):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P(),
                                     out_specs=P(), check_vma=False))(x)

    def contrib(v):
        return v * (jax.lax.axis_index("data") + 1.0)

    ref = np.asarray(run(lambda v: jax.lax.psum(contrib(v), "data")))
    for kwargs in ({}, {"num_buckets": 3}):
        got = np.asarray(run(lambda v, kw=kwargs: ring_all_reduce(
            contrib(v), ("data",), num_replicas=4, **kw)))
        np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-5)
    # compressed circulate: error bounded by one codec half-step/replica
    comp = np.asarray(run(lambda v: ring_all_reduce(
        contrib(v), ("data",), num_replicas=4, compressed=True)))
    tol = 10 * np.abs(ref).max() / 127.0
    assert np.abs(comp - ref).max() <= tol
    print("RING OK")
    """)
    assert "RING OK" in out


# ---------------------------------------------------------------------------
# the overlapped backward scan
# ---------------------------------------------------------------------------

def _step_pair(cfg, pol_kwargs, ocfg_kind="momentum"):
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    ocfg = OptimizerConfig(kind=ocfg_kind)
    bits = default_bits(cfg, enabled=pol_kwargs.pop("bits_on", True))
    hyper = Hyper(lr=jnp.float32(0.05), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    outs = {}
    for overlap in ("off", "on"):
        pol = QuantPolicy(**pol_kwargs, overlap=overlap)
        step = jax.jit(make_train_step(cfg, pol, ocfg))
        outs[overlap] = step(params, state, batch, hyper, bits)
    return outs


@pytest.mark.parametrize("family", ["dense", "hybrid", "encdec"])
def test_overlap_single_device_bit_exact(family):
    """With no dw_psum_axes the handle is the identity, so the overlapped
    scan is a pure schedule change: params, opt state and metrics must be
    BITWISE identical to the blocking scan."""
    outs = _step_pair(tiny(family),
                      dict(grad_scale=16.0, quantize_updates=True))
    p0, s0, m0 = outs["off"]
    p1, s1, m1 = outs["on"]
    assert float(m0["loss"]) == float(m1["loss"])
    for a, b in zip(jax.tree.leaves((p0, s0)), jax.tree.leaves((p1, s1))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m0["grad_norm"]) == pytest.approx(float(m1["grad_norm"]),
                                                   abs=1e-5)


def test_overlap_single_device_bit_exact_compressed():
    """compress_dw with no mesh axes is the codec round-trip; the
    overlapped scan's identity handle must apply the SAME round-trip, not
    silently skip it."""
    outs = _step_pair(tiny("dense"),
                      dict(quantize_weights=False, quantize_acts=False,
                           quantize_grads=False, kernel_backend="off",
                           compress_dw=True, bits_on=False),
                      ocfg_kind="sgd")
    p0, _, m0 = outs["off"]
    p1, _, m1 = outs["on"]
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # grad_norm sums per-layer gsq in pipeline order (drain term last) —
    # float reassociation only, params above are the bitwise check
    assert float(m0["grad_norm"]) == pytest.approx(float(m1["grad_norm"]),
                                                   rel=1e-6)


def test_overlap_rejects_unknown_mode():
    with pytest.raises(ValueError, match="overlap"):
        make_train_step(tiny("dense"), QuantPolicy.off(), OptimizerConfig(),
                        StepOptions(overlap="sometimes"))


def test_overlap_matrix_leg_trains(overlap):
    """The CI-matrix leg's overlap mode (REPRO_OVERLAP via the conftest
    fixture) must run the train hot path end-to-end."""
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    ocfg = OptimizerConfig()
    step = jax.jit(make_train_step(cfg, QuantPolicy.off(), ocfg,
                                   StepOptions(overlap=overlap)))
    _, _, m = step(params, init_train_state(params, ocfg),
                   make_batch(cfg, t=32),
                   Hyper(lr=jnp.float32(0.01), step=jnp.int32(0)),
                   default_bits(cfg, enabled=False))
    assert np.isfinite(float(m["loss"])), overlap


def test_overlap_multi_device_matches_blocking():
    """On a 4-device mesh the overlapped ring reduce must agree with the
    blocking in-scan psum: forward bit-exact, updated params <= 1e-5 (the
    ring reassociates the 4-replica sum), dense AND compressed."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig()
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.01), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    mesh = jax.make_mesh((4,), ("data",))

    def run(overlap, compress):
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          compress_dw=compress, dw_psum_axes=("data",),
                          dw_num_replicas=4, overlap=overlap)
        step = make_train_step(cfg, pol, ocfg)
        f = jax.shard_map(lambda p, s, b: step(p, s, b, hyper, bits),
                          mesh=mesh, in_specs=(P(), P(), P("data")),
                          out_specs=(P(), P(), P()), check_vma=False)
        return jax.jit(f)(params, state, batch)

    for compress in (False, True):
        p_off, _, m_off = run("off", compress)
        p_on, _, m_on = run("on", compress)
        assert float(m_off["loss"]) == float(m_on["loss"])
        worst = max(float(jnp.abs(a - b).max()) for a, b in
                    zip(jax.tree.leaves(p_off), jax.tree.leaves(p_on)))
        assert worst < 1e-5, (compress, worst)
        print(f"compress={compress} worst={worst:.2e} OK")
    print("OVERLAP4 OK")
    """)
    assert "OVERLAP4 OK" in out


def test_overlap_hlo_has_compute_in_collective_windows():
    """The compiled overlapped step must show compute scheduled inside
    collective latency windows (the cross-scan-step handles) — the
    overlap_fraction metric the benchmark gates on."""
    out = run_py("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.dist.hlo_analysis import overlap_fraction
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig()
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.01), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    mesh = jax.make_mesh((4,), ("data",))
    pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                      quantize_grads=False, kernel_backend="off",
                      dw_psum_axes=("data",), dw_num_replicas=4,
                      overlap="on")
    step = make_train_step(cfg, pol, ocfg)
    f = jax.shard_map(lambda p, s, b: step(p, s, b, hyper, bits),
                      mesh=mesh, in_specs=(P(), P(), P("data")),
                      out_specs=(P(), P(), P()), check_vma=False)
    hlo = jax.jit(f).lower(params, state, batch).compile().as_text()
    ov = overlap_fraction(hlo)
    assert ov["collectives"] > 0, ov
    assert ov["overlap_fraction"] > 0.0, ov
    assert ov["compute_ops_in_windows"] > 0, ov
    print("OVFRAC", ov["overlap_fraction"], "OK")
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# the dryrun report surfaces overlap_fraction / pipe_bubble (satellite)
# ---------------------------------------------------------------------------

def test_dryrun_report_surfaces_overlap_and_pipe_bubble():
    from repro.launch.report import render_dryrun_table
    rec = {
        "arch": "qwen1.5-0.5b", "cell": "train_4k", "mesh": "pod_16x16",
        "status": "ok", "compile_s": 12.0,
        "overlap_fraction": 0.25, "pipe_bubble": 0.2,
        "scanned_artifact": {
            "memory_analysis": {"argument_size_in_bytes": 1 << 20,
                                "temp_size_in_bytes": 1 << 20},
            "collectives": {"counts": {"all-reduce": 3}},
            "overlap": {"overlap_fraction": 0.25},
        },
    }
    legacy = dict(rec, cell="prefill_32k")
    legacy.pop("overlap_fraction")
    legacy.pop("pipe_bubble")
    legacy["scanned_artifact"] = dict(rec["scanned_artifact"])
    legacy["scanned_artifact"].pop("overlap")
    table = render_dryrun_table([rec, legacy])
    assert "| overlap | pipe bubble |" in table.splitlines()[0]
    assert "| 0.25 | 0.20 |" in table     # new record renders the metrics
    assert "| — | — |" in table           # pre-overlap records stay legible


# ---------------------------------------------------------------------------
# check_regression: missing committed baseline warns and skips (satellite)
# ---------------------------------------------------------------------------

def _run_gate(args):
    return subprocess.run(
        [sys.executable, str(ROOT / "benchmarks" / "check_regression.py"),
         *args], capture_output=True, text=True, cwd=ROOT)


def test_check_regression_missing_baseline_warns_and_skips(tmp_path):
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps(
        [{"suite": "overlap", "name": "x", "us_per_call": 100.0}]))
    out = _run_gate(["--baseline", str(tmp_path / "nope.json"),
                     "--fresh", str(fresh)])
    assert out.returncode == 0, out.stdout + out.stderr
    assert "no committed baseline" in out.stdout


def test_check_regression_still_gates_with_baseline(tmp_path):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(
        [{"suite": "s", "name": "a", "us_per_call": 1000.0},
         {"suite": "s", "name": "b", "us_per_call": 1000.0}]))
    fresh.write_text(json.dumps(
        [{"suite": "s", "name": "a", "us_per_call": 1000.0},
         {"suite": "s", "name": "b", "us_per_call": 5000.0}]))
    out = _run_gate(["--baseline", str(base), "--fresh", str(fresh)])
    assert out.returncode == 1
    assert "FAIL s/b" in out.stdout


# ---------------------------------------------------------------------------
# overlap_fraction on captured HLO from both regimes (satellite regression)
# ---------------------------------------------------------------------------

def test_hlo_overlap_fraction_differs_between_regimes():
    """Regression: ``overlap_fraction`` reported the IDENTICAL 0.2222 for
    overlap=off (9 collectives / 2 overlapped) and overlap=on with the
    ring transport (81 / 18) because every ppermute hop of the ring was
    counted as its own overlapped collective, inflating numerator and
    denominator in lockstep.  With hop-chain absorption the two compiled
    regimes must produce DIFFERENT fractions, and the on-regime must not
    count an order of magnitude more "collectives" than the off-regime
    has logical reduces."""
    out = run_py("""
    import dataclasses, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.dist.hlo_analysis import overlap_fraction
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig(kind="sgd")
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(1e-2), step=jnp.int32(0))
    opt = init_train_state(params, ocfg)
    mesh = jax.make_mesh((4,), ("data",))

    stats = {}
    for overlap in ("off", "on"):
        # the issue's regression pair: off with the (autotuned -> psum)
        # default, on with the ring transport forced -- 9/2 vs 81/18 hops
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          dw_psum_axes=("data",), dw_num_replicas=4,
                          overlap=overlap,
                          dw_transport="ring" if overlap == "on" else "auto")
        step = make_train_step(cfg, pol, ocfg)
        fn = jax.jit(jax.shard_map(
            lambda p, s, b: step(p, s, b, hyper, bits),
            mesh=mesh, in_specs=(P(), P(), P("data")),
            out_specs=(P(), P(), P()), check_vma=False))
        hlo = fn.lower(params, opt, batch).compile().as_text()
        stats[overlap] = overlap_fraction(hlo)

    off, on = stats["off"], stats["on"]
    assert off["collectives"] > 0 and on["collectives"] > 0
    # hop absorption: the on-regime's ring must not explode the count
    assert on["collectives"] <= 4 * off["collectives"], (off, on)
    assert on["overlap_fraction"] > 0, (off, on)
    assert on["overlap_fraction"] != off["overlap_fraction"], (off, on)
    print("REGIMES", off["overlap_fraction"], on["overlap_fraction"])
    """)
    assert "REGIMES" in out
