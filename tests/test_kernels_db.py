"""Double-buffered DMA kernel datapath: bit-exactness vs the implicit
blocked-fetch path, autotuner VMEM budgeting, and the resolve knob.

The double-buffered variants compute the SAME blocks in the SAME order
(only the fetch mechanism changes: explicit 2-slot prefetch DMAs instead of
Pallas' implicit pipeline), so outputs must match bit-for-bit — any
difference means a race between the prefetch and the consuming MAC.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bp_fused_unit import bp_fused_unit
from repro.kernels.bp_gstep import bp_gstep
from repro.kernels.fxp_matmul import fxp_matmul
from repro.kernels.ops import (bp_fused_unit_op, bp_gstep_op, fxp_matmul_op,
                               resolve_double_buffer, tune_blocks, tune_fused,
                               VMEM_BUDGET_BYTES)
from repro.quant.int8 import quantize_int8_auto

jax.config.update("jax_default_matmul_precision", "highest")


def rand(key, shape, scale=1.0):
    return (jax.random.normal(jax.random.key(key), shape) * scale
            ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# bit-exactness vs the single-buffered kernels (emulate + int8)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (32, 48, 24, 16, 8, 8),     # multi-block k (the prefetch loop runs)
    (16, 8, 16, 8, 8, 8),       # single k block (prefetch guard only)
    (64, 32, 32, 16, 16, 16),
])
def test_fxp_matmul_double_buffer_bit_exact(m, k, n, bm, bk, bn):
    x, w = rand(1, (m, k)), rand(2, (k, n))
    kw = dict(xa_bits=(4, 10), w_bits=(2, 12), out_bits=(4, 10), act="relu",
              bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(fxp_matmul(x, w, **kw)),
        np.asarray(fxp_matmul(x, w, double_buffer=True, **kw)))


def test_fxp_matmul_double_buffer_int8_bit_exact():
    x, w = rand(3, (32, 48), 2.0), rand(4, (48, 24), 0.5)
    qx, sx = quantize_int8_auto(x, (4, 10))
    qw, sw = quantize_int8_auto(w, (2, 12))
    kw = dict(out_bits=(4, 10), act="relu", bm=16, bn=8, bk=8,
              interpret=True, datapath="int8", scale=sx * sw)
    np.testing.assert_array_equal(
        np.asarray(fxp_matmul(qx, qw, **kw)),
        np.asarray(fxp_matmul(qx, qw, double_buffer=True, **kw)))


@pytest.mark.parametrize("with_z", [True, False])
def test_bp_gstep_double_buffer_bit_exact(with_z):
    g, w = rand(1, (32, 24)), rand(2, (16, 24))
    z = rand(3, (32, 16)) if with_z else None
    kw = dict(g_bits=(2, 12), act="relu" if with_z else "identity",
              bm=16, bn=8, bk=8, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(bp_gstep(g, w, z, **kw)),
        np.asarray(bp_gstep(g, w, z, double_buffer=True, **kw)))


def test_bp_gstep_double_buffer_bf16_bit_exact():
    """bf16 operands must hit the MXU in bf16 on BOTH fetch paths — the
    DMA slots keep the input dtype, no silent f32 promotion."""
    g = rand(1, (32, 24)).astype(jnp.bfloat16)
    w = rand(2, (16, 24)).astype(jnp.bfloat16)
    z = rand(3, (32, 16))
    kw = dict(g_bits=(2, 12), act="relu", bm=16, bn=8, bk=8, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(bp_gstep(g, w, z, **kw)),
        np.asarray(bp_gstep(g, w, z, double_buffer=True, **kw)))


def test_bp_gstep_double_buffer_int8_bit_exact():
    g, w, z = rand(1, (32, 24)), rand(2, (16, 24)), rand(3, (32, 16))
    qg, sg = quantize_int8_auto(g, (2, 12))
    qw, sw = quantize_int8_auto(w, (2, 12))
    kw = dict(g_bits=(2, 12), act="relu", bm=16, bn=8, bk=8, interpret=True,
              datapath="int8", scale=sg * sw)
    np.testing.assert_array_equal(
        np.asarray(bp_gstep(qg, qw, z, **kw)),
        np.asarray(bp_gstep(qg, qw, z, double_buffer=True, **kw)))


@pytest.mark.parametrize("bt", [8, 32])
def test_bp_fused_unit_double_buffer_bit_exact(bt):
    T, Din, Dout = 32, 16, 24
    g, w = rand(1, (T, Dout)), rand(2, (Din, Dout))
    x, z = rand(3, (T, Din)), rand(4, (T, Din))
    kw = dict(g_bits=(2, 12), w_bits=(2, 12), w_out_bits=(2, 12), act="relu",
              bt=bt, interpret=True)
    a = bp_fused_unit(g, w, x, z, 0.05, **kw)
    b = bp_fused_unit(g, w, x, z, 0.05, double_buffer=True, **kw)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


def test_bp_fused_unit_double_buffer_int8_bit_exact():
    T, Din, Dout = 32, 16, 24
    g, w = rand(1, (T, Dout)), rand(2, (Din, Dout))
    x, z = rand(3, (T, Din)), rand(4, (T, Din))
    qg, sg = quantize_int8_auto(g, (2, 12))
    qx, sx = quantize_int8_auto(x, (4, 10))
    kw = dict(g_bits=(2, 12), w_bits=(2, 12), w_out_bits=(2, 12), act="relu",
              bt=8, interpret=True, datapath="int8", g_scale=sg, x_scale=sx)
    a = bp_fused_unit(qg, w, qx, z, 0.05, **kw)
    b = bp_fused_unit(qg, w, qx, z, 0.05, double_buffer=True, **kw)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


# ---------------------------------------------------------------------------
# the op wrappers accept the knob (jit-static)
# ---------------------------------------------------------------------------

def test_op_wrappers_double_buffer_knob():
    x, w = rand(1, (32, 48)), rand(2, (48, 24))
    base = fxp_matmul_op(x, w, double_buffer=False)
    np.testing.assert_array_equal(
        np.asarray(base), np.asarray(fxp_matmul_op(x, w, double_buffer=True)))
    g, z = rand(3, (32, 24)), rand(4, (32, 16))
    w2 = rand(5, (16, 24))
    np.testing.assert_array_equal(
        np.asarray(bp_gstep_op(g, w2, z, double_buffer=False)),
        np.asarray(bp_gstep_op(g, w2, z, double_buffer=True)))
    xf = rand(6, (32, 16))
    a = bp_fused_unit_op(g, w2, xf, z, 0.05, double_buffer=False)
    b = bp_fused_unit_op(g, w2, xf, z, 0.05, double_buffer=True)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


def test_resolve_double_buffer_platform_default():
    assert resolve_double_buffer(None) is False   # this suite runs on CPU
    assert resolve_double_buffer(True) is True
    assert resolve_double_buffer(False) is False


# ---------------------------------------------------------------------------
# autotuner budgets the second slot
# ---------------------------------------------------------------------------

def test_tune_blocks_double_buffer_budget():
    # a shape where the 2-slot budget forces smaller tiles than 1-slot
    m = n = k = 2048
    db = tune_blocks(m, n, k, itemsize=4, double_buffer=True)
    nb = tune_blocks(m, n, k, itemsize=4, double_buffer=False)
    assert db is not None and nb is not None
    bm, bn, bk = db

    def vmem(blocks, slots):
        bm, bn, bk = blocks
        return slots * (bm * bk + bk * bn) * 4 + bm * bn * 8

    assert vmem(db, 2) <= VMEM_BUDGET_BYTES
    assert vmem(nb, 1) <= VMEM_BUDGET_BYTES
    # the single-buffered choice admits at least as much tile volume
    assert nb[0] * nb[1] * nb[2] >= bm * bn * bk


def test_tune_fused_double_buffer_budget():
    # double-buffering the G/X/Z streams can only shrink the token block
    t, din, dout = 4096, 512, 512
    bt_db = tune_fused(t, din, dout, double_buffer=True)
    bt_nb = tune_fused(t, din, dout, double_buffer=False)
    assert bt_db is not None and bt_nb is not None
    assert bt_nb >= bt_db
