"""Conformance suite for the bitwidth search subsystem (ISSUE 10).

Four contracts:

  * **sweep determinism** — the same ``SweepConfig`` always selects the
    same ``BitPlan`` (probes are seeded, rounding is RNE, selection is
    pure Python);
  * **monotonicity** — widening (I,F) never raises the probe loss beyond
    tolerance (the property that makes greedy narrowest-first selection
    sound);
  * **anneal** — a step built with ``bit_anneal`` equals a step fed
    manually-annealed bits bitwise at every milestone, and a checkpoint
    written mid-ramp resumes bitwise-identically (the ramp is a pure
    function of the restored step);
  * **export parity** — a plan's serving-side int8 numerics (grid
    embedding, KV cache rule, decode prologue) match train-time
    quantization bit-for-bit.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import restore_checkpoint, save_checkpoint
from repro.core.steps import (StepOptions, apply_resume_extra,
                              capture_resume_extra, default_bits,
                              init_train_state, make_train_step)
from repro.core.taxonn import QuantPolicy
from repro.models import lm
from repro.optim import Hyper, OptimizerConfig
from repro.quant import schedule_from_formats
from repro.search import AnnealSchedule, BitPlan
from repro.search import export as bit_export
from repro.search.plan import layer_groups, plan_from_formats
from repro.search.sensitivity import SweepConfig, make_lenet_probe, run_sweep
from test_models import make_batch, tiny

jax.config.update("jax_default_matmul_precision", "highest")

QUICK_SWEEP = SweepConfig(num_groups=2, probe_steps=40, target=0.15,
                          grid=((1, 3), (1, 5), (2, 6), (2, 10)))


# ---------------------------------------------------------------------------
# Sensitivity sweep
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def quick_plan():
    return run_sweep(QUICK_SWEEP)


def test_sweep_deterministic_under_fixed_seed(quick_plan):
    again = run_sweep(QUICK_SWEEP)
    assert again.to_json() == quick_plan.to_json()


def test_sweep_meets_loss_target(quick_plan):
    # the acceptance criterion: the selected plan's end-to-end probe loss
    # lands within the configured target of the f32 baseline
    assert quick_plan.met_target
    assert quick_plan.final_loss <= quick_plan.baseline_loss + \
        quick_plan.target
    assert quick_plan.num_layers == 3  # LeNet hidden stack
    covered = sorted(l for g in quick_plan.groups for l in g.layers)
    assert covered == list(range(quick_plan.num_layers))


def test_sweep_plan_json_roundtrip(quick_plan, tmp_path):
    path = str(tmp_path / "plan.json")
    quick_plan.save(path)
    loaded = BitPlan.load(path)
    assert loaded.to_json() == quick_plan.to_json()
    assert loaded.formats() == quick_plan.formats()


def test_probe_loss_monotone_in_bitwidth():
    """Wider (I,F) never raises the probe loss beyond tolerance."""
    sweep = dataclasses.replace(QUICK_SWEEP, probe_steps=60)
    probe, n = make_lenet_probe(sweep)
    losses = {
        fmt: probe(schedule_from_formats([fmt] * n))
        for fmt in ((1, 3), (2, 6), (2, 12))
    }
    baseline = probe(schedule_from_formats([(2, 12)] * n, enabled=False))
    tol = 0.05
    assert losses[(2, 6)] <= losses[(1, 3)] + tol
    assert losses[(2, 12)] <= losses[(2, 6)] + tol
    # and the wide end of the grid behaves like full precision
    assert losses[(2, 12)] <= baseline + tol


def test_layer_groups_partition():
    assert layer_groups(5, 2) == ((0, 1), (2, 3, 4))
    assert layer_groups(3, 0) == ((0,), (1,), (2,))
    assert layer_groups(4, 7) == ((0,), (1,), (2,), (3,))
    with pytest.raises(ValueError):
        layer_groups(0, 1)


# ---------------------------------------------------------------------------
# Anneal schedules
# ---------------------------------------------------------------------------

def test_anneal_parse_grammar():
    a = AnnealSchedule.parse("0:off, 100:16,400:12")
    assert a.spec == "0:off,100:16,400:12"
    assert a.f_floor_at(0) == -1 and a.f_floor_at(99) == -1
    assert a.f_floor_at(100) == 16 and a.f_floor_at(400) == 12
    assert a.final_step == 400
    assert AnnealSchedule.parse(a) is a  # idempotent

    for bad in ("", "5:12", "0:12,0:10", "0:xyz", "0:12,100:-3", "0:99"):
        with pytest.raises(ValueError):
            AnnealSchedule.parse(bad)


def test_anneal_apply_floors_and_off():
    a = AnnealSchedule.parse("0:off,3:16,7:12")
    sched = schedule_from_formats([(2, 6), (2, 8), (2, 14)])
    off = a.apply(sched, jnp.int32(1))
    assert float(off.enabled) == 0.0
    mid = a.apply(sched, jnp.int32(3))
    assert mid.w_f.tolist() == [16, 16, 16] and float(mid.enabled) == 1.0
    end = a.apply(sched, jnp.int32(50))
    # the floor never NARROWS a layer below its own schedule
    assert end.w_f.tolist() == [12, 12, 14]
    assert end.a_f.tolist() == [12, 12, 14]
    assert end.g_f.tolist() == [12, 12, 14]
    # I bits and the underlying schedule are untouched
    np.testing.assert_array_equal(np.asarray(end.w_i), np.asarray(sched.w_i))
    np.testing.assert_array_equal(np.asarray(sched.w_f),
                                  np.asarray([6, 8, 14]))


def test_step_options_normalizes_anneal_spec():
    opts = StepOptions(bit_anneal="0:16,10:12")
    assert isinstance(opts.bit_anneal, AnnealSchedule)
    assert opts.bit_anneal.spec == "0:16,10:12"
    with pytest.raises(ValueError):
        StepOptions(bit_anneal=123)
    pol = QuantPolicy(bit_anneal="0:16,10:12")
    assert StepOptions.from_policy(pol).bit_anneal.spec == "0:16,10:12"


def _train(step_fn, params, opt, batches, bits, *, start=0, rng_base=None):
    for i, batch in enumerate(batches[start:], start=start):
        hyper = Hyper(lr=jnp.float32(0.05), step=jnp.int32(i))
        rng = (jax.random.fold_in(rng_base, i)
               if rng_base is not None else None)
        params, opt, _ = step_fn(params, opt, batch, hyper, bits, rng)
    return params, opt


def test_anneal_step_matches_manual_bits_bitwise():
    """A step built with bit_anneal == the same step fed manually-annealed
    bits, at every milestone — so the ramp composes with the engine (scan,
    stochastic rounding, kernel paths) with no special cases."""
    spec = "0:off,2:14,5:10"
    cfg = tiny("dense")
    policy = QuantPolicy(grad_scale=8.0)
    ocfg = OptimizerConfig(kind="sgd")
    annealed = jax.jit(make_train_step(
        cfg, policy, ocfg, StepOptions(bit_anneal=spec)))
    manual = jax.jit(make_train_step(cfg, policy, ocfg, StepOptions()))
    assert annealed.bit_anneal.spec == spec

    sched = AnnealSchedule.parse(spec)
    bits = default_bits(cfg, enabled=True)
    params = lm.init_params(jax.random.key(0), cfg)
    opt = init_train_state(params, ocfg)
    batch = make_batch(cfg, b=2, t=16)
    for step in (0, 1, 2, 4, 5, 9):
        hyper = Hyper(lr=jnp.float32(0.05), step=jnp.int32(step))
        pa, oa, ma = annealed(params, opt, batch, hyper, bits)
        pm, om, mm = manual(params, opt, batch, hyper,
                            sched.apply_tree(bits, step))
        for a, m in zip(jax.tree.leaves((pa, oa)), jax.tree.leaves((pm, om))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(m))
        np.testing.assert_array_equal(np.asarray(ma["loss"]),
                                      np.asarray(mm["loss"]))


def test_anneal_resume_bitwise_mid_ramp(tmp_path):
    """Checkpoint in the middle of the F-bit ramp, restart, and the
    continuation is bitwise identical to the uninterrupted run — annealed
    bits are a pure function of the (restored) step."""
    spec = "0:14,3:12,7:10"
    cfg = tiny("dense")
    policy = QuantPolicy(grad_scale=8.0, stochastic=True)
    ocfg = OptimizerConfig(kind="sgd")
    step_fn = jax.jit(make_train_step(
        cfg, policy, ocfg, StepOptions(bit_anneal=spec)))
    bits = default_bits(cfg, enabled=True)
    batches = [make_batch(cfg, b=2, t=16, key=i) for i in range(10)]
    rng_base = jax.random.key(7)

    params0 = lm.init_params(jax.random.key(0), cfg)
    opt0 = init_train_state(params0, ocfg)

    # uninterrupted: 10 steps straight through the 3->7 milestones
    p_full, o_full = _train(step_fn, params0, opt0, batches, bits,
                            rng_base=rng_base)

    # interrupted: stop at step 5 (mid-ramp), checkpoint, restore, continue
    p_half, o_half = _train(step_fn, params0, opt0, batches[:5], bits,
                            rng_base=rng_base)
    ckpt_dir = str(tmp_path / "ckpt")
    extra = capture_resume_extra(cfg, 5, anneal=spec)
    assert extra["bit_anneal"] == spec
    save_checkpoint(ckpt_dir, 5, (p_half, o_half), extra=extra)
    (p_res, o_res), ckpt_step, extra_r = restore_checkpoint(
        ckpt_dir, (p_half, o_half))
    start = apply_resume_extra(extra_r, cfg, ckpt_step, anneal=spec)
    assert start == 5
    p_resumed, o_resumed = _train(step_fn, p_res, o_res, batches, bits,
                                  start=start, rng_base=rng_base)

    for a, b in zip(jax.tree.leaves((p_full, o_full)),
                    jax.tree.leaves((p_resumed, o_resumed))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_anneal_resume_guard():
    cfg = tiny("dense")
    extra = capture_resume_extra(cfg, 5, anneal="0:14,3:12")
    # same spec: fine
    assert apply_resume_extra(extra, cfg, 5, anneal="0:14,3:12") == 5
    # different ramp: refuse (the bit schedule would jump mid-run)
    with pytest.raises(ValueError, match="annealed under"):
        apply_resume_extra(extra, cfg, 5, anneal="0:16,3:12")
    # dropping the anneal at resume: loud warning, not silent drift
    with pytest.warns(RuntimeWarning, match="bit-anneal mismatch"):
        apply_resume_extra(extra, cfg, 5)
    # plain checkpoints resumed plainly stay silent
    plain = capture_resume_extra(cfg, 5)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert apply_resume_extra(plain, cfg, 5) == 5


# ---------------------------------------------------------------------------
# Export path: train <-> serve int8 parity
# ---------------------------------------------------------------------------

EXPORT_PLAN = plan_from_formats([(2, 5), (1, 6), (2, 12), (4, 10)])


def test_export_parity_bit_for_bit():
    res = bit_export.verify_train_serve_parity(EXPORT_PLAN)
    assert res["ok"], res
    assert res["grid_msb_max_diff"] == 0.0
    assert res["grid_exact_max_diff"] == 0.0
    assert res["kv_payload_max_diff"] == 0
    assert res["kv_scale_max_diff"] == 0.0
    assert res["prologue_max_diff"] == 0.0


def test_export_grid_embedding_exact_below_int8():
    """bitwidth <= 8 formats embed exactly: serve-side dequantization is
    the identity on train-quantized tensors."""
    from repro.quant import dequantize_int8, quantize, quantize_int8_fxp

    i_b, f_b = 2, 5  # bitwidth 8
    x = jax.random.uniform(jax.random.key(3), (1024,), jnp.float32, -6.0, 6.0)
    x_q = quantize(x, i_b, f_b)
    payload, scale = quantize_int8_fxp(x_q, i_b, f_b)
    np.testing.assert_array_equal(
        np.asarray(dequantize_int8(payload, scale)), np.asarray(x_q))


def test_export_kv_rule_matches_engine():
    from repro.serving import engine

    x = 3.0 * jax.random.normal(jax.random.key(4), (32, 4, 16), jnp.float32)
    q_eng, s_eng = engine.quant_kv_rows(x)
    q_exp, s_exp = bit_export.kv_reference(x)
    assert q_eng.dtype == jnp.int8 and q_exp.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q_eng), np.asarray(q_exp))
    np.testing.assert_array_equal(np.asarray(s_eng), np.asarray(s_exp))


def test_serve_plan_rendering_and_roundtrip(tmp_path):
    sp = bit_export.to_serve_plan(EXPORT_PLAN)
    by_layer = {l.layer: l for l in sp.layers}
    assert by_layer[0].mode == "fxp" and by_layer[0].exact       # (2,5) -> bw 8
    assert by_layer[1].mode == "fxp" and by_layer[1].exact       # (1,6) -> bw 8
    assert by_layer[2].mode == "absmax" and by_layer[2].shift == 7  # (2,12)
    assert by_layer[2].eff_f_bits == 5
    assert sp.serve_config_kwargs() == {"cache_dtype": jnp.int8}

    path = str(tmp_path / "serve.json")
    bit_export.save_serve_plan(sp, path)
    assert bit_export.load_serve_plan(path).to_json() == sp.to_json()

    # I > 7 cannot keep its MSBs in int8
    with pytest.raises(ValueError, match="I > 7"):
        bit_export.to_serve_plan(plan_from_formats([(8, 4)]))


def test_sweep_plan_exports_with_parity(quick_plan):
    """End to end: the searched plan itself exports and passes parity."""
    sp = bit_export.to_serve_plan(quick_plan)
    assert len(sp.layers) == quick_plan.num_layers
    bit_export.assert_parity(quick_plan)
