"""Transport autotuner + compressed reduce-scatter ring + depth-D overlap.

Covers dist.async_collectives.decide_transport (cached decision stability,
the REPRO_TRANSPORT override, model fallback inside a trace), the psum
transport's bit-exactness vs the blocking path, the compressed RS ring's
error bound vs compressed_psum on a 4-device mesh, the single-device /
empty-axes no-op short-circuit, the multi-process guard, and the
overlap_depth pipeline's exactness across depths.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.core.taxonn import overlap_depth_for
from repro.dist.async_collectives import (TRANSPORTS, all_reduce_start,
                                          all_reduce_wait,
                                          clear_transport_cache,
                                          decide_transport,
                                          dump_transport_cache,
                                          prime_transport_cache,
                                          transport_cache_snapshot,
                                          tree_all_reduce_start,
                                          tree_all_reduce_wait)
from repro.models import lm
from repro.optim import Hyper, OptimizerConfig
from test_models import make_batch, tiny
from test_overlap import run_py


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_transport_cache()
    yield
    clear_transport_cache()


# ---------------------------------------------------------------------------
# decide_transport: cache, override, model fallback
# ---------------------------------------------------------------------------

def test_decision_is_cached_and_stable():
    """Same (size-bucket, group) must return the same transport on every
    call, and near-identical sizes share one cached decision."""
    first = decide_transport(4 << 20, 4)
    assert first in TRANSPORTS
    snap = transport_cache_snapshot()
    assert len(snap) == 1
    for _ in range(5):
        assert decide_transport(4 << 20, 4) == first
    # same power-of-two bucket -> cache hit, no new entry
    assert decide_transport((4 << 20) - 128, 4) == first
    assert len(transport_cache_snapshot()) == 1
    # a different group size is a different decision key
    decide_transport(4 << 20, 2)
    assert len(transport_cache_snapshot()) == 2


def test_repro_transport_override(monkeypatch):
    """REPRO_TRANSPORT forces the decision past cache and measurement."""
    # host-CPU measured composite: never the ppermute ring
    assert decide_transport(1 << 20, 4) in ("psum", "scatter")
    monkeypatch.setenv("REPRO_TRANSPORT", "ring")
    assert decide_transport(1 << 20, 4) == "ring"
    monkeypatch.setenv("REPRO_TRANSPORT", "psum")
    assert decide_transport(1 << 20, 4) == "psum"
    monkeypatch.setenv("REPRO_TRANSPORT", "scatter")
    assert decide_transport(1 << 20, 4) == "scatter"
    # the compressed wire format has no scatter split: degrades to psum
    assert decide_transport(1 << 20, 4, compressed=True) == "psum"
    monkeypatch.setenv("REPRO_TRANSPORT", "auto")
    assert decide_transport(1 << 20, 4) in TRANSPORTS
    monkeypatch.setenv("REPRO_TRANSPORT", "carrier-pigeon")
    with pytest.raises(ValueError, match="REPRO_TRANSPORT"):
        decide_transport(1 << 20, 4)


def test_model_fallback_inside_trace():
    """Inside a jit trace no micro-benchmark can run: the decision must
    come from the platform model (scatter for dense payloads on host-CPU
    — blocking reduce-scatter enabling the sharded update — psum for the
    compressed wire format), not crash."""
    picked = []

    @jax.jit
    def f(x):
        picked.append(decide_transport(x.size * 4, 4))
        picked.append(decide_transport(x.size * 4, 4, compressed=True))
        return x + 1.0

    f(jnp.zeros((1024,)))
    assert picked == ["scatter", "psum"]
    snap = transport_cache_snapshot()
    assert all(v["source"] == "model" for v in snap.values())


def test_single_member_group_is_psum_no_cache():
    assert decide_transport(4 << 20, 1) == "psum"
    assert transport_cache_snapshot() == {}


def test_prime_and_dump_cache(tmp_path):
    out = prime_transport_cache([1 << 16, (1 << 16) - 5, 1 << 20], g=2)
    assert set(out.values()) <= set(TRANSPORTS)
    assert len(out) == 2            # the two distinct size buckets
    path = tmp_path / "cache.json"
    dump_transport_cache(str(path))
    data = json.loads(path.read_text())
    assert len(data) == 2
    for rec in data.values():
        assert rec["transport"] in TRANSPORTS
        assert rec["source"] in ("measured", "model")


def test_invalid_transport_argument():
    x = jnp.ones((8,))
    with pytest.raises(ValueError, match="transport"):
        all_reduce_start(x, ("data",), num_replicas=4, transport="tcp")


# ---------------------------------------------------------------------------
# no-op short-circuit + multi-process guard (satellite)
# ---------------------------------------------------------------------------

def test_no_axes_short_circuits_to_identity_handle():
    x = jnp.arange(12.0, dtype=jnp.float32)
    for kwargs in ({"axes": ()}, {"axes": ("data",), "num_replicas": 1}):
        h = all_reduce_start(x, transport="ring", **kwargs)
        assert h.kind == "identity"
        np.testing.assert_array_equal(np.asarray(all_reduce_wait(h)),
                                      np.asarray(x))
    # and the compiled module contains NO collective ops
    hlo = jax.jit(
        lambda v: all_reduce_wait(all_reduce_start(v, ()))
    ).lower(x).compile().as_text()
    assert "collective-permute" not in hlo and "all-reduce" not in hlo


def test_multi_process_ring_raises_clear_error(monkeypatch):
    """A ring spanning a multi-process runtime must fail with a clear
    NotImplementedError at start, not a shape error mid-hop."""
    monkeypatch.setattr(jax, "process_count", lambda: 2)
    x = jnp.ones((512,))
    with pytest.raises(NotImplementedError, match="single-process"):
        all_reduce_start(x, ("data",), num_replicas=4, transport="ring")
    # the fused psum transport stays available (it raises no guard here;
    # the collective itself needs a mesh, so just check the guard is not
    # hit before transport dispatch)
    with pytest.raises(NotImplementedError, match="single-process"):
        tree_all_reduce_start({"w": x}, ("data",), num_replicas=4,
                              transport="ring")


# ---------------------------------------------------------------------------
# transports on a live 4-device mesh (subprocess)
# ---------------------------------------------------------------------------

def test_autotuned_matches_forced_psum_bitwise_dense():
    """The full train step with transport='auto' must be BITWISE identical
    to transport='psum' on the dense path: on host-CPU devices the
    autotuner picks blocking transports at every bucket (psum, or scatter
    whose sharded sgd update is elementwise on chunks whose reduced
    values match the XLA CPU all-reduce bit-for-bit), so both steps land
    the same same-iteration updates."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig()
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.01), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    mesh = jax.make_mesh((4,), ("data",))

    def run(transport):
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          dw_psum_axes=("data",), dw_num_replicas=4,
                          overlap="on", dw_transport=transport)
        step = make_train_step(cfg, pol, ocfg)
        f = jax.shard_map(lambda p, s, b: step(p, s, b, hyper, bits),
                          mesh=mesh, in_specs=(P(), P(), P("data")),
                          out_specs=(P(), P(), P()), check_vma=False)
        return jax.jit(f)(params, state, batch)

    p_auto, s_auto, m_auto = run("auto")
    p_psum, s_psum, m_psum = run("psum")
    assert float(m_auto["loss"]) == float(m_psum["loss"])
    for a, b in zip(jax.tree.leaves((p_auto, s_auto)),
                    jax.tree.leaves((p_psum, s_psum))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("AUTO=PSUM OK")
    """)
    assert "AUTO=PSUM OK" in out


def test_compressed_rs_ring_error_bound_vs_compressed_psum():
    """The decompress-add-recompress reduce-scatter ring must agree with
    compressed_psum within the documented bound: each side performs at
    most 2g-2 extra codec half-steps, so |err| <= (2g-2)*max_absmax/254
    with absmax of the largest partial sum."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.async_collectives import ring_all_reduce
    from repro.dist.collectives import compressed_psum
    from repro.quant.compression import BLOCK

    mesh = jax.make_mesh((4,), ("data",))
    g = 4
    x = jnp.asarray(np.random.default_rng(7).standard_normal((g, 2048)),
                    jnp.float32)

    def run(fn):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P("data"),
                                     out_specs=P("data"),
                                     check_vma=False))(x)

    ref = np.asarray(run(lambda v: compressed_psum(v, ("data",),
                                                   num_replicas=g)))
    ring = np.asarray(run(lambda v: ring_all_reduce(
        v, ("data",), num_replicas=g, compressed=True, transport="ring")))
    exact = np.asarray(run(lambda v: jax.lax.psum(v, "data")))

    # every device's result must be the same reduced tensor
    assert np.abs(ring[0] - ring[1]).max() == 0.0

    # documented bound: (2g-2) codec half-steps of the largest partial sum
    # (use the exact sum's blockwise absmax as the partial-sum proxy, x2
    # slack for intermediate partials exceeding the final sum's absmax)
    pad = (-exact.size) % BLOCK
    blocks = np.pad(exact.reshape(-1), (0, pad)).reshape(-1, BLOCK)
    bound = 2 * (2 * g - 2) * np.abs(blocks).max() / 254.0
    err = np.abs(ring - ref).max()
    assert err <= bound, (err, bound)
    # and it is a real all-reduce: close to the exact dense sum too
    assert np.abs(ring - exact).max() <= bound
    print("RSRING OK", err, bound)
    """)
    assert "RSRING OK" in out


def test_forced_ring_env_matches_blocking_on_step():
    """REPRO_TRANSPORT=ring must force the chunked ring through the full
    overlapped step and still match the blocking psum step to 1e-5."""
    out = run_py("""
    import os
    os.environ["REPRO_TRANSPORT"] = "ring"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig()
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.01), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    mesh = jax.make_mesh((4,), ("data",))

    def run(overlap):
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          dw_psum_axes=("data",), dw_num_replicas=4,
                          overlap=overlap)
        step = make_train_step(cfg, pol, ocfg)
        f = jax.shard_map(lambda p, s, b: step(p, s, b, hyper, bits),
                          mesh=mesh, in_specs=(P(), P(), P("data")),
                          out_specs=(P(), P(), P()), check_vma=False)
        return jax.jit(f)(params, state, batch)

    p_off, _, m_off = run("off")
    p_on, _, m_on = run("on")
    assert float(m_off["loss"]) == float(m_on["loss"])
    worst = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(p_off), jax.tree.leaves(p_on)))
    assert worst < 1e-5, worst
    print("ENVRING OK", worst)
    """)
    assert "ENVRING OK" in out


def test_scatter_transport_matches_psum_bitwise():
    """wait(start(x, transport='scatter')) — native reduce-scatter + chunk
    carry + all-gather — must equal lax.psum bit-for-bit on the CPU
    backend, for odd sizes (padding) and multi-axis groups."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.async_collectives import (all_reduce_start,
                                              all_reduce_wait)

    for mesh_shape, names in (((4,), ("data",)), ((2, 2), ("pipe", "data"))):
        mesh = jax.make_mesh(mesh_shape, names)
        x = jax.random.normal(jax.random.key(0), (37, 19))  # pads to 4|n

        def f(v):
            h = all_reduce_start(v, names, num_replicas=4,
                                 transport="scatter")
            assert h.kind == "scatter"
            return all_reduce_wait(h)

        a = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(),
                                  out_specs=P(), check_vma=False))(x)
        b = jax.jit(jax.shard_map(lambda v: jax.lax.psum(v, names),
                                  mesh=mesh, in_specs=P(), out_specs=P(),
                                  check_vma=False))(x)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("SCATTER OK")
    """)
    assert "SCATTER OK" in out


def test_forced_scatter_sharded_update_matches_psum_step():
    """REPRO_TRANSPORT=scatter routes EVERY dW leaf through the sharded
    sgd update (reduce-scatter, update the 1/g chunk, all-gather updated
    params); params AND the grad-norm metric (device-local chunk squares
    closed by a scalar psum) must match the forced-psum step bitwise on
    this backend."""
    out = run_py("""
    import os
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig()            # sgd: sharded-update eligible
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.01), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    mesh = jax.make_mesh((4,), ("data",))

    def run(transport):
        os.environ["REPRO_TRANSPORT"] = transport
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          dw_psum_axes=("data",), dw_num_replicas=4,
                          overlap="on")
        step = make_train_step(cfg, pol, ocfg)
        f = jax.shard_map(lambda p, s, b: step(p, s, b, hyper, bits),
                          mesh=mesh, in_specs=(P(), P(), P("data")),
                          out_specs=(P(), P(), P()), check_vma=False)
        return jax.jit(f)(params, state, batch)

    p_sc, _, m_sc = run("scatter")
    p_ps, _, m_ps = run("psum")
    assert float(m_sc["loss"]) == float(m_ps["loss"])
    assert float(m_sc["grad_norm"]) == float(m_ps["grad_norm"]), (
        float(m_sc["grad_norm"]), float(m_ps["grad_norm"]))
    for a, b in zip(jax.tree.leaves(p_sc), jax.tree.leaves(p_ps)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("SHARDED OK")
    """)
    assert "SHARDED OK" in out


def test_scatter_degrades_to_blocking_update_for_stateful_optimizer():
    """momentum is not sharded-update eligible (its state would need
    gathering too): with scatter decided everywhere the overlapped step
    must degrade to the fused blocking update and still match the off
    scan bitwise."""
    out = run_py("""
    import os
    os.environ["REPRO_TRANSPORT"] = "scatter"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig(kind="momentum")
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.01), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    mesh = jax.make_mesh((4,), ("data",))

    def run(overlap):
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          dw_psum_axes=("data",), dw_num_replicas=4,
                          overlap=overlap)
        step = make_train_step(cfg, pol, ocfg)
        f = jax.shard_map(lambda p, s, b: step(p, s, b, hyper, bits),
                          mesh=mesh, in_specs=(P(), P(), P("data")),
                          out_specs=(P(), P(), P()), check_vma=False)
        return jax.jit(f)(params, state, batch)

    p_off, s_off, m_off = run("off")
    p_on, s_on, m_on = run("on")
    assert float(m_off["loss"]) == float(m_on["loss"])
    worst = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves((p_off, s_off)),
                    jax.tree.leaves((p_on, s_on))))
    assert worst < 1e-6, worst
    print("DEGRADE OK", worst)
    """)
    assert "DEGRADE OK" in out


# ---------------------------------------------------------------------------
# depth-D overlap pipeline exactness
# ---------------------------------------------------------------------------

def test_overlap_depth_clamps_to_layer_count():
    pol = QuantPolicy(overlap_depth=2)
    assert overlap_depth_for(pol, 6) == 2
    assert overlap_depth_for(pol, 2) == 2
    assert overlap_depth_for(pol, 1) == 1
    assert overlap_depth_for(QuantPolicy(overlap_depth=5), 3) == 3
    with pytest.raises(ValueError, match="overlap_depth"):
        overlap_depth_for(QuantPolicy(overlap_depth=0), 4)


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_overlap_depth_bit_exact_single_device(depth):
    """Every pipeline depth is a pure schedule change on one device: the
    handles are identities, so params/opt must be BITWISE equal to the
    blocking scan regardless of how many scan steps the wait lags."""
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    ocfg = OptimizerConfig(kind="momentum")
    bits = default_bits(cfg, enabled=True)
    hyper = Hyper(lr=jnp.float32(0.05), step=jnp.int32(0))
    state = init_train_state(params, ocfg)

    def run(overlap, d):
        pol = QuantPolicy(grad_scale=16.0, quantize_updates=True,
                          overlap=overlap, overlap_depth=d)
        step = jax.jit(make_train_step(cfg, pol, ocfg))
        return step(params, state, batch, hyper, bits)

    p0, s0, m0 = run("off", depth)
    p1, s1, m1 = run("on", depth)
    assert float(m0["loss"]) == float(m1["loss"])
    for a, b in zip(jax.tree.leaves((p0, s0)), jax.tree.leaves((p1, s1))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_overlap_depth_2_multi_device_ring_matches_blocking():
    """Two in-flight ring handles on a 4-device mesh (forced ring so the
    autotuner cannot collapse the pipeline to identity handles): the
    2-deep drain + ys realignment must agree with the blocking scan."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import QuantPolicy, StepOptions, make_train_step
    from repro.core.steps import default_bits, init_train_state
    from repro.models import lm
    from repro.optim import Hyper, OptimizerConfig
    from test_models import make_batch, tiny

    cfg = tiny("dense")     # 2 layers: depth 2 == full drain-from-flush
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=8, t=32)
    ocfg = OptimizerConfig()
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.01), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    mesh = jax.make_mesh((4,), ("data",))

    def run(overlap, depth):
        pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                          quantize_grads=False, kernel_backend="off",
                          dw_psum_axes=("data",), dw_num_replicas=4,
                          overlap=overlap, overlap_depth=depth,
                          dw_transport="ring")
        step = make_train_step(cfg, pol, ocfg)
        f = jax.shard_map(lambda p, s, b: step(p, s, b, hyper, bits),
                          mesh=mesh, in_specs=(P(), P(), P("data")),
                          out_specs=(P(), P(), P()), check_vma=False)
        return jax.jit(f)(params, state, batch)

    p_off, _, m_off = run("off", 2)
    for depth in (1, 2):
        p_on, _, m_on = run("on", depth)
        assert float(m_off["loss"]) == float(m_on["loss"])
        worst = max(float(jnp.abs(a - b).max()) for a, b in
                    zip(jax.tree.leaves(p_off), jax.tree.leaves(p_on)))
        assert worst < 1e-5, (depth, worst)
        print(f"depth={depth} worst={worst:.2e}")
    print("DEPTH OK")
    """)
    assert "DEPTH OK" in out


def test_make_train_step_transport_override():
    with pytest.raises(ValueError, match="transport"):
        make_train_step(tiny("dense"), QuantPolicy.off(), OptimizerConfig(),
                        StepOptions(transport="smoke-signal"))
    # a valid override lands in the policy and the step still trains
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    ocfg = OptimizerConfig()
    step = jax.jit(make_train_step(cfg, QuantPolicy.off(), ocfg,
                                   StepOptions(overlap="on",
                                               transport="psum")))
    _, _, m = step(params, init_train_state(params, ocfg),
                   make_batch(cfg, t=32),
                   Hyper(lr=jnp.float32(0.01), step=jnp.int32(0)),
                   default_bits(cfg, enabled=False))
    assert np.isfinite(float(m["loss"]))
