"""KernelBackend wiring: the dense unit's custom_vjp vs autodiff, the
train/serve hot paths across backends (off == emulate to float tolerance;
int8 within quantization tolerance), the LeNet-5 kernel-datapath trainer,
and the compressed-dW engine flag."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.lenet import (init_lenet_params, lenet_bits, lenet_bits_off,
                              make_lenet_train_step)
from repro.core.steps import default_bits, init_train_state
from repro.configs.lenet5 import LeNetConfig
from repro.kernels.ops import kernel_backend_ctx, resolve_backend
from repro.models import layers as L, lm
from repro.optim import Hyper, OptimizerConfig
from repro.serving import engine as E

from test_models import tiny, make_batch

jax.config.update("jax_default_matmul_precision", "highest")


def _max_param_diff(pa, pb):
    flat_b = {jax.tree_util.keystr(k): v
              for k, v in jax.tree_util.tree_leaves_with_path(pb)}
    worst = 0.0
    for k, v in jax.tree_util.tree_leaves_with_path(pa):
        ref = flat_b[jax.tree_util.keystr(k)]
        worst = max(worst, float(jnp.max(jnp.abs(
            v.astype(jnp.float32) - ref.astype(jnp.float32)))))
    return worst


# ---------------------------------------------------------------------------
# dense_unit: custom_vjp vs autodiff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ["identity", "relu", "gelu", "silu"])
def test_dense_unit_emulate_matches_autodiff(act):
    x = jax.random.normal(jax.random.key(0), (4, 16, 32))
    w = jax.random.normal(jax.random.key(1), (32, 24)) * 0.2
    dy = jax.random.normal(jax.random.key(2), (4, 16, 24))

    def f_ref(x, w):
        h = x.reshape(-1, 32) @ w
        from repro.kernels.common import act_fn
        return jnp.sum(act_fn(h, act).reshape(4, 16, 24) * dy)

    def f_unit(x, w):
        with kernel_backend_ctx("emulate"):
            return jnp.sum(L.dense_unit(x, w, act) * dy)

    y_ref, (dx_ref, dw_ref) = jax.value_and_grad(f_ref, argnums=(0, 1))(x, w)
    y_u, (dx_u, dw_u) = jax.value_and_grad(f_unit, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(float(y_u), float(y_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dx_u), np.asarray(dx_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dw_u), np.asarray(dw_ref),
                               atol=1e-4, rtol=1e-4)


def _cos(a, b):
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-30))


@pytest.mark.parametrize("act", ["identity", "relu"])
def test_dense_unit_int8_within_quant_tolerance(act):
    x = jax.random.normal(jax.random.key(0), (64, 32))
    w = jax.random.normal(jax.random.key(1), (32, 24)) * 0.2
    dy = jax.random.normal(jax.random.key(2), (64, 24))

    def f(x, w, backend):
        with kernel_backend_ctx(backend):
            return jnp.sum(L.dense_unit(x, w, act) * dy)

    y8, (dx8, dw8) = jax.value_and_grad(
        lambda a, b: f(a, b, "int8"), argnums=(0, 1))(x, w)
    yr, (dxr, dwr) = jax.value_and_grad(
        lambda a, b: f(a, b, "off"), argnums=(0, 1))(x, w)
    assert abs(float(y8) - float(yr)) <= 0.05 * abs(float(yr)) + 0.5
    # gradients point the same way (relu: the quantized forward can flip
    # the derivative mask where z ~ 0, so elementwise bounds only hold for
    # the mask-free identity case)
    assert _cos(dx8, dxr) > 0.97
    assert _cos(dw8, dwr) > 0.97
    if act == "identity":
        scale = float(jnp.max(jnp.abs(dxr)))
        np.testing.assert_allclose(np.asarray(dx8), np.asarray(dxr),
                                   atol=0.05 * scale + 0.05, rtol=0.5)
        scale = float(jnp.max(jnp.abs(dwr)))
        np.testing.assert_allclose(np.asarray(dw8), np.asarray(dwr),
                                   atol=0.05 * scale + 0.05, rtol=0.5)


def test_dense_unit_off_is_plain_matmul():
    x = jax.random.normal(jax.random.key(0), (8, 16))
    w = jax.random.normal(jax.random.key(1), (16, 8))
    y = L.dense_unit(x, w, "identity")  # no ctx: backend off
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Train step: backends agree on a small LM config
# ---------------------------------------------------------------------------

def _run_step(cfg, backend, steps=2):
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    ocfg = OptimizerConfig()
    bits = default_bits(cfg, enabled=False)
    step = jax.jit(make_train_step(cfg, QuantPolicy.off(), ocfg,
                                   StepOptions(kernel_backend=backend)))
    p, o = params, init_train_state(params, ocfg)
    m = None
    for s in range(steps):
        hyper = Hyper(lr=jnp.float32(0.05), step=jnp.int32(s))
        p, o, m = step(p, o, batch, hyper, bits)
    return p, m


def test_train_step_emulate_matches_off():
    p_off, m_off = _run_step(tiny("dense"), "off")
    p_emu, m_emu = _run_step(tiny("dense"), "emulate")
    assert float(m_emu["loss"]) == pytest.approx(float(m_off["loss"]),
                                                 rel=1e-4)
    assert _max_param_diff(p_emu, p_off) < 5e-4


def test_train_step_int8_within_quant_tolerance():
    p_off, m_off = _run_step(tiny("dense"), "off", steps=1)
    p_i8, m_i8 = _run_step(tiny("dense"), "int8", steps=1)
    assert float(m_i8["loss"]) == pytest.approx(float(m_off["loss"]), rel=0.05)
    assert _max_param_diff(p_i8, p_off) < 0.05


def test_backend_keeps_bits_as_runtime_data():
    """One compiled emulate-backend step must still serve every schedule."""
    from repro.quant import make_bit_schedule
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    ocfg = OptimizerConfig()
    step = jax.jit(make_train_step(cfg, QuantPolicy(), ocfg,
                                   StepOptions(kernel_backend="emulate")))
    hyper = Hyper(lr=jnp.float32(0.1), step=jnp.int32(0))
    state = init_train_state(params, ocfg)
    step(params, state, batch, hyper,
         {"blocks": make_bit_schedule(cfg.num_layers, weight=(2, 12))})
    step(params, state, batch, hyper,
         {"blocks": make_bit_schedule(cfg.num_layers, weight=(1, 4))})
    assert step._cache_size() == 1


# ---------------------------------------------------------------------------
# LeNet-5: the full kernel pipeline (acceptance config)
# ---------------------------------------------------------------------------

LENET = LeNetConfig(input_dim=64, hidden=32, num_layers=5, num_classes=10)


def _lenet_data():
    x = jax.random.normal(jax.random.key(1), (64, LENET.input_dim))
    y = jax.random.randint(jax.random.key(2), (64,), 0, LENET.num_classes)
    return x, y


@pytest.mark.parametrize("bits_on", [False, True])
def test_lenet_emulate_matches_off(bits_on):
    bits = lenet_bits(5) if bits_on else lenet_bits_off(5)
    params = init_lenet_params(jax.random.key(0), LENET)
    batch = _lenet_data()
    s_off = jax.jit(make_lenet_train_step(LENET, bits, "off"))
    s_emu = jax.jit(make_lenet_train_step(LENET, bits, "emulate"))
    p0, m0 = s_off(params, batch, 0.1)
    p1, m1 = s_emu(params, batch, 0.1)
    assert float(m1["loss"]) == pytest.approx(float(m0["loss"]), rel=1e-5)
    assert _max_param_diff(p1, p0) < 2e-5


def test_lenet_int8_close_and_descends():
    bits = lenet_bits(5)
    params = init_lenet_params(jax.random.key(0), LENET)
    batch = _lenet_data()
    s_off = jax.jit(make_lenet_train_step(LENET, bits, "off"))
    s_i8 = jax.jit(make_lenet_train_step(LENET, bits, "int8"))
    p0, m0 = s_off(params, batch, 0.1)
    p8, m8 = s_i8(params, batch, 0.1)
    assert float(m8["loss"]) == pytest.approx(float(m0["loss"]), rel=0.05)
    assert _max_param_diff(p8, p0) < 0.05
    # and the int8 datapath must actually train
    losses = []
    p = params
    for _ in range(25):
        p, m = s_i8(p, batch, 0.2)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.8, losses


# ---------------------------------------------------------------------------
# Serving: prefill on the kernel datapath
# ---------------------------------------------------------------------------

def test_prefill_backends_agree():
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    l_off, s_off = E.prefill(params, cfg, batch, max_len=64,
                             kernel_backend="off")
    l_emu, s_emu = E.prefill(params, cfg, batch, max_len=64,
                             kernel_backend="emulate")
    l_i8, _ = E.prefill(params, cfg, batch, max_len=64, kernel_backend="int8")
    np.testing.assert_allclose(np.asarray(l_emu), np.asarray(l_off),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(l_i8), np.asarray(l_off),
                               atol=0.5, rtol=0.3)
    for k in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(s_emu["caches"][k], np.float32),
            np.asarray(s_off["caches"][k], np.float32), atol=1e-2)


def test_generate_on_kernel_backend():
    """Prefill through the kernels, decode on the jnp path: same tokens."""
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=16)
    t_off = E.greedy_generate(params, cfg, batch, max_len=32, num_steps=4,
                              kernel_backend="off")
    t_emu = E.greedy_generate(params, cfg, batch, max_len=32, num_steps=4,
                              kernel_backend="emulate")
    np.testing.assert_array_equal(np.asarray(t_off), np.asarray(t_emu))


# ---------------------------------------------------------------------------
# compressed dW wire format inside the backward scan
# ---------------------------------------------------------------------------

def test_compress_dw_flag_roundtrips_updates():
    cfg = tiny("dense")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    ocfg = OptimizerConfig()
    bits = default_bits(cfg, enabled=False)
    hyper = Hyper(lr=jnp.float32(0.05), step=jnp.int32(0))
    state = init_train_state(params, ocfg)

    base = jax.jit(make_train_step(cfg, QuantPolicy.off(), ocfg))
    pol = QuantPolicy(quantize_weights=False, quantize_acts=False,
                      quantize_grads=False, kernel_backend="off",
                      compress_dw=True)
    comp = jax.jit(make_train_step(cfg, pol, ocfg))
    p0, _, m0 = base(params, state, batch, hyper, bits)
    p1, _, m1 = comp(params, state, batch, hyper, bits)
    # forward identical; dW differs by <= lr * absmax_block/127/2 per element
    assert float(m1["loss"]) == pytest.approx(float(m0["loss"]), rel=1e-6)
    diff = _max_param_diff(p1, p0)
    assert 0.0 < diff < 1e-2, diff


def test_matrix_leg_backend_trains(kernel_backend):
    """The CI-matrix leg's datapath (REPRO_KERNEL_BACKEND via the conftest
    fixture) must run the train + serve hot paths end-to-end, so the
    no-kernel and int8 paths can't silently rot on any leg."""
    cfg = tiny("dense")
    p, m = _run_step(cfg, kernel_backend, steps=1)
    assert np.isfinite(float(m["loss"])), kernel_backend
    logits, _ = E.prefill(lm.init_params(jax.random.key(0), cfg), cfg,
                          make_batch(cfg, t=16), max_len=32,
                          kernel_backend=kernel_backend)
    assert bool(jnp.all(jnp.isfinite(logits))), kernel_backend


def test_resolve_backend_auto_off_on_cpu():
    assert resolve_backend("auto") == "off"  # this suite runs on CPU
    assert resolve_backend(None) == "off"
    assert resolve_backend("emulate") == "emulate"
    with pytest.raises(ValueError):
        resolve_backend("bogus")
