"""Per-architecture smoke tests: reduced config of the same family, one
forward + one TaxoNN train step on CPU, asserting shapes + finiteness.

The FULL assigned configs are exercised via the dry-run only (see
launch/dryrun.py); these reduced twins keep every family-specific code path
(MLA, MoE routing, SSD, shared-attn groups, enc-dec, VLM concat) covered by
fast CPU tests.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, input_specs, SHAPE_CELLS
from repro.core import QuantPolicy, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.models import lm
from repro.models.config import ModelConfig, cell_is_applicable
from repro.optim import Hyper, OptimizerConfig


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink to test scale, preserving family + feature flags."""
    changes = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.family != "hybrid" else 4),
        d_model=64,
        vocab_size=256,
        compute_dtype="float32",
    )
    if cfg.num_heads:
        kv = max(1, min(cfg.num_kv_heads, 2))
        heads = 4 if cfg.num_heads >= 4 else cfg.num_heads
        if cfg.num_kv_heads == cfg.num_heads:
            kv = heads
        changes.update(num_heads=heads, num_kv_heads=kv, head_dim=16)
    if cfg.d_ff:
        changes.update(d_ff=128)
    if cfg.family == "moe":
        changes.update(num_experts=4,
                       experts_per_token=min(cfg.experts_per_token, 2),
                       moe_d_ff=32)
    if cfg.use_mla:
        changes.update(kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                       v_head_dim=16)
    if cfg.family in ("ssm", "hybrid"):
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        changes.update(num_layers=4, attn_every=2)
    if cfg.family == "encdec":
        changes.update(num_encoder_layers=2, encoder_seq=16)
    if cfg.family == "vlm":
        changes.update(num_patches=8)
    if cfg.swa_window:
        changes.update(swa_window=16)
    return dataclasses.replace(cfg, **changes)


def reduced_batch(cfg: ModelConfig, b=2, t=24, key=0):
    ks = jax.random.split(jax.random.key(key), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, t), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[3], (b, cfg.num_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(arch):
    full = get_config(arch)
    cfg = reduce_config(full)
    assert cfg.family == full.family
    params = lm.init_params(jax.random.key(0), cfg)
    batch = reduced_batch(cfg)

    # forward: hidden states have the right shape and are finite
    x = lm.forward_hidden(params, cfg, batch)
    t_expect = batch["tokens"].shape[1] + (
        cfg.num_patches if cfg.family == "vlm" else 0)
    assert x.shape == (2, t_expect, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(x, np.float32)))

    # one TaxoNN train step with the paper-style bit schedule enabled
    ocfg = OptimizerConfig(kind="sgd")
    step = jax.jit(make_train_step(cfg, QuantPolicy(grad_scale=16.0), ocfg))
    state = init_train_state(params, ocfg)
    bits = default_bits(cfg, enabled=True)
    hyper = Hyper(lr=jnp.float32(0.01), step=jnp.int32(0))
    new_params, _, metrics = step(params, state, batch, hyper, bits)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a - b_))), params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_specs_are_lazy(arch):
    """Full configs must be constructible as specs without any allocation."""
    from repro.configs import param_specs
    cfg = get_config(arch)
    specs = param_specs(cfg)
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs))
    # sanity: assigned sizes are in the expected ballpark
    expected = cfg.param_count()
    assert abs(n - expected) / expected < 0.05, (arch, n, expected)
    for cell in SHAPE_CELLS:
        if not cell_is_applicable(cfg, cell):
            continue
        sp = input_specs(cfg, cell.name)
        assert all(hasattr(s, "shape") for s in jax.tree.leaves(sp))


def test_param_counts_match_model_class():
    """Rough scale check against public parameter counts."""
    expected_b = {
        "h2o-danube-3-4b": (3.0, 5.0),
        "gemma-7b": (7.5, 9.5),       # 8.5B with its 256k embed
        "qwen1.5-0.5b": (0.4, 0.7),
        "yi-34b": (30.0, 38.0),
        "deepseek-v2-lite-16b": (14.0, 18.0),
        "mixtral-8x7b": (42.0, 50.0),
        "whisper-tiny": (0.02, 0.06),
        "mamba2-370m": (0.3, 0.45),
        "llava-next-mistral-7b": (6.5, 8.0),
        "zamba2-2.7b": (2.2, 3.2),
    }
    for arch, (lo, hi) in expected_b.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"
