"""Model substrate tests: layer oracles, family forwards, gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm, layers as L, ssm as S
from repro.models.config import ModelConfig

jax.config.update("jax_default_matmul_precision", "highest")

V = 128


def tiny(family="dense", **kw):
    base = dict(
        name=f"t-{family}", family=family, num_layers=2, d_model=32,
        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=V,
        compute_dtype="float32",
    )
    if family == "moe":
        base.update(num_kv_heads=4, d_ff=0, num_experts=4, experts_per_token=2,
                    num_shared_experts=1, moe_d_ff=48)
    if family == "ssm":
        base.update(num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=16,
                    ssm_head_dim=8, ssm_chunk=16)
    if family == "hybrid":
        base.update(num_layers=4, num_kv_heads=4, ssm_state=16, ssm_head_dim=8,
                    ssm_chunk=16, attn_every=2)
    if family == "encdec":
        base.update(num_kv_heads=4, num_encoder_layers=2, encoder_seq=20,
                    use_rope=False, norm_kind="layernorm", mlp_kind="gelu")
    if family == "vlm":
        base.update(num_patches=8)
    base.update(kw)
    return ModelConfig(**base)


def make_batch(cfg, b=2, t=64, key=0):
    ks = jax.random.split(jax.random.key(key), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, t), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (b, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(ks[3], (b, cfg.num_patches, cfg.d_model))
    return batch


FAMILIES = ["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@pytest.mark.parametrize("family", FAMILIES)
def test_forward_and_grad_finite(family):
    cfg = tiny(family)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert np.all(np.isfinite(np.asarray(g))), f"non-finite grad at {path}"
    # embedding must receive gradient (checks the whole chain is connected)
    assert float(jnp.abs(grads["embed"]).sum()) > 0


def test_chunked_attention_matches_full():
    """Online-softmax scan attention == full softmax attention."""
    cfg = tiny("dense", swa_window=None)
    key = jax.random.key(1)
    b, t, h, hd = 2, 128, 4, 8
    q, k, v = (jax.random.normal(ks, (b, t, h, hd)) for ks in jax.random.split(key, 3))
    mask = L._attn_mask(t, t, True, None)
    full = L._sdpa_full(q, k, v, mask, hd ** -0.5)
    import repro.models.layers as layers_mod
    old = layers_mod.ATTN_KV_BLOCK
    layers_mod.ATTN_KV_BLOCK = 32  # force multiple blocks
    try:
        chunked = L._sdpa_chunked(q, k, v, True, None, hd ** -0.5)
    finally:
        layers_mod.ATTN_KV_BLOCK = old
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=2e-5, rtol=2e-5)


def test_chunked_attention_swa_matches_full():
    key = jax.random.key(2)
    b, t, h, hd, w = 1, 96, 2, 8, 24
    q, k, v = (jax.random.normal(ks, (b, t, h, hd)) for ks in jax.random.split(key, 3))
    mask = L._attn_mask(t, t, True, w)
    full = L._sdpa_full(q, k, v, mask, hd ** -0.5)
    import repro.models.layers as layers_mod
    old = layers_mod.ATTN_KV_BLOCK
    layers_mod.ATTN_KV_BLOCK = 16
    try:
        chunked = L._sdpa_chunked(q, k, v, True, w, hd ** -0.5)
    finally:
        layers_mod.ATTN_KV_BLOCK = old
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=2e-5, rtol=2e-5)


def test_ssd_chunked_matches_naive_recurrence():
    """Chunked SSD == step-by-step h_t = exp(dt*A) h + dt*B x recurrence."""
    key = jax.random.key(3)
    b, t, h, p, n = 2, 32, 3, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, t, n))
    Cm = jax.random.normal(ks[4], (b, t, n))

    y_chunk, hT = S.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)

    # naive reference
    hstate = np.zeros((b, h, n, p))
    ys = np.zeros((b, t, h, p))
    xn, dtn, An, Bn, Cn = map(np.asarray, (x, dt, A, Bm, Cm))
    for i in range(t):
        decay = np.exp(dtn[:, i] * An)  # [b,h]
        hstate = hstate * decay[:, :, None, None] + np.einsum(
            "bn,bh,bhp->bhnp", Bn[:, i], dtn[:, i], xn[:, i])
        ys[:, i] = np.einsum("bn,bhnp->bhp", Cn[:, i], hstate)
    np.testing.assert_allclose(np.asarray(y_chunk), ys, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), hstate, atol=1e-4, rtol=1e-4)


def test_ssd_initial_state_stitching():
    """Running two halves with carried state == running the full sequence."""
    key = jax.random.key(4)
    b, t, h, p, n = 1, 32, 2, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, t, n))
    Cm = jax.random.normal(ks[4], (b, t, n))
    y_full, h_full = S.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    m = t // 2
    y1, h1 = S.ssd_chunked(x[:, :m], dt[:, :m], A, Bm[:, :m], Cm[:, :m], chunk=8)
    y2, h2 = S.ssd_chunked(x[:, m:], dt[:, m:], A, Bm[:, m:], Cm[:, m:], chunk=8,
                           h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, :m]), np.asarray(y1),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y_full[:, m:]), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               atol=1e-4, rtol=1e-4)


def test_rope_rotation_property():
    """RoPE: relative-position property <q_i, k_j> depends only on i-j."""
    hd = 8
    q = jax.random.normal(jax.random.key(5), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.key(6), (1, 1, 1, hd))

    def dot_at(pi, pj):
        qi = L.apply_rope(q, jnp.array([[pi]]), 10_000.0)
        kj = L.apply_rope(k, jnp.array([[pj]]), 10_000.0)
        return float(jnp.sum(qi * kj))

    assert dot_at(3, 1) == pytest.approx(dot_at(12, 10), abs=1e-4)
    assert dot_at(7, 7) == pytest.approx(dot_at(0, 0), abs=1e-4)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= E/K (full capacity) MoE output must equal the
    dense-per-token expert mixture (no drops)."""
    cfg = tiny("moe", capacity_factor=4.0)  # C >= n*K/E * 4: no drops
    key = jax.random.key(7)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(8), (2, 16, cfg.d_model))
    out, aux = L.moe(p, x, cfg)

    # dense reference: every token through its top-k experts
    tokens = np.asarray(x.reshape(-1, cfg.d_model))
    logits = tokens @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = np.asarray(top_p / top_p.sum(-1, keepdims=True))
    top_e = np.asarray(top_e)
    wg, wu, wd = map(np.asarray, (p["w_gate"], p["w_up"], p["w_down"]))

    def silu(v):
        return v / (1 + np.exp(-v))

    ref = np.zeros_like(tokens)
    for i in range(tokens.shape[0]):
        for j in range(cfg.experts_per_token):
            e = top_e[i, j]
            h = silu(tokens[i] @ wg[e]) * (tokens[i] @ wu[e])
            ref[i] += top_p[i, j] * (h @ wd[e])
    sh = p["shared"]
    ref += (silu(tokens @ np.asarray(sh["w_gate"])) * (tokens @ np.asarray(sh["w_up"]))) @ np.asarray(sh["w_down"])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model), ref,
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_vlm_loss_ignores_patches():
    cfg = tiny("vlm")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=32)
    loss, _ = lm.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    # hidden slice: [B, Np + T] -> text part starts at Np
    x = lm.forward_hidden(params, cfg, batch)
    assert x.shape[1] == cfg.num_patches + 32


def test_ce_loss_chunking_invariance():
    """Chunked CE == unchunked CE regardless of chunk size."""
    cfg = tiny("dense", logit_chunk=16)
    cfg_big = tiny("dense", logit_chunk=4096)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, t=48)  # not divisible by 16*2 -> tests padding
    l1, _ = lm.loss_fn(params, cfg, batch)
    l2, _ = lm.loss_fn(params, cfg_big, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)


def test_param_count_estimate_close():
    for family in FAMILIES:
        cfg = tiny(family)
        params = lm.init_params(jax.random.key(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.15, (family, est, actual)
