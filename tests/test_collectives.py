"""Round-trip and agreement tests for repro.dist.collectives."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import (compressed_psum, compressed_psum_tree,
                                    dense_psum_tree)
from repro.quant.compression import BLOCK, compress_int8, decompress_int8

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 2, timeout=300):
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT/'src'}:{ROOT/'tests'}",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Codec error bound (pure, in-process)
# ---------------------------------------------------------------------------

def test_int8_roundtrip_blockwise_error_bound():
    rng = np.random.default_rng(3)
    x = (rng.standard_normal(3 * BLOCK + 17) * 5.0).astype(np.float32)
    payload, scales = compress_int8(jnp.asarray(x))
    assert payload.dtype == jnp.int8
    y = np.asarray(decompress_int8(payload, scales, x.shape))
    # per-block: |err| <= absmax_block / 127 / 2 (round-to-nearest)
    pad = (-x.size) % BLOCK
    xb = np.pad(x, (0, pad)).reshape(-1, BLOCK)
    eb = np.pad(x - y, (0, pad)).reshape(-1, BLOCK)
    tol = np.abs(xb).max(axis=1, keepdims=True) / 127.0 * 0.5 + 1e-7
    assert np.all(np.abs(eb) <= tol)


def test_compressed_vs_dense_single_replica():
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(
        np.random.default_rng(0).standard_normal((32, 16)), jnp.float32),
        "b": {"v": jnp.linspace(-2.0, 2.0, 300, dtype=jnp.float32)}}
    dense = dense_psum_tree(g, mesh, ("data",))
    comp = compressed_psum_tree(g, mesh, ("data",))
    # one replica: dense is exact, compressed carries only codec error
    for k, leaf in (("w", g["w"]), ("v", g["b"]["v"])):
        d = dense["w"] if k == "w" else dense["b"]["v"]
        c = comp["w"] if k == "w" else comp["b"]["v"]
        np.testing.assert_array_equal(np.asarray(d), np.asarray(leaf))
        tol = float(jnp.abs(leaf).max()) / 127.0
        assert float(jnp.abs(d - c).max()) <= tol + 1e-6


# ---------------------------------------------------------------------------
# 2-replica agreement (subprocess: needs 2 devices)
# ---------------------------------------------------------------------------

def test_compressed_vs_dense_two_replicas():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType
from repro.dist.collectives import compressed_psum_tree, dense_psum_tree

mesh = jax.make_mesh((2,), ("data",), axis_types=(AxisType.Auto,))
g = {"w": jnp.asarray(np.random.default_rng(1).standard_normal((64, 8)),
                      jnp.float32)}
dense = dense_psum_tree(g, mesh, ("data",))
comp = compressed_psum_tree(g, mesh, ("data",))
# replicated input, 2 replicas -> dense == 2*g exactly
np.testing.assert_allclose(np.asarray(dense["w"]), 2 * np.asarray(g["w"]),
                           rtol=0, atol=0)
# compressed: each replica contributes <= one half-step of codec error
err = np.abs(np.asarray(dense["w"]) - np.asarray(comp["w"]))
tol = 2 * np.abs(np.asarray(g["w"])).max() / 127.0
assert err.max() <= tol + 1e-6, (err.max(), tol)
print("PSUM2 OK")
""")
    assert "PSUM2 OK" in out


def test_compressed_psum_no_mesh_honors_num_replicas():
    """The codec-roundtrip path must simulate the n-replica sum of a
    replicated value (n * decompress(compress(x))), matching what the mesh
    path returns for the same replicated input."""
    x = jnp.asarray(np.random.default_rng(2).standard_normal((40, 9)),
                    jnp.float32)
    one = compressed_psum(x, ())
    for n in (None, 1):
        np.testing.assert_array_equal(
            np.asarray(compressed_psum(x, (), num_replicas=n)),
            np.asarray(one))
    four = compressed_psum(x, (), num_replicas=4)
    np.testing.assert_allclose(np.asarray(four), 4.0 * np.asarray(one),
                               rtol=1e-6, atol=1e-6)


def test_dense_psum_inside_jit_grad_path():
    """dense_psum_tree must compose with jit (the backward scan issues it
    inside a compiled step)."""
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.ones((8, 8), jnp.float32)}
    out = jax.jit(lambda t: dense_psum_tree(t, mesh, ("data",)))(g)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((8, 8)))
