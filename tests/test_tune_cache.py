"""Persistent kernel tune cache (kernels.ops), modeled on test_transport.

Covers: decision stability through the cache, snapshot/load round-trip
with ``restored:`` provenance, the no-clobber rule (existing entries win
unless overwrite), the dump/REPRO_TUNE_CACHE file path, malformed-entry
tolerance, driver priming (train + serve shape sets), and the replay
guarantees through checkpoint resume ``extra`` and the paged serve
snapshot.
"""
import json

import jax
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels.ops import (clear_tune_cache, dump_tune_cache,
                               load_tune_cache, prime_tune_cache,
                               serve_tune_shapes, train_tune_shapes,
                               tune_blocks, tune_cache_snapshot,
                               tune_prologue)
from repro.models import lm
from test_models import tiny


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_tune_cache()
    yield
    clear_tune_cache()


# ---------------------------------------------------------------------------
# Decisions are cached and stable
# ---------------------------------------------------------------------------

def test_decision_is_cached_and_stable():
    first = tune_blocks(32, 16, 48)
    assert first == (32, 16, 48)
    snap = tune_cache_snapshot()
    assert len(snap) == 1
    (key, entry), = snap.items()
    assert key.startswith("kind=blocks,m=32,n=16,k=48")
    assert entry["source"] == "computed"
    for _ in range(3):
        assert tune_blocks(32, 16, 48) == first
    assert len(tune_cache_snapshot()) == 1


def test_negative_decisions_are_cached_too():
    assert tune_blocks(7, 16, 48) is None          # no aligned divisor of 7
    assert tune_prologue(30, 4, 2, 30) is None     # misaligned head dim
    snap = tune_cache_snapshot()
    assert len(snap) == 2
    assert all(e["decision"] is None for e in snap.values())


# ---------------------------------------------------------------------------
# Snapshot / load: provenance, no-clobber, overwrite
# ---------------------------------------------------------------------------

def test_snapshot_load_roundtrip_with_restored_provenance():
    want = tune_blocks(32, 16, 48)
    pro = tune_prologue(64, 4, 2, 16)
    snap = tune_cache_snapshot()
    clear_tune_cache()
    assert tune_cache_snapshot() == {}
    assert load_tune_cache(snap) == len(snap)
    # restored decisions replay identically and carry provenance
    assert tune_blocks(32, 16, 48) == want
    assert tune_prologue(64, 4, 2, 16) == pro
    after = tune_cache_snapshot()
    assert after.keys() == snap.keys()
    assert all(e["source"] == "restored:computed" for e in after.values())


def test_load_does_not_clobber_unless_overwrite():
    tune_blocks(32, 16, 48)
    snap = tune_cache_snapshot()
    (key, entry), = snap.items()
    fake = {key: {"decision": [8, 8, 8], "source": "computed"}}
    assert load_tune_cache(fake) == 0              # existing entry wins
    assert tune_blocks(32, 16, 48) == (32, 16, 48)
    assert load_tune_cache(fake, overwrite=True) == 1
    assert tune_blocks(32, 16, 48) == (8, 8, 8)


def test_malformed_entries_are_skipped():
    good = {"kind=blocks,m=32,n=16,k=48,item=4,acc=4,db=True":
            {"decision": [32, 16, 48], "source": "computed"}}
    bad = {"not-a-key": {"decision": 1, "source": "x"},
           "kind=unknown,z=1": {"decision": 1, "source": "x"},
           "kind=blocks,m=oops,n=16,k=48,item=4,acc=4,db=True":
           {"decision": [8], "source": "x"}}
    assert load_tune_cache({**bad, **good}) == 1
    assert tune_blocks(32, 16, 48) == (32, 16, 48)


# ---------------------------------------------------------------------------
# Dump / REPRO_TUNE_CACHE preload
# ---------------------------------------------------------------------------

def test_dump_and_env_preload(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    # write a dump whose decision DIFFERS from what the tuner would derive,
    # so a cache hit is observable
    tune_blocks(32, 16, 48)
    snap = tune_cache_snapshot()
    (key, _), = snap.items()
    dump_tune_cache(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == snap
    on_disk[key]["decision"] = [8, 8, 8]
    path.write_text(json.dumps(on_disk))

    clear_tune_cache()
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    monkeypatch.setattr(kops, "_TUNE_ENV_LOADED", False)
    assert tune_blocks(32, 16, 48) == (8, 8, 8)    # env decision, not derived
    (key2, entry), = tune_cache_snapshot().items()
    assert key2 == key and entry["source"] == "restored:computed"


# ---------------------------------------------------------------------------
# Driver priming
# ---------------------------------------------------------------------------

def test_prime_train_and_serve_shapes():
    cfg = tiny()
    primed = prime_tune_cache(train_tune_shapes(cfg, 8, 64))
    assert primed and all(k.startswith("kind=") for k in primed)
    primed_s = prime_tune_cache(serve_tune_shapes(
        cfg, num_blocks=17, block_size=8, max_blocks_per_seq=4))
    assert any(k.startswith("kind=paged") for k in primed_s)
    assert any(k.startswith("kind=prologue") for k in primed_s)
    # priming again is pure cache hits: snapshot unchanged
    before = tune_cache_snapshot()
    prime_tune_cache(train_tune_shapes(cfg, 8, 64))
    assert tune_cache_snapshot() == before


# ---------------------------------------------------------------------------
# Replay through checkpoint resume extra and the serve snapshot
# ---------------------------------------------------------------------------

def test_checkpoint_extra_replays_tune_decisions(capsys):
    from repro.core.steps import apply_resume_extra, capture_resume_extra
    cfg = tiny()
    want = tune_blocks(32, 16, 48)
    extra = capture_resume_extra(cfg, 5)
    assert extra["tune_cache"]
    clear_tune_cache()
    assert apply_resume_extra(extra, cfg, 5) == 5
    assert "restored 1 tune-cache decision(s)" in capsys.readouterr().out
    assert tune_blocks(32, 16, 48) == want
    snap = tune_cache_snapshot()
    assert all(e["source"] == "restored:computed" for e in snap.values())


def test_serve_snapshot_replays_tune_decisions():
    from repro.serving import (BatchScheduler, EngineHooks, Request,
                               ServeConfig)
    cfg = tiny()
    params = lm.init_params(jax.random.key(0), cfg)
    sc = ServeConfig(num_slots=2, eos_id=None, max_len=32, mode="paged",
                     block_size=8, cache_dtype="float32",
                     kernel_backend="emulate")
    hooks = EngineHooks.for_model(params, cfg, sc)
    s = BatchScheduler(sc, hooks)
    rng = np.random.default_rng(3)
    s.submit(Request(uid=0,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         size=(9,)).astype(np.int32),
                     max_new_tokens=4))
    for _ in range(3):
        s.step()
    snap = s.snapshot()
    assert np.asarray(snap["tune_cache"]).size    # decisions rode along
    primed = tune_cache_snapshot()
    assert primed                                  # the fused decode tuned

    clear_tune_cache()
    restored = BatchScheduler.restore(snap, hooks=hooks)
    assert restored.config.kernel_backend == "emulate"
    after = tune_cache_snapshot()
    assert after.keys() == primed.keys()
    assert all(e["source"].startswith("restored:") for e in after.values())
    # the decisions themselves replay bit-for-bit
    assert {k: e["decision"] for k, e in after.items()} \
        == {k: e["decision"] for k, e in primed.items()}
