"""Fault-injection harness + checkpoint integrity/recovery unit drills.

The end-to-end kill/restart drills live in tests/test_recovery_drills.py;
this file proves each mechanism in isolation: FaultPlan determinism, the
checkpoint layer's checksum/verify/fallback/retry story, AsyncCheckpointer
lifecycle, loader exception propagation and step-tag reconciliation, and
the resume-extra capture/apply round trip.
"""
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.ckpt import (AsyncCheckpointer, latest_step, latest_valid_step,
                        list_steps, restore_checkpoint, save_checkpoint,
                        verify_checkpoint)
from repro.data import DataProducerError, StragglerTolerantLoader
from repro.ft import FAULT_EXIT_CODE, FaultPlan, flip_one_bit


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_fault_plan_parse_and_describe():
    p = FaultPlan.parse("crash@12;io@8x2;fsync@9;rename@9;stall@5:0.25;"
                        "flip@10;seed=7")
    assert p.seed == 7
    assert p.crash_step() == 12
    assert p.flip_steps() == [10]
    kinds = sorted(e.kind for e in p.events)
    assert kinds == ["crash", "flip", "fsync", "io", "rename", "stall"]
    assert "io@8x2" in p.describe()


def test_fault_plan_seeded_random_crash_step_is_deterministic():
    a = FaultPlan.parse("crash@rand:8-20;seed=5").crash_step()
    b = FaultPlan.parse("crash@rand:8-20;seed=5").crash_step()
    c = FaultPlan.parse("crash@rand:8-20;seed=6").crash_step()
    assert a == b and 8 <= a < 20
    assert any(FaultPlan.parse(f"crash@rand:8-20;seed={s}").crash_step() != a
               for s in range(10))  # the range is actually sampled
    assert 8 <= c < 20


def test_fault_plan_bad_specs_rejected():
    for bad in ("crash12", "io@x", "boom@3", "crash@rand:9-9"):
        with pytest.raises(ValueError):
            FaultPlan.parse(bad)


def test_fault_plan_env_and_flag(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_PLAN", "crash@3")
    assert FaultPlan.from_env(None).crash_step() == 3
    assert FaultPlan.from_env("crash@9").crash_step() == 9  # flag wins
    monkeypatch.delenv("REPRO_FAULT_PLAN")
    assert FaultPlan.from_env(None) is None


def test_ckpt_fault_budget_is_transient():
    p = FaultPlan.parse("io@4x2")
    with pytest.raises(OSError):
        p.ckpt_fault("io", 4)
    with pytest.raises(OSError):
        p.ckpt_fault("io", 4)
    p.ckpt_fault("io", 4)       # budget exhausted: no-op
    p.ckpt_fault("io", 5)       # other steps never fire
    p.ckpt_fault("fsync", 4)    # other kinds never fire
    assert p.fired == [("io", 4), ("io", 4)]


def test_wrap_fetch_stalls_only_the_planned_step():
    p = FaultPlan.parse("stall@2:0.2")
    fetch = p.wrap_fetch(lambda s: {"x": np.full((2,), s)})
    t0 = time.monotonic()
    fetch(1)
    fast = time.monotonic() - t0
    t0 = time.monotonic()
    out = fetch(2)
    slow = time.monotonic() - t0
    assert slow >= 0.2 > fast
    assert out["x"][0] == 2
    assert ("stall", 2) in p.fired


# ---------------------------------------------------------------------------
# Checkpoint integrity: checksums, verify, fallback, retry
# ---------------------------------------------------------------------------

def test_manifest_carries_checksums_and_verify_passes(tmp_path):
    save_checkpoint(tmp_path, 3, tree())
    assert verify_checkpoint(tmp_path, 3) == []
    assert latest_valid_step(tmp_path) == 3


def test_verify_detects_bit_flip(tmp_path):
    save_checkpoint(tmp_path, 3, tree())
    name = flip_one_bit(tmp_path, 3, seed=0)
    assert name is not None
    problems = verify_checkpoint(tmp_path, 3)
    assert problems and "crc32 mismatch" in problems[0]
    assert latest_valid_step(tmp_path) is None


def test_restore_falls_back_past_corrupt_latest(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 2, t)
    flip_one_bit(tmp_path, 2, seed=1)
    assert latest_step(tmp_path) == 2           # pointer still says 2
    assert latest_valid_step(tmp_path) == 1     # integrity says otherwise
    with pytest.warns(RuntimeWarning, match="failed verification"):
        restored, step, _ = restore_checkpoint(tmp_path, t)
    assert step == 1
    for a, b in zip(np.asarray(restored["a"]).ravel(),
                    np.asarray(t["a"]).ravel()):
        assert a == b


def test_restore_pinned_corrupt_step_raises(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 2, t)
    flip_one_bit(tmp_path, 2, seed=1)
    with pytest.raises(ValueError, match="failed verification"):
        restore_checkpoint(tmp_path, t, step=2)
    # the valid pinned step still loads
    _, step, _ = restore_checkpoint(tmp_path, t, step=1)
    assert step == 1


def test_restore_all_corrupt_raises_with_history(tmp_path):
    t = tree()
    for s in (1, 2):
        save_checkpoint(tmp_path, s, t)
        flip_one_bit(tmp_path, s, seed=s)
    with pytest.warns(RuntimeWarning):
        with pytest.raises(FileNotFoundError, match="no valid checkpoint"):
            restore_checkpoint(tmp_path, t)


def test_missing_latest_pointer_falls_back_to_dirs(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 2, t)
    (tmp_path / "LATEST").unlink()
    assert latest_step(tmp_path) == 2
    assert list_steps(tmp_path) == [1, 2]
    _, step, _ = restore_checkpoint(tmp_path, t)
    assert step == 2


def test_save_retries_transient_io_failures(tmp_path):
    plan = FaultPlan.parse("io@5x2")
    with pytest.warns(RuntimeWarning, match="retrying"):
        save_checkpoint(tmp_path, 5, tree(), fault=plan.ckpt_fault,
                        backoff_s=0.01)
    assert plan.fired == [("io", 5), ("io", 5)]
    assert verify_checkpoint(tmp_path, 5) == []


def test_save_retries_fsync_and_rename_failures(tmp_path):
    plan = FaultPlan.parse("fsync@6x1;rename@6x1")
    with pytest.warns(RuntimeWarning, match="retrying"):
        save_checkpoint(tmp_path, 6, tree(), fault=plan.ckpt_fault,
                        backoff_s=0.01)
    assert ("fsync", 6) in plan.fired and ("rename", 6) in plan.fired
    assert verify_checkpoint(tmp_path, 6) == []


def test_save_exhausts_retries_and_raises(tmp_path):
    plan = FaultPlan.parse("io@7x99")
    with pytest.warns(RuntimeWarning):
        with pytest.raises(OSError, match="injected io failure"):
            save_checkpoint(tmp_path, 7, tree(), fault=plan.ckpt_fault,
                            retries=2, backoff_s=0.01)
    # the failed write never became visible
    assert latest_step(tmp_path) is None


# ---------------------------------------------------------------------------
# AsyncCheckpointer lifecycle
# ---------------------------------------------------------------------------

def test_async_checkpointer_close_flushes_final_write(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    ck.save(3, tree())
    ck.close()  # no wait(): close must join the in-flight write
    assert latest_step(tmp_path) == 3
    ck.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        ck.save(4, tree())


def test_async_checkpointer_context_manager(tmp_path):
    with AsyncCheckpointer(tmp_path) as ck:
        ck.save(1, tree())
    assert latest_step(tmp_path) == 1


def test_async_checkpointer_close_reraises_background_error(tmp_path):
    plan = FaultPlan.parse("io@2x99")
    ck = AsyncCheckpointer(tmp_path / "sub", fault=plan.ckpt_fault)
    with pytest.warns(RuntimeWarning):
        ck.save(2, tree())
        with pytest.raises(OSError, match="injected io failure"):
            ck.close()
    # after surfacing, the error is cleared and close stays idempotent
    ck.close()


def test_async_checkpointer_fault_threads_through(tmp_path):
    plan = FaultPlan.parse("io@3x1")
    with pytest.warns(RuntimeWarning, match="retrying"):
        with AsyncCheckpointer(tmp_path, fault=plan.ckpt_fault) as ck:
            ck.save(3, tree())
            ck.wait()
    assert latest_step(tmp_path) == 3  # one transient failure absorbed


# ---------------------------------------------------------------------------
# StragglerTolerantLoader: exception propagation + step-tag reconciliation
# ---------------------------------------------------------------------------

def test_loader_propagates_producer_exception():
    def fetch(step):
        if step == 2:
            raise RuntimeError("disk on fire")
        return {"x": np.full((2,), step)}

    loader = StragglerTolerantLoader(fetch, deadline_s=2.0, prefetch=1)
    try:
        assert loader.get(0)["x"][0] == 0
        assert loader.get(1)["x"][0] == 1
        with pytest.raises(DataProducerError, match="disk on fire"):
            loader.get(2)
        # latched: every later get re-raises instead of serving stale data
        with pytest.raises(DataProducerError):
            loader.get(3)
    finally:
        loader.close()


def test_loader_discards_late_batch_for_skipped_step():
    gate = threading.Event()

    def fetch(step):
        if step == 2:
            gate.wait(5.0)  # straggler, released mid-test
        return {"x": np.full((2,), step)}

    loader = StragglerTolerantLoader(fetch, deadline_s=0.25, prefetch=1)
    try:
        assert loader.get(0)["x"][0] == 0
        assert loader.get(1)["x"][0] == 1
        sub = loader.get(2)           # deadline hit: substitute last batch
        assert sub["x"][0] == 1 and loader.skips == 1
        gate.set()                    # the late batch for step 2 now lands
        got = loader.get(3)           # ... and must be DISCARDED, not served
        assert got["x"][0] == 3
        assert loader.stale_drops >= 1
    finally:
        loader.close()


def test_loader_start_step_resumes_stream():
    loader = StragglerTolerantLoader(
        lambda s: {"x": np.full((2,), s)}, deadline_s=5.0, start_step=10)
    try:
        assert loader.get(10)["x"][0] == 10
        assert loader.get(11)["x"][0] == 11
    finally:
        loader.close()


# ---------------------------------------------------------------------------
# Resume-extra capture/apply + transport-cache persistence
# ---------------------------------------------------------------------------

def test_transport_cache_snapshot_load_roundtrip():
    from repro.dist.async_collectives import (
        clear_transport_cache, decide_transport, load_transport_cache,
        transport_cache_snapshot)
    clear_transport_cache()
    try:
        fake = {"compressed=False,bytes=4096,g=8":
                {"transport": "ring", "source": "measured", "us": {}}}
        assert load_transport_cache(fake) == 1
        # cache hit wins over the platform model (which would say psum on
        # CPU) and over measurement (g=8 exceeds the host's devices anyway)
        assert decide_transport(3000, 8) == "ring"
        snap = transport_cache_snapshot()
        key = "compressed=False,bytes=4096,g=8"
        assert snap[key]["transport"] == "ring"
        assert snap[key]["source"].startswith("restored:")
        # existing entries are not clobbered without overwrite
        fake2 = {key: {"transport": "psum", "source": "measured", "us": {}}}
        assert load_transport_cache(fake2) == 0
        assert decide_transport(3000, 8) == "ring"
        assert load_transport_cache(fake2, overwrite=True) == 1
        assert decide_transport(3000, 8) == "psum"
        # malformed entries are skipped, not fatal
        assert load_transport_cache({"garbage": {"transport": "ring"},
                                     key: {"transport": "warp"}}) == 0
    finally:
        clear_transport_cache()


def test_capture_and_apply_resume_extra(tmp_path):
    from repro.configs import get_config
    from repro.core.steps import apply_resume_extra, capture_resume_extra
    from repro.dist.async_collectives import (clear_transport_cache,
                                              decide_transport,
                                              load_transport_cache)
    cfg = get_config("qwen1.5-0.5b")
    clear_transport_cache()
    try:
        load_transport_cache({"compressed=False,bytes=8192,g=4":
                              {"transport": "ring", "source": "measured"}})
        loader = StragglerTolerantLoader(
            lambda s: {"x": np.zeros(2)}, deadline_s=2.0)
        loader.get(0)
        extra = capture_resume_extra(cfg, 7, loader=loader,
                                     user_extra={"loss": 1.5})
        loader.close()
        assert extra["arch"] == cfg.name and extra["data_step"] == 7
        assert extra["loss"] == 1.5
        assert extra["loader"]["served"] == 1
        assert "compressed=False,bytes=8192,g=4" in extra["transport_cache"]

        # must round-trip the checkpoint manifest (msgpack)
        save_checkpoint(tmp_path, 7, tree(), extra=extra)
        _, _, extra2 = restore_checkpoint(tmp_path, tree())

        clear_transport_cache()
        step = apply_resume_extra(extra2, cfg, 7)
        assert step == 7
        assert decide_transport(8000, 4) == "ring"  # reinstalled
    finally:
        clear_transport_cache()

    other = get_config("gemma-7b")
    with pytest.raises(ValueError, match="refusing to resume"):
        apply_resume_extra({"arch": cfg.name}, other, 7)
    # pre-schema checkpoints fall back to the checkpoint step
    assert apply_resume_extra({}, cfg, 9) == 9


def test_fault_exit_code_is_distinct():
    assert FAULT_EXIT_CODE not in (0, 1, 2)
