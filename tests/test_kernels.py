"""Pallas kernel validation: interpret-mode vs pure-jnp oracles, sweeping
shapes, block sizes, dtypes, activation kinds and bit formats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.bp_gstep import bp_gstep
from repro.kernels.fxp_matmul import fxp_matmul
from repro.kernels.sgd_dw_update import sgd_dw_update
from repro.kernels.ops import bp_gstep_op, fxp_matmul_op, sgd_dw_update_op

jax.config.update("jax_default_matmul_precision", "highest")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.key(key), shape) * scale).astype(dtype)


SHAPES_MM = [
    (16, 16, 16, 8, 8, 8),      # multi-block every dim
    (32, 16, 48, 16, 16, 16),   # rectangular
    (8, 8, 8, 8, 8, 8),         # single block
    (64, 32, 16, 16, 8, 16),    # wide M
]


@pytest.mark.parametrize("m,k,n,bm,bk,bn", SHAPES_MM)
@pytest.mark.parametrize("act", ["identity", "relu"])
def test_fxp_matmul_blocks(m, k, n, bm, bk, bn, act):
    x = rand(1, (m, k))
    w = rand(2, (k, n))
    got = fxp_matmul(x, w, act=act, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.fxp_matmul_ref(x, w, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("bits", [((2, 4), (1, 6), (3, 4)),
                                  ((4, 10), (2, 12), (4, 10)),
                                  ((6, 8), (4, 8), None)])
def test_fxp_matmul_bit_formats(bits):
    xa, wb, ob = bits
    x = rand(3, (16, 24), scale=2.0)
    w = rand(4, (24, 16), scale=0.5)
    got = fxp_matmul(x, w, xa_bits=xa, w_bits=wb, out_bits=ob,
                     bm=8, bn=8, bk=8, interpret=True)
    want = ref.fxp_matmul_ref(x, w, xa_bits=xa, w_bits=wb, out_bits=ob)
    # blocked accumulation reorders float adds: a value landing on a .5-ulp
    # tie of the OUTPUT grid may round to the neighbouring step -> tolerance
    # of one output-resolution step
    atol = (2.0 ** -ob[1]) if ob is not None else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fxp_matmul_dtypes(dtype):
    x = rand(5, (16, 16), dtype)
    w = rand(6, (16, 16), dtype)
    got = fxp_matmul(x, w, bm=8, bn=8, bk=8, interpret=True)
    want = ref.fxp_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("act", ["relu", "sigmoid", "tanh", "silu"])
@pytest.mark.parametrize("t,din,dout,bm,bn,bk", [
    (16, 24, 32, 8, 8, 16),
    (32, 16, 16, 16, 16, 8),
    (8, 8, 8, 8, 8, 8),
])
def test_bp_gstep(act, t, din, dout, bm, bn, bk):
    g = rand(7, (t, dout), scale=0.5)
    w = rand(8, (din, dout))
    z = rand(9, (t, din), scale=2.0)
    got = bp_gstep(g, w, z, act=act, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.bp_gstep_ref(g, w, z, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_bp_gstep_matches_autodiff():
    """Paper Eq. 8 on a two-layer chain: G_i = (G_{i+1} @ W_{i+1}^T) * f'_i,
    where G_{i+1} already carries f'_{i+1} (Eq. 6).  G_1 from the kernel must
    equal the true dLoss/dZ_1 from autodiff."""
    t, d1, d2 = 16, 16, 16
    z1 = rand(10, (t, d1))           # layer-1 pre-activation
    w2 = rand(11, (d1, d2))
    g2_seed = rand(12, (t, d2))      # dLoss/dY_2

    def loss_of_z1(z):
        y1 = jax.nn.relu(z)
        z2 = y1 @ w2
        y2 = jax.nn.relu(z2)
        return jnp.sum(y2 * g2_seed)

    want = jax.grad(loss_of_z1)(z1)  # = dLoss/dZ_1 = G_1

    z2 = jax.nn.relu(z1) @ w2
    g2 = g2_seed * (z2 > 0)          # Eq. 6: G_2 = dE/dY_2 * f'_2
    got = bp_gstep(g2, w2, z1, g_bits=None, act="relu",
                   bm=8, bn=8, bk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("t,din,dout,bm,bn,bk", [
    (16, 24, 32, 8, 16, 8),
    (64, 16, 16, 8, 8, 16),
])
@pytest.mark.parametrize("w_bits", [None, (2, 12)])
def test_sgd_dw_update(t, din, dout, bm, bn, bk, w_bits):
    x = rand(13, (t, din))
    g = rand(14, (t, dout), scale=0.1)
    w = rand(15, (din, dout))
    got = sgd_dw_update(x, g, w, 0.05, w_bits=w_bits,
                        bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.sgd_dw_update_ref(x, g, w, 0.05, w_bits=w_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_sgd_dw_update_is_true_sgd_step():
    """Kernel == loss-gradient SGD step for L = <G, X@W>."""
    t, din, dout = 32, 16, 8
    x = rand(16, (t, din))
    g = rand(17, (t, dout))
    w = rand(18, (din, dout))
    lr = 0.1
    grad = jax.grad(lambda wv: jnp.sum((x @ wv) * g))(w)
    want = w - lr * grad
    got = sgd_dw_update(x, g, w, lr, bm=8, bn=8, bk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    mexp=st.integers(3, 5), kexp=st.integers(3, 5), nexp=st.integers(3, 5),
    seed=st.integers(0, 1000),
)
def test_fxp_matmul_property_shapes(mexp, kexp, nexp, seed):
    """Property sweep: random pow2 shapes, random blocks dividing them."""
    m, k, n = 2 ** mexp, 2 ** kexp, 2 ** nexp
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    got = fxp_matmul(x, w, bm=min(8, m), bn=min(8, n), bk=min(8, k),
                     interpret=True)
    want = ref.fxp_matmul_ref(x, w)
    # one output-grid step (F_out=10): accumulation-order rounding ties
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2.0 ** -10, rtol=1e-5)


def test_ops_wrappers_jit():
    x = rand(20, (32, 48))
    w = rand(21, (48, 16))
    got = fxp_matmul_op(x, w)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.fxp_matmul_ref(x, w)),
                               atol=1e-5, rtol=1e-5)
    g = rand(22, (32, 16), scale=0.2)
    z = rand(23, (32, 48))
    got2 = bp_gstep_op(g, w, z)
    np.testing.assert_allclose(np.asarray(got2),
                               np.asarray(ref.bp_gstep_ref(g, w, z)),
                               atol=1e-5, rtol=1e-5)
    got3 = sgd_dw_update_op(z, g, w, 0.01)
    np.testing.assert_allclose(np.asarray(got3),
                               np.asarray(ref.sgd_dw_update_ref(z, g, w, 0.01)),
                               atol=1e-5, rtol=1e-5)
