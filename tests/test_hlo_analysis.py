"""hlo_analysis: async start/done pairing, replica-group byte attribution,
and per-tick attribution against a 1F1B-compiled pipeline module."""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.dist.hlo_analysis import (collective_stats, overlap_fraction,
                                     per_tick_attribution, roofline_terms)

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 4, timeout=600):
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT/'src'}:{ROOT/'tests'}",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# text-level parsing (handcrafted HLO)
# ---------------------------------------------------------------------------

SYNC_HLO = """
ENTRY %main {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(f32[128,64]{1,0} %p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[512,64]{1,0} all-gather(f32[128,64]{1,0} %ar), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[128,64]{1,0} collective-permute(f32[128,64]{1,0} %p0), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  ROOT %t = (f32[128,64]{1,0}) tuple(%cp)
}
"""


def test_sync_collectives_group_attribution():
    stats = collective_stats(SYNC_HLO)
    assert stats["counts"] == {"all-reduce": 1, "all-gather": 1,
                               "collective-permute": 1}
    payload = 128 * 64 * 4
    gathered = 512 * 64 * 4
    # ring factors over a group of 4: all-reduce 2*(3/4), all-gather 3/4
    assert stats["by_kind_bytes"]["all-reduce"] == pytest.approx(
        1.5 * payload)
    assert stats["by_kind_bytes"]["all-gather"] == pytest.approx(
        0.75 * gathered)
    assert stats["by_kind_bytes"]["collective-permute"] == pytest.approx(
        payload)
    assert stats["moved_bytes_per_device"] == pytest.approx(
        1.5 * payload + 0.75 * gathered + payload)
    assert stats["async_pairs"] == 0 and stats["unmatched_starts"] == 0


ASYNC_HLO = """
ENTRY %main {
  %p0 = bf16[256,128]{1,0} parameter(0)
  %ars = (bf16[256,128]{1,0}, bf16[256,128]{1,0}) all-reduce-start(bf16[256,128]{1,0} %p0), replica_groups=[2,2]<=[4], to_apply=%add
  %mul = bf16[256,128]{1,0} multiply(bf16[256,128]{1,0} %p0, bf16[256,128]{1,0} %p0)
  %ard = bf16[256,128]{1,0} all-reduce-done((bf16[256,128]{1,0}, bf16[256,128]{1,0}) %ars)
  %cps = (bf16[256,128]{1,0}, bf16[256,128]{1,0}) collective-permute-start(bf16[256,128]{1,0} %mul), source_target_pairs={{0,1},{1,0}}
  %cpd = bf16[256,128]{1,0} collective-permute-done((bf16[256,128]{1,0}, bf16[256,128]{1,0}) %cps)
  %orphan = (bf16[8]{0}, bf16[8]{0}) all-gather-start(bf16[8]{0} %p0), replica_groups={{0,1}}, dimensions={0}
  ROOT %t = (bf16[256,128]{1,0}) tuple(%cpd)
}
"""


def test_async_pairs_counted_once():
    stats = collective_stats(ASYNC_HLO)
    # one all-reduce pair + one permute pair + one orphaned all-gather start
    assert stats["counts"] == {"all-reduce": 1, "collective-permute": 1,
                               "all-gather": 1}
    assert stats["async_pairs"] == 2
    assert stats["unmatched_starts"] == 1
    payload = 256 * 128 * 2
    # iota groups [2,2]<=[4] -> group size 2 -> all-reduce factor 2*(1/2)
    assert stats["by_kind_bytes"]["all-reduce"] == pytest.approx(payload)
    # the -done op must not double-count bytes
    assert stats["by_kind_bytes"]["collective-permute"] == pytest.approx(
        payload)


def test_group_of_one_moves_nothing():
    hlo = ("  %ar = f32[64]{0} all-reduce(f32[64]{0} %p), "
           "replica_groups={{0}}, to_apply=%add")
    stats = collective_stats(hlo)
    assert stats["counts"] == {"all-reduce": 1}
    assert stats["moved_bytes_per_device"] == 0.0


def test_default_group_size_fallback():
    hlo = "  %ar = f32[64]{0} all-reduce(f32[64]{0} %p), to_apply=%add"
    # g=2 default: all-reduce factor 2*(1/2) = 1 -> the old result-bytes
    assert collective_stats(hlo)["moved_bytes_per_device"] == 64 * 4
    # explicit override
    assert collective_stats(hlo, default_group_size=4)[
        "moved_bytes_per_device"] == pytest.approx(1.5 * 64 * 4)


def test_per_tick_attribution_text():
    out = per_tick_attribution(SYNC_HLO, num_ticks=8)
    payload = 128 * 64 * 4
    assert out["num_ticks"] == 8
    assert out["permute_bytes_per_tick"] == pytest.approx(payload / 8)
    assert out["moved_bytes_per_tick"] == pytest.approx(
        out["collectives"]["moved_bytes_per_device"] / 8)
    with pytest.raises(ValueError):
        per_tick_attribution(SYNC_HLO, num_ticks=0)


NO_COLLECTIVES_HLO = """
ENTRY %main {
  %p0 = f32[64,64]{1,0} parameter(0)
  %mul = f32[64,64]{1,0} multiply(f32[64,64]{1,0} %p0, f32[64,64]{1,0} %p0)
  ROOT %t = (f32[64,64]{1,0}) tuple(%mul)
}
"""

ORPHAN_DONE_HLO = """
ENTRY %main {
  %p0 = f32[8,8]{1,0} parameter(0)
  %ard = f32[8,8]{1,0} all-reduce-done((f32[8,8]{1,0}, f32[8,8]{1,0}) %ghost)
  ROOT %t = (f32[8,8]{1,0}) tuple(%ard)
}
"""


def test_per_tick_attribution_zero_collectives():
    """A module with no collectives attributes zero bytes everywhere —
    not an error, just an empty census."""
    out = per_tick_attribution(NO_COLLECTIVES_HLO, num_ticks=4)
    assert out["moved_bytes_per_tick"] == 0.0
    assert out["permute_bytes_per_tick"] == 0.0
    assert out["bytes_per_tick_by_kind"] == {}
    assert out["collectives"]["counts"] == {}


def test_per_tick_attribution_rejects_unpaired_start():
    """ASYNC_HLO carries an orphaned all-gather-start: its bytes have no
    closing window, so per-tick attribution must refuse, not guess."""
    assert collective_stats(ASYNC_HLO)["unmatched_starts"] == 1
    with pytest.raises(ValueError, match="without a done"):
        per_tick_attribution(ASYNC_HLO, num_ticks=4)


def test_per_tick_attribution_rejects_orphan_done():
    stats = collective_stats(ORPHAN_DONE_HLO)
    assert stats["unmatched_dones"] == 1
    assert stats["moved_bytes_per_device"] == 0.0  # never counted
    with pytest.raises(ValueError, match="without a start"):
        per_tick_attribution(ORPHAN_DONE_HLO, num_ticks=4)


# ---------------------------------------------------------------------------
# overlap_fraction: compute scheduled inside collective latency windows
# ---------------------------------------------------------------------------

def test_overlap_fraction_async_pair_with_compute():
    ov = overlap_fraction(ASYNC_HLO)
    # the all-reduce pair brackets %mul (compute); the permute pair is
    # issued right after %mul with nothing between start and done; the
    # orphaned start never closes a window
    assert ov["collectives"] == 2
    assert ov["overlapped"] == 1
    assert ov["overlap_fraction"] == pytest.approx(0.5)
    assert ov["compute_ops_in_windows"] == 1


def test_overlap_fraction_sync_window_to_first_consumer():
    # %ar's result reaches ROOT through its carry chain (the %add2
    # accumulate), so it is loop-carried: window extends to the ROOT and
    # holds both %mul and %add2
    hlo = """
ENTRY %main {
  %p0 = f32[8,8]{1,0} parameter(0)
  %ar = f32[8,8]{1,0} all-reduce(f32[8,8]{1,0} %p0), replica_groups={{0,1}}, to_apply=%add
  %mul = f32[8,8]{1,0} multiply(f32[8,8]{1,0} %p0, f32[8,8]{1,0} %p0)
  %add2 = f32[8,8]{1,0} add(f32[8,8]{1,0} %ar, f32[8,8]{1,0} %mul)
  ROOT %t = (f32[8,8]{1,0}) tuple(%add2)
}
"""
    ov = overlap_fraction(hlo)
    assert ov["collectives"] == 1
    assert ov["overlapped"] == 1
    assert ov["compute_ops_in_windows"] == 2

    # a sync collective consumed by NON-chain compute (a multiply) with
    # nothing scheduled between issue and consumer is NOT overlapped
    hlo2 = """
ENTRY %main {
  %p0 = f32[8,8]{1,0} parameter(0)
  %ar = f32[8,8]{1,0} all-reduce(f32[8,8]{1,0} %p0), replica_groups={{0,1}}, to_apply=%add
  %use = f32[8,8]{1,0} multiply(f32[8,8]{1,0} %ar, f32[8,8]{1,0} %p0)
  %late = f32[8,8]{1,0} multiply(f32[8,8]{1,0} %use, f32[8,8]{1,0} %use)
  ROOT %t = (f32[8,8]{1,0}) tuple(%late)
}
"""
    ov2 = overlap_fraction(hlo2)
    assert ov2["collectives"] == 1
    assert ov2["overlapped"] == 0
    assert ov2["compute_ops_in_windows"] == 0


def test_overlap_fraction_collapses_chained_ring_hops():
    """A ring decomposed into chained permute hops (hop -> accumulate ->
    hop -> ...) is ONE logical collective: the chain-head's chase absorbs
    the downstream hops, so the hop count cannot swamp the denominator
    (the bug that made a 24-hop overlapped ring and a lone blocking psum
    report the same 0.2222 fraction)."""
    hlo = """
ENTRY %main {
  %p0 = f32[8]{0} parameter(0)
  %hop1 = f32[8]{0} collective-permute(f32[8]{0} %p0), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %acc1 = f32[8]{0} add(f32[8]{0} %hop1, f32[8]{0} %p0)
  %hop2 = f32[8]{0} collective-permute(f32[8]{0} %acc1), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %acc2 = f32[8]{0} add(f32[8]{0} %hop2, f32[8]{0} %p0)
  %hop3 = f32[8]{0} collective-permute(f32[8]{0} %acc2), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %acc3 = f32[8]{0} add(f32[8]{0} %hop3, f32[8]{0} %p0)
  ROOT %t = (f32[8]{0}) tuple(%acc3)
}
"""
    ov = overlap_fraction(hlo)
    assert ov["collectives"] == 1          # 3 hops, one logical ring
    assert ov["overlapped"] == 1           # loop-carried into the ROOT
    assert ov["overlap_fraction"] == pytest.approx(1.0)


def test_overlap_fraction_distinguishes_ring_from_blocking_regime():
    """The regression this fix targets: a module mixing a carried ring
    with blocking psums must NOT report the blocking module's fraction.
    Before hop absorption every hop counted as its own overlapped
    collective, inflating both numerator and denominator until the two
    regimes became numerically indistinguishable."""
    blocking = """
ENTRY %main {
  %p0 = f32[8]{0} parameter(0)
  %ar1 = f32[8]{0} all-reduce(f32[8]{0} %p0), replica_groups={{0,1}}, to_apply=%add
  %u1 = f32[8]{0} multiply(f32[8]{0} %ar1, f32[8]{0} %p0)
  %ar2 = f32[8]{0} all-reduce(f32[8]{0} %u1), replica_groups={{0,1}}, to_apply=%add
  %u2 = f32[8]{0} multiply(f32[8]{0} %ar2, f32[8]{0} %u1)
  ROOT %t = (f32[8]{0}) tuple(%u2)
}
"""
    ringy = """
ENTRY %main {
  %p0 = f32[8]{0} parameter(0)
  %ar1 = f32[8]{0} all-reduce(f32[8]{0} %p0), replica_groups={{0,1}}, to_apply=%add
  %u1 = f32[8]{0} multiply(f32[8]{0} %ar1, f32[8]{0} %p0)
  %hop1 = f32[8]{0} collective-permute(f32[8]{0} %u1), source_target_pairs={{0,1},{1,0}}
  %acc1 = f32[8]{0} add(f32[8]{0} %hop1, f32[8]{0} %p0)
  %hop2 = f32[8]{0} collective-permute(f32[8]{0} %acc1), source_target_pairs={{0,1},{1,0}}
  %acc2 = f32[8]{0} add(f32[8]{0} %hop2, f32[8]{0} %p0)
  ROOT %t = (f32[8]{0}) tuple(%acc2)
}
"""
    ov_block = overlap_fraction(blocking)
    ov_ring = overlap_fraction(ringy)
    assert ov_block["collectives"] == 2 and ov_block["overlapped"] == 0
    # ringy: the same 2 blocking-style ops would read 0.0; the carried
    # ring adds ONE overlapped logical collective, not two hop entries
    assert ov_ring["collectives"] == 2
    assert ov_ring["overlapped"] == 1
    assert ov_ring["overlap_fraction"] != ov_block["overlap_fraction"]


def test_overlap_fraction_absorbs_async_permute_hops_in_chain():
    """Chained hops emitted in -start/-done form absorb too: the done of
    an absorbed start must not land in unmatched accounting or re-count."""
    hlo = """
ENTRY %main {
  %p0 = f32[8]{0} parameter(0)
  %hop1 = f32[8]{0} collective-permute(f32[8]{0} %p0), source_target_pairs={{0,1},{1,0}}
  %acc1 = f32[8]{0} add(f32[8]{0} %hop1, f32[8]{0} %p0)
  %h2s = f32[8]{0} collective-permute-start(f32[8]{0} %acc1), source_target_pairs={{0,1},{1,0}}
  %h2d = f32[8]{0} collective-permute-done(f32[8]{0} %h2s)
  %acc2 = f32[8]{0} add(f32[8]{0} %h2d, f32[8]{0} %p0)
  ROOT %t = (f32[8]{0}) tuple(%acc2)
}
"""
    ov = overlap_fraction(hlo)
    assert ov["collectives"] == 1
    assert ov["overlapped"] == 1


def test_overlap_fraction_no_collectives_is_zero():
    ov = overlap_fraction(NO_COLLECTIVES_HLO)
    assert ov == {"collectives": 0, "overlapped": 0,
                  "overlap_fraction": 0.0, "compute_ops_in_windows": 0}


def test_roofline_terms_dominant():
    t = roofline_terms(197e12, 819e9, 0.0)
    assert t["dominant"] in ("compute", "memory")
    assert t["step_s_lower_bound"] == pytest.approx(1.0, rel=1e-6)


# ---------------------------------------------------------------------------
# a 1F1B-compiled module: permute bytes per schedule tick
# ---------------------------------------------------------------------------

def test_per_tick_attribution_on_1f1b_compiled_module():
    out = run_py("""
    import jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.dist.hlo_analysis import collective_stats, per_tick_attribution
    from repro.dist.pipeline import get_schedule, pipeline_apply

    S, M, MB, D = 4, 8, 2, 16
    mesh = jax.make_mesh((S,), ("pipe",), axis_types=(AxisType.Auto,))
    sched = get_schedule("1f1b")
    w = jax.random.normal(jax.random.key(0), (S, D, D)) * D ** -0.5
    x = jax.random.normal(jax.random.key(1), (M, MB, D))

    def body(stage_w, h):
        return jnp.tanh(h @ stage_w)

    def loss(w_):
        return jnp.sum(pipeline_apply(w_, x, body, mesh, schedule=sched) ** 2)

    with jax.set_mesh(mesh):
        compiled = jax.jit(jax.grad(loss)).lower(w).compile()
    hlo = compiled.as_text()
    stats = collective_stats(hlo)
    assert stats["unmatched_starts"] == 0, stats
    ticks = sched.plan(S, M).num_ticks
    out = per_tick_attribution(hlo, ticks)
    assert out["num_ticks"] == ticks
    assert out["moved_bytes_per_tick"] >= 0.0
    n_perm = stats["counts"].get("collective-permute", 0)
    print("PERMUTES", n_perm, "PAIRS", stats["async_pairs"],
          "PER_TICK", out["permute_bytes_per_tick"])
    if n_perm:
        assert out["permute_bytes_per_tick"] > 0.0
    print("HLO_OK")
    """)
    assert "HLO_OK" in out
