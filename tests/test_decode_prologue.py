"""Fused decode-prologue kernel: bitwise parity and serving equivalence.

The load-bearing claims, each a test:
  * fused ``decode_prologue`` is BITWISE identical to the unfused
    ``apply_norm`` + ``_project_qkv`` chain under jit, across GQA vs MHA,
    qkv_bias on/off, and rope theta — f32 datapath.
  * the Pallas kernel is BITWISE identical to the jitted jnp reference on
    BOTH datapaths (f32 and int8) — forcing the tune_prologue fallback
    must not change a single bit.
  * unsupported geometries (layernorm front, MLA) gate the fusion off.
  * with the fusion active end-to-end (kernel_backend="emulate"), paged
    AND contiguous serving emit token streams identical to the unfused
    runs, across cache_dtype f32/bf16/int8.

Every parity assertion jits BOTH sides: XLA CPU fuses the rope mul-adds
into FMAs under jit, so an eager chain differs from its jitted twin by
1 ulp — production decode is always jitted, and that is the contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_prologue as DP
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import BatchScheduler, EngineHooks, Request, ServeConfig
from test_models import tiny


@pytest.fixture(autouse=True)
def _fresh_tune_cache():
    kops.clear_tune_cache()
    yield
    kops.clear_tune_cache()


def _cfg(**kw):
    base = dict(name="t-prologue", family="dense", num_layers=1, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _params(cfg, seed=0):
    key = jax.random.key(seed)
    d, h, hkv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                     cfg.head_dim)
    ks = jax.random.split(key, 8)
    norm = {"scale": 1.0 + 0.1 * jax.random.normal(ks[0], (d,), jnp.float32)}
    attn = {"wq": jax.random.normal(ks[1], (d, h, hd)) * 0.1,
            "wk": jax.random.normal(ks[2], (d, hkv, hd)) * 0.1,
            "wv": jax.random.normal(ks[3], (d, hkv, hd)) * 0.1}
    if cfg.qkv_bias:
        attn["bq"] = jax.random.normal(ks[4], (h, hd)) * 0.1
        attn["bk"] = jax.random.normal(ks[5], (hkv, hd)) * 0.1
        attn["bv"] = jax.random.normal(ks[6], (hkv, hd)) * 0.1
    x = jax.random.normal(ks[7], (3, 1, d), jnp.float32)
    pos = jnp.array([0, 5, 17], jnp.int32)
    return norm, attn, x, pos


# ---------------------------------------------------------------------------
# Fused vs unfused: bitwise under jit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv,bias,theta", [
    (2, False, 10_000.0),          # GQA, the common case
    (4, True, 10_000.0),           # MHA + qkv bias (qwen-style)
    (2, True, 500_000.0),          # long-context rope theta
])
def test_fused_matches_unfused_bitwise(kv, bias, theta):
    cfg = _cfg(num_kv_heads=kv, qkv_bias=bias, rope_theta=theta)
    norm, attn, x, pos = _params(cfg)

    fused = jax.jit(lambda xx: DP.decode_prologue(norm, attn, xx, cfg, pos))
    unfused = jax.jit(lambda xx: L._project_qkv(
        attn, L.apply_norm(norm, xx, cfg), cfg, pos[:, None]))
    for got, want in zip(fused(x), unfused(x)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_matches_unfused_no_rope():
    cfg = _cfg(use_rope=False)
    norm, attn, x, pos = _params(cfg)
    fused = jax.jit(lambda xx: DP.decode_prologue(norm, attn, xx, cfg, pos))
    unfused = jax.jit(lambda xx: L._project_qkv(
        attn, L.apply_norm(norm, xx, cfg), cfg, pos[:, None]))
    for got, want in zip(fused(x), unfused(x)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Kernel vs jnp reference: bitwise on both datapaths
# ---------------------------------------------------------------------------

def _force_ref(monkeypatch):
    """Reject every shape so decode_prologue takes the jnp fallback."""
    monkeypatch.setattr(kops, "tune_prologue", lambda *a, **k: None)


def test_kernel_matches_ref_bitwise_f32(monkeypatch):
    cfg = _cfg(qkv_bias=True)
    norm, attn, x, pos = _params(cfg)
    assert kops.tune_prologue(cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                              cfg.head_dim) is not None
    kernel = jax.jit(lambda xx: DP.decode_prologue(norm, attn, xx, cfg, pos))
    kout = kernel(x)
    _force_ref(monkeypatch)
    ref = jax.jit(lambda xx: DP.decode_prologue(norm, attn, xx, cfg, pos))
    for got, want in zip(kout, ref(x)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_matches_ref_bitwise_int8(monkeypatch):
    cfg = _cfg(qkv_bias=True)
    norm, attn, x, pos = _params(cfg)
    with kops.kernel_backend_ctx("int8"):
        kernel = jax.jit(
            lambda xx: DP.decode_prologue(norm, attn, xx, cfg, pos))
        kout = kernel(x)
        _force_ref(monkeypatch)
        ref = jax.jit(lambda xx: DP.decode_prologue(norm, attn, xx, cfg, pos))
        rout = ref(x)
    for got, want in zip(kout, rout):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Gating
# ---------------------------------------------------------------------------

def test_unsupported_geometries_gate_off():
    assert DP.prologue_supported(_cfg())
    assert not DP.prologue_supported(_cfg(norm_kind="layernorm"))
    ssm = tiny("ssm")
    assert not DP.prologue_supported(ssm)          # no attention heads
    mla = tiny(use_mla=True, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8,
               v_head_dim=8)
    assert not DP.prologue_supported(mla)


def test_prologue_inactive_without_backend_and_on_prefill():
    cfg = _cfg()
    x1 = jnp.zeros((2, 1, cfg.d_model))
    x8 = jnp.zeros((2, 8, cfg.d_model))
    assert not DP.prologue_active(cfg, x1)         # ambient backend is off
    with kops.kernel_backend_ctx("emulate"):
        assert DP.prologue_active(cfg, x1)
        assert not DP.prologue_active(cfg, x8)     # prefill stays unfused


def test_layernorm_arch_decodes_through_unfused_path():
    """A layernorm-front arch under an active backend must fall back to
    the unfused decode (prologue_active False) and still emit the same
    stream as the backend-off run."""
    cfg = tiny(norm_kind="layernorm")
    assert not DP.prologue_supported(cfg)
    params = lm.init_params(jax.random.key(0), cfg)
    toks = _serve_tokens(params, cfg, mode="contiguous",
                         cache_dtype="float32", kernel_backend="emulate")
    ref = _serve_tokens(params, cfg, mode="contiguous",
                        cache_dtype="float32", kernel_backend=None)
    assert toks == ref


# ---------------------------------------------------------------------------
# End-to-end serving equivalence (fused decode on vs off)
# ---------------------------------------------------------------------------

def _serve_tokens(params, cfg, *, mode, cache_dtype, kernel_backend):
    sc = ServeConfig(num_slots=2, eos_id=None, max_len=32, mode=mode,
                     block_size=8, cache_dtype=cache_dtype,
                     kernel_backend=kernel_backend)
    s = BatchScheduler(sc, EngineHooks.for_model(params, cfg, sc))
    rng = np.random.default_rng(7)
    for i in range(3):
        s.submit(Request(uid=i,
                         prompt=rng.integers(0, cfg.vocab_size,
                                             size=(9,)).astype(np.int32),
                         max_new_tokens=6))
    return {r.uid: r.generated for r in s.run_until_drained()}


@pytest.mark.parametrize("mode", ["paged", "contiguous"])
@pytest.mark.parametrize("cache_dtype", ["float32", "bfloat16", "int8"])
def test_serving_streams_identical_with_fused_decode(mode, cache_dtype):
    cfg = tiny()
    params = lm.init_params(jax.random.key(0), cfg)
    fused = _serve_tokens(params, cfg, mode=mode, cache_dtype=cache_dtype,
                          kernel_backend="emulate")
    ref = _serve_tokens(params, cfg, mode=mode, cache_dtype=cache_dtype,
                        kernel_backend=None)
    assert fused == ref
