"""Paged-KV serving: block pool, prefix sharing, chunked prefill, the fused
paged-attention kernel, and the ServeConfig/EngineHooks scheduler.

The load-bearing claims, each a test:
  * paged decode is BITWISE identical to the contiguous cache path on the
    same cache bytes (same einsums, same softmax, same masking).
  * the Pallas paged-attention kernel is BITWISE identical to the jnp
    gather reference, f32 and int8 pools alike.
  * prefix sharing changes WHICH blocks are read, never the bytes: shared
    and unshared schedulers emit identical streams, and after the requests
    drain and the prefix cache is released every refcount is zero.
  * chunked prefill never starves running decodes: on an arrival trace
    with a long prompt admitted mid-stream, every tick that spends prefill
    budget also decodes the active slots.
  * a snapshot taken MID-chunked-prefill restores through the checkpoint
    layer and continues the exact streams.
"""
import os
import pathlib
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.serving import (BatchScheduler, BlockPool, EngineHooks,
                           PoolExhausted, PrefixIndex, Request, ServeConfig,
                           decode_step, init_decode_state, init_paged_state,
                           paged_decode_step, paged_prefill_chunk, prefill)
from test_models import tiny

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 4, timeout=600):
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT/'src'}:{ROOT/'tests'}",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _setup(seed=0):
    cfg = tiny()
    params = lm.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(seed)
    return cfg, params, rng


def _sched(params, cfg, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("eos_id", None)
    kw.setdefault("max_len", 64)
    kw.setdefault("cache_dtype", "float32")
    sc = ServeConfig(**kw)
    return BatchScheduler(sc, EngineHooks.for_model(params, cfg, sc))


# ---------------------------------------------------------------------------
# Block pool + prefix index unit behavior
# ---------------------------------------------------------------------------

def test_block_pool_accounting():
    pool = BlockPool(5)
    assert pool.available() == 4          # block 0 reserved
    a, b = pool.alloc(), pool.alloc()
    pool.retain(a)
    pool.release(a)
    assert pool.available() == 2          # a still referenced
    pool.release(a)
    pool.release(b)
    assert pool.available() == 4
    for _ in range(4):
        pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()


def test_prefix_index_longest_match_and_partial_boundary():
    pool = BlockPool(10)
    idx = PrefixIndex()
    prompt = np.arange(20, dtype=np.int32)  # Bs=8: blocks at 8, 16, +20
    table = [pool.alloc() for _ in range(3)]
    idx.register(prompt, table, 8, pool)
    assert len(idx) == 3                   # ends 8, 16, and partial 20
    # a longer prompt sharing all 20 tokens reuses the partial entry
    longer = np.concatenate([prompt, np.arange(100, 106, dtype=np.int32)])
    n, blocks = idx.lookup(longer, len(longer) - 1)
    assert n == 20 and list(blocks) == table
    # a prompt sharing only the first block matches the aligned entry
    fork = np.concatenate([prompt[:8], np.arange(50, 60, dtype=np.int32)])
    n, blocks = idx.lookup(fork, len(fork) - 1)
    assert n == 8 and list(blocks) == table[:1]
    # limit caps reuse below a full-prompt entry
    n, _ = idx.lookup(prompt, len(prompt) - 1)
    assert n == 16
    idx.drop(pool)
    assert pool.refs[table].tolist() == [1, 1, 1]   # back to alloc-only


# ---------------------------------------------------------------------------
# Bitwise: paged vs contiguous, kernel vs ref
# ---------------------------------------------------------------------------

def test_paged_decode_bitwise_vs_contiguous():
    """Same prompt, same weights: the paged pool path and the contiguous
    cache path produce BITWISE identical logits at every decode step."""
    cfg, params, rng = _setup()
    prompt = rng.integers(0, cfg.vocab_size, size=(1, 12)).astype(np.int32)
    max_len, bs = 32, 8

    logits_c, state = prefill(params, cfg, {"tokens": jnp.asarray(prompt)},
                              max_len, jnp.float32)
    pool = init_paged_state(cfg, 1 + max_len // bs, bs, jnp.float32)
    table = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    logits_p, pool = paged_prefill_chunk(params, cfg, pool, table,
                                         jnp.asarray(prompt), 0)
    np.testing.assert_array_equal(np.asarray(logits_c),
                                  np.asarray(logits_p))
    pos = prompt.shape[1]
    for _ in range(6):
        tok = jnp.argmax(logits_c, axis=-1).astype(jnp.int32)[:, None]
        logits_c, state = decode_step(params, cfg, state, tok)
        logits_p, pool = paged_decode_step(
            params, cfg, pool, table, jnp.asarray([pos], jnp.int32), tok)
        np.testing.assert_array_equal(np.asarray(logits_c),
                                      np.asarray(logits_p))
        pos += 1


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_paged_kernel_bitwise_vs_ref(dtype):
    """The fused Pallas kernel (interpret mode on CPU) is BITWISE identical
    to the jnp gather reference for both pool dtypes."""
    from repro.kernels import paged_attention as PA

    rng = np.random.default_rng(3)
    n, bs, hkv, hd, groups, b, m = 9, 8, 2, 8, 2, 3, 4
    h = hkv * groups
    kv = rng.standard_normal((2, n, bs, hkv, hd)).astype(np.float32)
    if dtype == "int8":
        amax = np.abs(kv).max(axis=(3, 4))
        scale = np.maximum(amax, 1e-8) / 127.0
        q8 = np.clip(np.round(kv / scale[..., None, None]), -127, 127)
        pool_l = {"k": jnp.asarray(q8[0], jnp.int8),
                  "v": jnp.asarray(q8[1], jnp.int8),
                  "k_scale": jnp.asarray(scale[0], jnp.float32),
                  "v_scale": jnp.asarray(scale[1], jnp.float32)}
    else:
        pool_l = {"k": jnp.asarray(kv[0]), "v": jnp.asarray(kv[1])}
    q = jnp.asarray(rng.standard_normal((b, h, hd)).astype(np.float32))
    tables = jnp.asarray(rng.integers(1, n, size=(b, m)), jnp.int32)
    lens = jnp.asarray([5, 17, 30], jnp.int32)
    ref = PA._ref(q, pool_l, tables, lens, groups, hd ** -0.5)
    got = PA._call_kernel(q, pool_l, tables, lens, groups, hd ** -0.5)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_paged_attention_falls_back_over_budget():
    """Pools the VMEM budget rejects take the jnp ref path, same results."""
    from repro.kernels import ops as kops
    assert kops.tune_paged(8, 8, 4, 2, 8, 2) is not None
    assert kops.tune_paged(100_000, 8, 4096, 8, 128, 4) is None
    assert kops.tune_paged(8, 8, 4, 2, 10, 2) is None   # hd % 8 != 0


# ---------------------------------------------------------------------------
# Scheduler: parity, prefix sharing, chunked prefill, admission
# ---------------------------------------------------------------------------

def test_paged_scheduler_matches_contiguous_streams():
    """Equal-length prompts (the regime where the legacy global-pos
    contiguous scheduler is well-defined): paged + chunked prefill emits
    the exact same token streams."""
    cfg, params, rng = _setup()
    prompts = [rng.integers(0, cfg.vocab_size, size=(12,)).astype(np.int32)
               for _ in range(4)]

    def run(**kw):
        s = _sched(params, cfg, **kw)
        for i, p in enumerate(prompts):
            s.submit(Request(uid=i, prompt=p.copy(), max_new_tokens=8))
        return {r.uid: r.generated for r in s.run_until_drained()}, s

    ref, _ = run(mode="contiguous")
    got, sp = run(mode="paged", block_size=8, prefill_chunk=5)
    assert got == ref
    assert sp.stats["prefill_tokens"] == 4 * 12


def test_prefix_sharing_bitwise_and_refcounts_drop_to_zero():
    """Shared-prefix requests reuse blocks (hits, reused tokens, COW on the
    partial boundary) yet the streams are identical to the unshared run;
    once drained + prefix cache released, every refcount returns to zero."""
    cfg, params, rng = _setup(seed=1)
    head = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
    prompts = [head.copy(),                       # registers entries 8,16,20
               np.concatenate([head, rng.integers(0, cfg.vocab_size,
                                                  size=(6,)).astype(np.int32)]),
               np.concatenate([head, rng.integers(0, cfg.vocab_size,
                                                  size=(4,)).astype(np.int32)])]

    def run(pfx):
        s = _sched(params, cfg, num_slots=1, mode="paged", block_size=8,
                   prefill_chunk=8, prefix_sharing=pfx)
        for i, p in enumerate(prompts):
            s.submit(Request(uid=i, prompt=p.copy(), max_new_tokens=8))
        return {r.uid: r.generated for r in s.run_until_drained()}, s

    ref, _ = run(False)
    got, s = run(True)
    assert got == ref
    # requests 1 and 2 both reuse request 0's full 20-token prompt, whose
    # last block is partial: real copy-on-write must have fired
    assert s.stats["prefix_hits"] == 2
    assert s.stats["reused_tokens"] == 40
    assert s.stats["cow_copies"] >= 2
    live = s.block_pool
    assert (live.refs[1:] != 0).any()             # index still holds blocks
    s.release_prefix_cache()
    assert (live.refs[1:] == 0).all()
    assert live.available() == live.num_blocks - 1


def test_no_starvation_during_long_chunked_prefill():
    """Arrival trace: a short request is decoding when a long prompt lands.
    The long prefill spreads over many ticks (prefill_chunk budget) and the
    running stream must decode on EVERY one of those ticks."""
    cfg, params, rng = _setup(seed=2)
    short = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
    long_p = rng.integers(0, cfg.vocab_size, size=(40,)).astype(np.int32)

    s = _sched(params, cfg, mode="paged", block_size=8, prefill_chunk=4,
               prefix_sharing=False)
    s.submit(Request(uid=0, prompt=short, max_new_tokens=30))
    s.step()                                      # admit + begin short
    while s._prefilling.any():
        s.step()                                  # finish short's prefill
    s.submit(Request(uid=1, prompt=long_p, max_new_tokens=4))
    overlap_ticks = 0
    for _ in range(40):
        before = len(s.tick_log)
        s.step()
        t = s.tick_log[before]
        if t["prefill_tokens"] > 0:
            # a tick that spent prefill budget on the long prompt must
            # still have decoded the short request's slot
            assert t["decoded"] >= 1, t
            overlap_ticks += 1
        if not any(r is not None and r.uid == 1 for r in s.slots) \
                and not s.pending:
            if all(r is None for r in s.slots):
                break
    assert overlap_ticks >= 40 // 4 - 1           # the prefill really spread
    done = s.run_until_drained()
    assert {r.uid for r in done} | {0, 1} == {0, 1}


def test_priority_admission_jumps_fifo_queue():
    cfg, params, rng = _setup(seed=3)
    prompts = [rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
               for _ in range(3)]
    s = _sched(params, cfg, num_slots=1, mode="paged", admission="priority",
               prefix_sharing=False)
    for i, p in enumerate(prompts):
        s.submit(Request(uid=i, prompt=p, max_new_tokens=6,
                         priority=(10 if i == 2 else 0)))
    s.step()
    first = [r for r in s.slots if r is not None]
    assert first and first[0].uid == 2            # high priority admitted 1st
    s.run_until_drained()
    fifo = _sched(params, cfg, num_slots=1, mode="paged",
                  prefix_sharing=False)
    for i, p in enumerate(prompts):
        fifo.submit(Request(uid=i, prompt=p, max_new_tokens=6,
                            priority=(10 if i == 2 else 0)))
    fifo.step()
    first = [r for r in fifo.slots if r is not None]
    assert first and first[0].uid == 0            # fifo ignores priority


def test_admission_respects_block_budget():
    """With a pool too small for two concurrent requests, the second waits
    in pending until the first frees its blocks — no PoolExhausted."""
    cfg, params, rng = _setup(seed=4)
    prompts = [rng.integers(0, cfg.vocab_size, size=(16,)).astype(np.int32)
               for _ in range(2)]
    s = _sched(params, cfg, mode="paged", block_size=8, max_len=32,
               num_blocks=8, prefix_sharing=False)
    for i, p in enumerate(prompts):
        s.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    s.step()
    assert sum(r is not None for r in s.slots) == 1 and len(s.pending) == 1
    done = s.run_until_drained()
    assert {r.uid for r in done} == {0, 1}


# ---------------------------------------------------------------------------
# Snapshot / restore
# ---------------------------------------------------------------------------

def test_mid_chunked_prefill_snapshot_restores_identically(tmp_path):
    """Interrupt the scheduler MID-chunked-prefill (int8 pool, prefix
    sharing on), round-trip the snapshot through the checkpoint layer, and
    the continued streams must be identical to the uninterrupted ones."""
    from repro.ckpt import restore_checkpoint, save_checkpoint

    cfg, params, rng = _setup(seed=5)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (20, 26, 20, 26)]
    sc = ServeConfig(num_slots=2, eos_id=None, max_len=64, mode="paged",
                     block_size=8, prefill_chunk=4, cache_dtype="int8")
    hooks = EngineHooks.for_model(params, cfg, sc)
    s = BatchScheduler(sc, hooks)
    for i, p in enumerate(prompts):
        s.submit(Request(uid=i, prompt=p.copy(), max_new_tokens=6))
    for _ in range(3):
        s.step()
    assert s._prefilling.any(), "snapshot must land mid-prefill"
    snap = s.snapshot()

    save_checkpoint(tmp_path, 1, snap)
    template = jax.tree.map(np.asarray, snap)
    loaded, _, _ = restore_checkpoint(tmp_path, template)

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        resumed = BatchScheduler.restore(loaded, hooks=hooks)
    f1 = {r.uid: r.generated for r in s.run_until_drained()}
    f2 = {r.uid: r.generated for r in resumed.run_until_drained()}
    assert f1 == f2 and len(f1) == 4


# ---------------------------------------------------------------------------
# Mesh-sharded paged serving (4 virtual devices)
# ---------------------------------------------------------------------------

def test_paged_scheduler_on_production_mesh():
    """The pool shards over the production mesh ("lnshd": blocks over data,
    KV heads over model) and the sharded run emits the same streams as the
    single-device run."""
    out = run_py("""
    import jax, numpy as np
    from repro.dist.api import activation_sharding_ctx, make_default_rules
    from repro.launch.mesh import batch_axes, make_debug_mesh
    from repro.models import lm
    from repro.serving import (BatchScheduler, EngineHooks, Request,
                               ServeConfig)
    from test_models import tiny

    cfg = tiny()
    params = lm.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(12,)).astype(np.int32)
               for _ in range(4)]

    def run():
        sc = ServeConfig(num_slots=2, eos_id=None, max_len=64, mode="paged",
                         block_size=8, prefill_chunk=8,
                         cache_dtype="float32")
        s = BatchScheduler(sc, EngineHooks.for_model(params, cfg, sc))
        for i, p in enumerate(prompts):
            s.submit(Request(uid=i, prompt=p.copy(), max_new_tokens=8))
        return {r.uid: tuple(r.generated) for r in s.run_until_drained()}

    ref = run()
    mesh = make_debug_mesh(2, 2)
    rules = make_default_rules(batch_axes(mesh))
    with jax.set_mesh(mesh), activation_sharding_ctx(rules):
        got = run()
    assert got == ref, (got, ref)
    print("MESH OK", len(got))
    """, devices=4)
    assert "MESH OK 4" in out
