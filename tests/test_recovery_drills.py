"""End-to-end recovery drills: the training loop must survive kills,
corrupt checkpoints, and topology changes.

Each drill runs the REAL driver (``repro.launch.train``) in a subprocess
with an injected fault plan (``--fault-plan`` / ``REPRO_FAULT_PLAN``) and
asserts the recovery contract from the checkpoint layer's docstring:

* kill at a seeded-random step + restart on the SAME device count resumes
  **bitwise** — final params, optimizer state, and the logged per-step
  losses are identical to an uninterrupted run (stochastic-rounding RNG,
  data stream, and LR schedule are all step-indexed);
* restart on a DIFFERENT device count (elastic reshard) matches the
  uninterrupted run within a small float tolerance (the data-parallel
  reduction order changes, nothing else);
* a corrupted newest checkpoint is detected by checksum, warned about
  loudly, and recovery falls back to the previous valid checkpoint.

CI runs this file with ``REPRO_DRILL_DEVICES=4`` on the 4-device job;
locally it defaults to a single device to stay fast.
"""
import os
import pathlib
import re
import subprocess
import sys

import msgpack
import numpy as np
import pytest

from repro.ft import FAULT_EXIT_CODE

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEVICES = int(os.environ.get("REPRO_DRILL_DEVICES", "1"))


def run_driver(*extra, devices=DEVICES, expect_code=0, timeout=600):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.pop("REPRO_FAULT_PLAN", None)  # drills pass plans explicitly
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen1.5-0.5b", "--reduced", "--seq-len", "32",
           "--global-batch", "8", "--lr", "3e-2", "--log-every", "1",
           *extra]
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=timeout)
    assert out.returncode == expect_code, (
        f"expected exit {expect_code}, got {out.returncode}\n"
        f"stdout: {out.stdout[-2000:]}\nstderr: {out.stderr[-3000:]}")
    return out


def step_losses(stdout):
    """{step: formatted-loss-string} — string compare = bitwise compare."""
    return {int(m.group(1)): m.group(2) for m in
            re.finditer(r"step\s+(\d+) loss (\d+\.\d+)", stdout)}


def manifest_crcs(ck, step):
    cdir = pathlib.Path(ck) / f"step_{step:08d}"
    m = msgpack.unpackb((cdir / "manifest.msgpack").read_bytes())
    return {e["path"]: (int(e["crc32"]), int(e["nbytes"]))
            for e in m["leaves"]}


def load_leaves(ck, step):
    cdir = pathlib.Path(ck) / f"step_{step:08d}"
    m = msgpack.unpackb((cdir / "manifest.msgpack").read_bytes())
    return {e["path"]: np.load(cdir / e["file"], allow_pickle=False)
            for e in m["leaves"]}


@pytest.mark.slow
def test_kill_at_seeded_step_resumes_bitwise(tmp_path):
    """Kill at a seeded-random step; restart must be bitwise identical to an
    uninterrupted run — including the stochastic-rounding RNG stream."""
    common = ("--steps", "12", "--ckpt-every", "4",
              "--quantize", "--stochastic")
    ref_ck, ck = tmp_path / "ref", tmp_path / "ck"

    ref0 = run_driver(*common, "--ckpt-dir", str(ref_ck))
    ref = run_driver(*common, "--ckpt-dir", str(ref_ck))
    # the baseline itself must be run-to-run deterministic, or "bitwise
    # resume" would be unfalsifiable
    assert step_losses(ref.stdout) == step_losses(ref0.stdout)
    assert step_losses(ref.stdout), ref.stdout[-1000:]

    # the crash step is drawn from the plan seed inside [6, 11)
    killed = run_driver(*common, "--ckpt-dir", str(ck),
                        "--fault-plan", "crash@rand:6-11;seed=5",
                        expect_code=FAULT_EXIT_CODE)
    m = re.search(r"injected crash at step (\d+)", killed.stderr)
    assert m, killed.stderr[-2000:]
    crash_step = int(m.group(1))
    assert 6 <= crash_step < 11
    # the kill really was mid-run: no final checkpoint landed
    assert not (ck / "step_00000012").exists()

    resumed = run_driver(*common, "--ckpt-dir", str(ck), "--resume")
    rm = re.search(r"resumed from step (\d+)", resumed.stdout)
    assert rm, resumed.stdout[-2000:]
    resume_step = int(rm.group(1))
    assert 0 < resume_step <= crash_step

    # bitwise: every param/optimizer leaf of the final checkpoint matches
    assert manifest_crcs(ck, 12) == manifest_crcs(ref_ck, 12)
    # ... and the logged losses after resume match the reference run's
    ref_losses = step_losses(ref.stdout)
    res_losses = step_losses(resumed.stdout)
    assert res_losses, resumed.stdout[-1000:]
    for step, loss in res_losses.items():
        assert loss == ref_losses[step], (
            f"step {step}: resumed loss {loss} != reference {ref_losses[step]}")


@pytest.mark.slow
def test_corrupt_latest_falls_back_with_loud_warning(tmp_path):
    """Bit-flip the newest checkpoint; resume must detect it via checksum,
    warn, and recover from the previous valid checkpoint."""
    ck = tmp_path / "ck"
    # flip@12 corrupts the final checkpoint (data-step label 12) after it
    # lands; checkpoints at labels 5 and 9 stay valid
    run_driver("--steps", "12", "--ckpt-every", "4", "--ckpt-dir", str(ck),
               "--fault-plan", "flip@12")
    assert (ck / "step_00000012").exists()

    resumed = run_driver("--steps", "16", "--ckpt-every", "4",
                         "--ckpt-dir", str(ck), "--resume")
    assert "failed verification" in resumed.stderr, resumed.stderr[-3000:]
    assert re.search(r"recovered from checkpoint step 9", resumed.stderr)
    assert "resumed from step 9" in resumed.stdout, resumed.stdout[-2000:]
    # the continued run writes a fresh valid final checkpoint
    assert (ck / "step_00000016").exists()


@pytest.mark.slow
def test_transient_ckpt_io_failures_are_absorbed(tmp_path):
    """Two injected IO failures during a checkpoint write retry and succeed;
    the run exits clean with a valid final checkpoint."""
    ck = tmp_path / "ck"
    out = run_driver("--steps", "8", "--ckpt-every", "4",
                     "--ckpt-dir", str(ck),
                     "--fault-plan", "io@5x2")
    assert "retrying" in out.stderr, out.stderr[-3000:]
    assert (ck / "step_00000008").exists()
    from repro.ckpt import verify_checkpoint
    assert verify_checkpoint(ck, 5) == []
    assert verify_checkpoint(ck, 8) == []


@pytest.mark.slow
@pytest.mark.skipif(DEVICES > 1, reason="drill pins its own device counts")
def test_elastic_resume_on_different_device_count(tmp_path):
    """Train 8 steps on 1 device, resume on 2: the final params must match
    the uninterrupted 1-device run within float tolerance.  Only the
    data-parallel reduction order changes, so the tolerance is small; it is
    documented in the README's resume-guarantees table."""
    ref_ck, ck = tmp_path / "ref", tmp_path / "ck"
    run_driver("--steps", "12", "--ckpt-every", "4",
               "--ckpt-dir", str(ref_ck), devices=1)
    run_driver("--steps", "8", "--ckpt-every", "4",
               "--ckpt-dir", str(ck), devices=1)

    resumed = run_driver("--steps", "12", "--ckpt-every", "4",
                         "--ckpt-dir", str(ck), "--resume", devices=2)
    assert "'data': 2" in resumed.stdout, resumed.stdout[-2000:]
    assert "resumed from step 8" in resumed.stdout

    ref, got = load_leaves(ref_ck, 12), load_leaves(ck, 12)
    assert set(ref) == set(got)
    worst = 0.0
    for path in ref:
        a, b = ref[path].astype(np.float64), got[path].astype(np.float64)
        scale = max(np.abs(a).max(), 1e-8)
        worst = max(worst, float(np.abs(a - b).max() / scale))
        np.testing.assert_allclose(
            a, b, rtol=5e-3, atol=5e-3 * scale,
            err_msg=f"{path} diverged beyond the elastic-resume tolerance")
    print(f"[drill] elastic resume worst relative divergence: {worst:.2e}")


@pytest.mark.slow
def test_straggler_stall_does_not_break_resume(tmp_path):
    """A stalled fetch past the deadline is substituted (not fatal), and the
    run still checkpoints and finishes clean."""
    ck = tmp_path / "ck"
    out = run_driver("--steps", "8", "--ckpt-every", "4",
                     "--ckpt-dir", str(ck), "--deadline-s", "0.3",
                     "--fault-plan", "stall@3:2.0")
    assert (ck / "step_00000008").exists()
    assert len(step_losses(out.stdout)) >= 6
