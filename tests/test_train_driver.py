"""End-to-end launcher tests: train, checkpoint, kill, resume (subprocess)."""
import os
import pathlib
import re
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_train(*extra, timeout=600):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen1.5-0.5b", "--reduced", "--seq-len", "32",
           "--global-batch", "8", "--log-every", "5", *extra]
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def parse_losses(stdout):
    # per-step lines only ("step N loss X"), not the final summary line
    return [float(m.group(1))
            for m in re.finditer(r"step\s+\d+ loss (\d+\.\d+)", stdout)]


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    out = run_train("--steps", "60", "--lr", "3e-2")
    losses = parse_losses(out)
    assert len(losses) >= 3
    assert losses[-1] < losses[0] * 0.9, out[-2000:]


@pytest.mark.slow
def test_checkpoint_restart_continues(tmp_path):
    ck = tmp_path / "ck"
    out1 = run_train("--steps", "20", "--lr", "3e-2", "--ckpt-dir", str(ck),
                     "--ckpt-every", "10")
    assert (ck / "LATEST").exists()
    out2 = run_train("--steps", "30", "--lr", "3e-2", "--ckpt-dir", str(ck),
                     "--resume")
    assert "resumed from step 20" in out2
    # resumed run continues from the checkpointed loss level, not from init
    l1 = parse_losses(out1)
    l2 = parse_losses(out2)
    assert l2[0] < l1[0] * 0.98


@pytest.mark.slow
def test_quantized_training_converges():
    out = run_train("--steps", "60", "--lr", "3e-2", "--quantize")
    losses = parse_losses(out)
    assert losses[-1] < losses[0] * 0.92, out[-2000:]
