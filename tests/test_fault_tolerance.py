"""Fault-tolerance substrate: checkpoint atomicity, resume, elastic reshard,
deterministic data pipeline, straggler mitigation, compressed collectives."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (AsyncCheckpointer, latest_step, restore_checkpoint,
                        save_checkpoint)
from repro.data import (StragglerTolerantLoader, SyntheticClassificationDataset,
                        SyntheticLMDataset)


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 7, t, extra={"note": "hi"})
    assert latest_step(tmp_path) == 7
    restored, step, extra = restore_checkpoint(tmp_path, t)
    assert step == 7 and extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    t = tree()
    for s in range(6):
        save_checkpoint(tmp_path, s, t, keep_n=3)
    kept = sorted(p.name for p in tmp_path.iterdir()
                  if p.name.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004", "step_00000005"]
    assert latest_step(tmp_path) == 5


def test_checkpoint_crash_leaves_no_corruption(tmp_path):
    """A stale tmp dir (simulated crash) must not break save/restore."""
    t = tree()
    save_checkpoint(tmp_path, 1, t)
    stale = tmp_path / "step_00000002.tmp-9999"
    stale.mkdir()
    (stale / "garbage").write_text("x")
    save_checkpoint(tmp_path, 2, t)
    restored, step, _ = restore_checkpoint(tmp_path, t)
    assert step == 2


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 1, t)
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.ones((5,), jnp.int32)}}
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(tmp_path, bad)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    t = tree()
    ck.save(3, t)
    ck.wait()
    assert latest_step(tmp_path) == 3


def test_elastic_restore_resharded(tmp_path):
    """Save replicated, restore with an explicit sharding on a 1-dev mesh
    (the reshard path: placement decided at restore time)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(tmp_path, 1, t)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _, _ = restore_checkpoint(tmp_path, t, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))


def test_data_pipeline_deterministic_and_sharded():
    ds_a = SyntheticLMDataset(100, 16, 8, seed=1, shard_id=0, num_shards=2)
    ds_b = SyntheticLMDataset(100, 16, 8, seed=1, shard_id=0, num_shards=2)
    ds_c = SyntheticLMDataset(100, 16, 8, seed=1, shard_id=1, num_shards=2)
    b1, b2, b3 = ds_a.batch_at(5), ds_b.batch_at(5), ds_c.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # resume-exact
    assert not np.array_equal(b1["tokens"], b3["tokens"])      # shards differ
    assert b1["tokens"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(
        b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_lm_data_is_learnable():
    """The Markov stream must be compressible (labels mostly follow the
    deterministic map) — otherwise convergence benchmarks are meaningless."""
    ds = SyntheticLMDataset(100, 64, 4, seed=0, noise=0.1)
    b = ds.batch_at(0)
    pred = (b["tokens"] * ds.a + ds.b) % ds.vocab
    agreement = float(np.mean(pred == b["labels"]))
    assert agreement > 0.8


def test_straggler_loader_substitutes_on_deadline():
    calls = {"n": 0}

    def slow_fetch(step):
        calls["n"] += 1
        if step == 2:
            time.sleep(1.0)  # straggling host
        return {"x": np.full((2,), step)}

    loader = StragglerTolerantLoader(slow_fetch, deadline_s=0.25, prefetch=1)
    try:
        got0 = loader.get(0)
        got1 = loader.get(1)
        t0 = time.time()
        got2 = loader.get(2)  # producer stalled -> substitute, within deadline
        elapsed = time.time() - t0
        assert elapsed < 0.9
        assert loader.skips >= 1
    finally:
        loader.close()


def test_classification_dataset_separable():
    ds = SyntheticClassificationDataset(input_dim=32, num_classes=4,
                                        n_train=512, n_test=128, noise=0.2)
    x, y = ds.test
    # nearest-template classification should be near-perfect at low noise
    pred = np.argmax(x @ ds.templates.T, axis=1)
    assert np.mean(pred == y) > 0.95


def test_compressed_psum_matches_dense():
    from repro.dist.collectives import compressed_psum_tree, dense_psum_tree
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 8)),
                          jnp.float32)}
    dense = dense_psum_tree(g, mesh, ("data",))
    comp = compressed_psum_tree(g, mesh, ("data",))
    # single replica: compression error only
    err = np.abs(np.asarray(dense["w"]) - np.asarray(comp["w"]))
    tol = np.abs(np.asarray(g["w"])).max() / 127.0
    assert err.max() <= tol + 1e-6
