"""The TaxoNN engine: SGD unrolled into an explicit per-layer G-chain.

This is the paper's Eq. (2)-(9) as a JAX program.  Back-propagation is NOT
delegated to ``jax.grad`` over the whole model; instead it is an explicit
reverse ``lax.scan`` whose carry is the paper's G vector:

    G_i = (G_{i+1} @ W_{i+1}) * f'_i          (Eq. 8)
    dE/dW_i = G_i  (x)  X_i                   (Eq. 9)
    W_i <- W_i - alpha * dE/dW_i              (Eq. 1, fused: step 4)

realised at *layer* granularity: each scan step runs a local VJP of one
layer's body at its cached (quantized) input X_i, quantizes the outgoing G,
and applies the weight update immediately — the full-model gradient tree is
never materialised (gradient lifetime = one scan step, the paper's pipeline
in Fig. 3).  Because the data-parallel all-reduce of each layer's dW is
issued *inside* the scan body, XLA overlaps it with the next layer's
backward compute — the TPU analogue of the paper's timing overlap.

Memory discipline matches the paper: the forward pass caches only each
layer's input X_i (quantized to the activation (I,F) format); everything
else (pre-activations, f') is recomputed in the backward body — this is
remat-per-layer, i.e. the paper's "activation derivation unit" executed on
the fly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.async_collectives import (all_gather_chunks, group_size,
                                          reduce_scatter_chunk,
                                          resolve_leaf_transports,
                                          shard_chunk,
                                          tree_all_reduce_start,
                                          tree_all_reduce_wait)
from repro.dist.collectives import compressed_psum
from repro.optim import OptimizerConfig, Hyper, apply_update
from repro.util.scan import xscan
from repro.quant.fixed_point import (
    BitSchedule,
    make_bit_schedule,
    maybe_quantize,
    quantize_ste,
    quantize_stochastic,
    stochastic_round_batched,
)

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Which tensor classes get the per-layer (I,F) treatment (static)."""

    quantize_weights: bool = True
    quantize_acts: bool = True
    quantize_grads: bool = True
    quantize_updates: bool = False   # strict paper mode: q(alpha*dW) in-format
    stochastic: bool = False         # stochastic rounding for grads/updates
    grad_scale: float = 1.0          # loss scaling for the low-bit G chain
    # KernelBackend knob: "off" (pure jnp), "emulate" (Pallas f32 kernels),
    # "int8" (int8 MXU datapath), "auto" (off on CPU, int8 on TPU).
    kernel_backend: str = "auto"
    # Route each layer's dW through the int8 block-scaled wire format inside
    # the backward scan (dist.collectives.compressed_psum).  With
    # ``dw_psum_axes`` naming mesh axes (engine running in a shard_map) the
    # all-reduce moves compressed bytes; with no axes it is the codec
    # round-trip only (single-replica numerics of the same wire format).
    # With axes named and ``compress_dw=False`` the dW all-reduce is a
    # dense psum over those axes.
    compress_dw: bool = False
    dw_psum_axes: tuple = ()
    # Communication-overlapped backward scan ("off" | "on"): layer i STARTS
    # its dW all-reduce (dense or compressed, via
    # dist.async_collectives) and WAITS one scan step later, so the
    # collective overlaps layer i-1's G-step/VJP compute — the paper's TDM
    # overlap applied to the interconnect.  With no ``dw_psum_axes`` this is
    # a pure schedule change (bit-identical results).
    overlap: str = "off"
    # Ring-group size override for the overlapped reduce (None = resolve
    # from the ambient mesh at trace time).
    dw_num_replicas: Optional[int] = None
    # Software-pipeline depth of the overlapped reduce: layer i STARTS its
    # dW all-reduce and the wait lands ``overlap_depth`` scan steps later,
    # keeping that many collectives in flight (clamped to the layer count).
    # Depth 2 gives a ring's hops two layers' compute to hide behind.
    overlap_depth: int = 2
    # Transport for the overlapped dW reduce: "auto" (per-bucket autotuner,
    # dist.async_collectives.decide_transport; REPRO_TRANSPORT overrides),
    # "ring" (chunked ppermute), or "psum" (fused blocking collective at
    # start — one rendezvous per layer — with a free wait).
    dw_transport: str = "auto"
    # Progressive bitwidth-annealing spec ("0:16,200:12,..." — see
    # repro.search.anneal.AnnealSchedule).  Consumed by make_train_step:
    # the effective per-layer F bits become a step-indexed ramp applied on
    # top of the run's BitSchedule.  None = no anneal.
    bit_anneal: Optional[str] = None

    @staticmethod
    def off() -> "QuantPolicy":
        return QuantPolicy(False, False, False, False, False, 1.0)


def default_bits_for(num_units: int, enabled: bool = True) -> BitSchedule:
    """Paper-style default: (2,12) weights/grads, (4,10) acts, ramped tail."""
    return make_bit_schedule(num_units, weight=(2, 12), act=(4, 10),
                             grad=(2, 12), enabled=enabled)


# ---------------------------------------------------------------------------
# Quantization helpers (leaf policies)
# ---------------------------------------------------------------------------

def _is_matmul_leaf(w: Array) -> bool:
    """Quantize matmul weights; keep vector params (norm scales, biases,
    A_log, dt_bias, ...) full precision — the paper's wide accumulator /
    derivation-unit registers."""
    return w.ndim >= 2


def quantize_weight_tree(tree: PyTree, w_i, w_f, enabled: Array,
                         on: bool) -> PyTree:
    if not on:
        return tree
    return jax.tree.map(
        lambda w: maybe_quantize(w, w_i, w_f, enabled) if _is_matmul_leaf(w) else w,
        tree)


def _quant_grad(g: Array, g_i, g_f, enabled: Array, policy: QuantPolicy,
                key: Optional[Array]) -> Array:
    if not policy.quantize_grads:
        return g
    gf = g.astype(jnp.float32)
    if policy.stochastic and key is not None:
        # noise keyed per (layer key, global batch row) — NOT per tensor
        # shape — so the stage-sharded pipeline, which quantizes G one
        # microbatch at a time, makes the exact same draws (see
        # stochastic_round_batched / grad_tap_stochastic)
        q = stochastic_round_batched(gf, g_i, g_f, key, 0)
    else:
        q = quantize_ste(gf, g_i, g_f)
    return (enabled * q + (1.0 - enabled) * gf).astype(g.dtype)


@jax.custom_vjp
def grad_tap(x: Array, g_i, g_f, enabled) -> Array:
    """Identity forward whose COTANGENT is quantized to the (g_i, g_f)
    grid — the G-chain's per-layer ``G <- q(G)`` (Eq. 8's low-bit signal)
    expressed as a forward-graph annotation.  Inserting this at each layer
    input makes a plain ``jax.vjp`` through the stack compute the same
    quantized G-chain the engine's reverse scan does — which is how the
    stage-sharded pipeline path (``dist.pipeline``) keeps engine numerics
    without a hand-written backward."""
    return x


def _grad_tap_fwd(x, g_i, g_f, enabled):
    return x, (g_i, g_f, enabled)


def _grad_tap_bwd(res, ct):
    g_i, g_f, enabled = res
    ctf = ct.astype(jnp.float32)
    q = quantize_ste(ctf, g_i, g_f)
    ct_q = (enabled * q + (1.0 - enabled) * ctf).astype(ct.dtype)
    return (ct_q, jnp.zeros_like(g_i), jnp.zeros_like(g_f),
            jnp.zeros_like(enabled))


grad_tap.defvjp(_grad_tap_fwd, _grad_tap_bwd)


@jax.custom_vjp
def grad_tap_stochastic(x: Array, g_i, g_f, enabled, key_data,
                        offset) -> Array:
    """``grad_tap`` with stochastic rounding: the cotangent is quantized
    with per-batch-row noise drawn from ``fold_in(wrap(key_data),
    offset + b)`` (see ``stochastic_round_batched``).  ``key_data`` is the
    layer key as raw uint32 (``jax.random.key_data``) so the custom_vjp
    signature stays free of typed-key cotangents; ``offset`` is the
    microbatch's first global batch row, which makes the pipeline's
    per-microbatch draws identical to the scan engine's full-batch ones."""
    return x


def _grad_tap_stoch_fwd(x, g_i, g_f, enabled, key_data, offset):
    return x, (g_i, g_f, enabled, key_data, offset)


def _grad_tap_stoch_bwd(res, ct):
    g_i, g_f, enabled, key_data, offset = res
    key = jax.random.wrap_key_data(key_data)
    ctf = ct.astype(jnp.float32)
    q = stochastic_round_batched(ctf, g_i, g_f, key, offset)
    ct_q = (enabled * q + (1.0 - enabled) * ctf).astype(ct.dtype)
    return (ct_q, jnp.zeros_like(g_i), jnp.zeros_like(g_f),
            jnp.zeros_like(enabled), jnp.zeros_like(key_data),
            jnp.zeros_like(offset))


grad_tap_stochastic.defvjp(_grad_tap_stoch_fwd, _grad_tap_stoch_bwd)


def quantize_update(g: Array, b_l: dict, key: Optional[Array],
                    enabled: Array, policy: QuantPolicy,
                    hyper: Hyper) -> Array:
    """Strict-paper mode: quantize the update itself (post-reduction).

    ``q(alpha * dW)`` in the layer's gradient (I,F) format, returned in the
    dW domain (divided back by lr) so the optimizer applies it unchanged.
    Shared by the scan engine's per-layer fused update and the stage-sharded
    pipeline's vmapped/overlapped update paths — both quantize the SAME
    post-reduction tensor with the SAME per-layer key, which is what keeps
    the two paths within float reassociation of each other.
    """
    if not policy.quantize_updates:
        return g
    upd = hyper.lr * g
    if policy.stochastic and key is not None:
        updq = quantize_stochastic(upd, b_l["g_i"], b_l["g_f"], key)
    else:
        updq = quantize_ste(upd, b_l["g_i"], b_l["g_f"])
    upd = enabled * updq + (1.0 - enabled) * upd
    return upd / jnp.maximum(hyper.lr, 1e-20)


def _bits_xs(bits: BitSchedule) -> dict:
    """BitSchedule arrays as scan xs (leading dim = num units)."""
    return {"w_i": bits.w_i, "w_f": bits.w_f, "a_i": bits.a_i, "a_f": bits.a_f,
            "g_i": bits.g_i, "g_f": bits.g_f}


# ---------------------------------------------------------------------------
# Forward: scan saving quantized layer inputs (the X_i registers)
# ---------------------------------------------------------------------------

def forward_stack(body_fn: Callable, stacked: PyTree, shared: PyTree,
                  x0: Array, bits: BitSchedule, policy: QuantPolicy,
                  quantize_shared: bool = True):
    """body_fn(params_slice, shared, x, bits_layer) -> (y, aux).

    Returns (x_final, X_caches [L,...], aux_sum).  X_caches hold the
    *quantized* layer inputs — exactly what the backward pass re-linearises
    at, so forward and backward see identical numerics.

    ``quantize_shared=False`` for shared *activations* (e.g. encoder output
    feeding every decoder layer) which are quantized once by the caller.
    """
    enabled = bits.enabled

    def fwd(x, xs):
        p_l, b_l = xs
        if policy.quantize_acts:
            xq = (enabled * quantize_ste(x.astype(jnp.float32),
                                         b_l["a_i"], b_l["a_f"])
                  + (1.0 - enabled) * x.astype(jnp.float32)).astype(x.dtype)
        else:
            xq = x
        wq = quantize_weight_tree(p_l, b_l["w_i"], b_l["w_f"], enabled,
                                  policy.quantize_weights)
        sq = (quantize_weight_tree(shared, b_l["w_i"], b_l["w_f"], enabled,
                                   policy.quantize_weights)
              if quantize_shared else shared)
        y, aux = body_fn(wq, sq, xq, b_l)
        return y, (xq, aux)

    x_final, (caches, auxs) = xscan(fwd, x0, (stacked, _bits_xs(bits)))
    return x_final, caches, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# Backward: the G-chain reverse scan with fused per-layer update
# ---------------------------------------------------------------------------

def overlap_depth_for(policy: QuantPolicy, n_units: int) -> int:
    """Effective pipeline depth: ``policy.overlap_depth`` clamped to the
    layer count (a 2-layer stack can keep at most 2 reduces in flight)."""
    depth = int(policy.overlap_depth)
    if depth < 1:
        raise ValueError(
            f"QuantPolicy.overlap_depth must be >= 1, got {depth}")
    return min(depth, int(n_units))


def _dw_leaf_transports(policy: QuantPolicy, stacked: PyTree) -> list:
    """STATIC per-leaf transport decisions for one layer's dW tree (the
    [1:] slice shapes of ``stacked``, reduced as f32 like ``_vjp_layer``
    emits them).  Plain strings, so the overlapped paths can shape their
    program around them at trace time: ``"ring"`` leaves have genuinely
    in-flight hops worth deferring ``overlap_depth`` iterations, while
    blocking transports (``"psum"``/``"scatter"``) complete at start and
    get a same-iteration update."""
    slices = [jax.ShapeDtypeStruct(a.shape[1:], jnp.float32)
              for a in jax.tree.leaves(stacked)]
    return resolve_leaf_transports(
        slices, policy.dw_psum_axes, compressed=policy.compress_dw,
        num_replicas=policy.dw_num_replicas, transport=policy.dw_transport)


def _make_blocking_layer_update(policy: QuantPolicy, hyper: Hyper,
                                optim_cfg: OptimizerConfig, enabled: Array,
                                decisions: list):
    """Per-layer reduce + quantize + update when every dW leaf rides a
    BLOCKING transport (no ring hops to hide): the update lands in the
    same scan iteration, so the overlapped scan carries no pending state.

    Two refinements over the blocking off-path body make ``overlap=on``
    a measured win even where nothing can truly overlap (host-CPU device
    groups):

      * psum-decided leaves are FUSED into one variadic ``lax.psum`` —
        one rendezvous per layer instead of one per leaf;
      * scatter-decided leaves get the ZeRO-style SHARDED update when the
        optimizer is elementwise (sgd, no grad clip): reduce-scatter the
        dW leaf, run quantize-update + optimizer on this device's 1/g
        chunk only, and all-gather the UPDATED params — same wire bytes,
        1/g the update traffic (measured ~1.7x per leaf at dW sizes).
        Elementwise math on identical chunk values keeps the result
        within reduction-order reassociation of the fused psum path.

    The sharded leaves' grad-norm contribution is device-local (each
    device squares only its chunk), so callers must close the step with
    ``gsq += lax.psum(gsq_sharded, axes)`` — returned flag says whether
    that collective is needed.  Returns ``(update_layer, uses_sharded)``
    where ``update_layer(p_l, dW, opt_l, b_l, key) -> (new_p, new_opt,
    gsq, gsq_sharded)``.
    """
    axes = tuple(policy.dw_psum_axes)
    axis = axes if len(axes) > 1 else (axes[0] if axes else None)
    g = group_size(axes, policy.dw_num_replicas) if axes else 1
    # sharded-update eligibility is static: the optimizer and the update
    # quantizer must be elementwise so chunk results equal full-tensor
    # results per element (momentum8's rowwise absmax, the per-leaf clip
    # norm, and positional stochastic-rounding noise are not)
    sharded_ok = (bool(axes) and g > 1 and optim_cfg.kind == "sgd"
                  and optim_cfg.grad_clip == 0
                  and not policy.compress_dw
                  and not (policy.quantize_updates and policy.stochastic))
    sharded = [d == "scatter" and sharded_ok for d in decisions]
    uses_sharded = any(sharded)

    def update_layer(p_l, dW, opt_l, b_l, key):
        def qu(gg):
            return quantize_update(gg, b_l, key, enabled, policy, hyper)
        zero = jnp.float32(0.0)
        if not uses_sharded:
            # one fused blocking reduce + whole-tree update: the off
            # path's numerics, any optimizer
            leaves, treedef = jax.tree.flatten(dW)
            if policy.compress_dw:
                leaves = [compressed_psum(x, axes,
                                          num_replicas=policy.dw_num_replicas)
                          for x in leaves]
            elif axes:
                leaves = list(lax.psum(tuple(leaves), axes))
            leaves = [qu(x) for x in leaves]
            dWq = jax.tree.unflatten(treedef, leaves)
            new_p, new_opt = apply_update(p_l, dWq, opt_l, hyper, optim_cfg)
            gsq = sum(jnp.sum(jnp.square(x)) for x in leaves)
            return new_p, new_opt, gsq, zero
        p_leaves, ptd = jax.tree.flatten(p_l)
        g_leaves = jax.tree.leaves(dW)
        fuse = [i for i, s in enumerate(sharded) if not s]
        red = {}
        if fuse:
            reduced = (lax.psum(tuple(g_leaves[i] for i in fuse), axes)
                       if axes else [g_leaves[i] for i in fuse])
            red = dict(zip(fuse, reduced))
        new_leaves: list = [None] * len(p_leaves)
        gsq, gsq_sh = zero, zero
        for i, (pw, gw) in enumerate(zip(p_leaves, g_leaves)):
            if sharded[i]:
                chunk = qu(reduce_scatter_chunk(gw, axis, g))
                own = shard_chunk(pw, axis, g)
                new_chunk, _ = apply_update(own, chunk, {}, hyper, optim_cfg)
                new_leaves[i] = all_gather_chunks(new_chunk, axis, g,
                                                 tuple(pw.shape), pw.dtype)
                gsq_sh = gsq_sh + jnp.sum(jnp.square(chunk))
            else:
                gq = qu(red[i])
                new_leaves[i], _ = apply_update(pw, gq, {}, hyper, optim_cfg)
                gsq = gsq + jnp.sum(jnp.square(gq))
        # sgd is stateless (sharded_ok implies it): opt_l passes through
        return jax.tree.unflatten(ptd, new_leaves), opt_l, gsq, gsq_sh

    return update_layer, uses_sharded


def _overlapped_update_helpers(policy: QuantPolicy, hyper: Hyper,
                               optim_cfg: OptimizerConfig, enabled: Array,
                               key_for: Callable, depth: int):
    """Scaffolding of the ``depth``-deep software-pipelined per-layer dW
    reduce, shared by the overlapped backward scan and the stacked update
    tail (``apply_stacked_updates``) so the subtlest pieces exist exactly
    once.  The carry holds a tuple of ``depth`` pending entries, OLDEST
    first; each scan step starts one reduce and finalizes the oldest, so a
    layer's collective has ``depth`` layers' compute to hide behind:

    ``start``     issue a layer's all-reduce (dense or compressed, with the
                  policy's transport — autotuned by default)
    ``finalize``  wait on one in-flight entry, update-quantize, land the
                  delayed optimizer step; returns (new_p, new_opt, gsq)
    ``pending0``  warm-up carry: ``depth`` zero-slice entries with dummy
                  handles (no hops; finalizing one is a no-op update)
    ``drain``     finalize the ``depth`` entries still in flight after the
                  scan (oldest first); returns (flushes, gsq_sum)
    ``align``     undo the reverse scan's ``depth``-slot lag — ys slot i
                  holds the FINALIZED layer i+depth (the top ``depth``
                  slots warm-up garbage) and the drained layers
                  depth-1..0 are prepended in layer order
    """
    def start(dW, dummy=False):
        return tree_all_reduce_start(dW, policy.dw_psum_axes,
                                     compressed=policy.compress_dw,
                                     num_replicas=policy.dw_num_replicas,
                                     dummy=dummy,
                                     transport=policy.dw_transport)

    def finalize(pending):
        dW = tree_all_reduce_wait(pending["h"])
        key = key_for(pending["idx"])
        dW = jax.tree.map(
            lambda g: quantize_update(g, pending["bits"], key, enabled,
                                      policy, hyper), dW)
        new_p, new_opt = apply_update(pending["p"], dW, pending["opt"],
                                      hyper, optim_cfg)
        gsq = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(dW))
        return new_p, new_opt, gsq

    def slice0(tree, dtype=None):
        return jax.tree.map(
            lambda a: jnp.zeros(a.shape[1:], dtype or a.dtype), tree)

    def pending0(stacked, opt_stacked, bits_xs):
        entry = {"p": slice0(stacked), "opt": slice0(opt_stacked),
                 "h": start(slice0(stacked, jnp.float32), dummy=True),
                 "bits": slice0(bits_xs), "idx": jnp.int32(0)}
        return (entry,) * depth

    def drain(pending):
        flushes, gsq = [], jnp.float32(0.0)
        for entry in pending:       # oldest first: layers depth-1 .. 0
            new_p, new_opt, ginc = finalize(entry)
            flushes.append((new_p, new_opt))
            gsq = gsq + ginc
        return flushes, gsq

    def align(flushes, ys):
        # flushes arrive finalize-order (layer depth-1 first); stack them
        # in LAYER order and prepend to the ys slots that hold real layers
        stackf = jax.tree.map(lambda *fs: jnp.stack(list(fs)),
                              *reversed(flushes))
        return jax.tree.map(
            lambda f, y: jnp.concatenate([f, y[:-depth]], axis=0),
            stackf, ys)

    return start, finalize, pending0, drain, align


def backward_stack(body_fn: Callable, stacked: PyTree, shared: PyTree,
                   opt_stacked: PyTree, caches: PyTree, bits: BitSchedule,
                   G_out: Array, hyper: Hyper, policy: QuantPolicy,
                   optim_cfg: OptimizerConfig, aux_coef: float,
                   base_key: Optional[Array] = None,
                   quantize_shared: bool = True):
    """Reverse scan over layers.

    Per step (= paper steps 1-4 in one TDM frame):
      1. re-linearise the layer body at (q(W_i), q(X_i))   [VJP]
      2. dW_i, dShared_i, G_i  <- vjp(G_{i+1})
      3. G_i <- q(G_i)  (the low-bit backward signal sent upstream)
      4. W_i <- W_i - lr * dW_i  (fused update; DP all-reduce of dW_i is
         inside this scan body -> overlapped with step i-1's compute)

    With ``policy.overlap == "on"`` step 4's strategy follows the STATIC
    per-leaf transport decisions (``policy.dw_transport`` — autotuned by
    default, dist.async_collectives).  Ring-decided leaves have genuinely
    in-flight hops, so the whole layer tree is software-pipelined
    ``policy.overlap_depth`` scan steps deep: layer i STARTS its dW
    all-reduce and the update lands ``depth`` iterations later, the
    handles riding in the carry, so each collective overlaps ``depth``
    layers' VJP/G-step compute; the last ``depth`` in-flight layers are
    flushed after the scan.  When every leaf rides a BLOCKING transport
    (fused psum / native reduce-scatter) the reduce completes at start,
    so the update lands in the SAME iteration — one fused rendezvous per
    layer, and scatter-decided leaves run the optimizer on their 1/g
    chunk before all-gathering the updated params (the sharded update
    that makes ``overlap=on`` a measured win even on host-CPU groups
    where nothing can truly overlap).  With no ``dw_psum_axes`` both
    shapes degrade to the blocking one-device scan and the overlapped
    path computes bit-identical results — a pure schedule change.

    Gradient-scale convention: ``G_out`` arrives SCALED by policy.grad_scale
    (loss scaling for the low-bit chain).  dW is un-scaled just before the
    update; G and dShared stay in the scaled domain (callers un-scale when
    the gradient leaves the chain).

    Returns (G_in, new_stacked, new_opt, dShared_accum_SCALED, grad_sq_sum).
    """
    if policy.overlap not in ("off", "on"):
        raise ValueError(f"QuantPolicy.overlap must be 'off' or 'on', got "
                         f"{policy.overlap!r}")
    overlap = policy.overlap == "on"
    enabled = bits.enabled
    n_units = jax.tree.leaves(stacked)[0].shape[0]
    inv_scale = 1.0 / policy.grad_scale

    shared_f32 = jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), shared)

    def _key_for(idx):
        return (jax.random.fold_in(base_key, idx)
                if (base_key is not None and policy.stochastic) else None)

    def _quant_update(g, b_l, key):
        return quantize_update(g, b_l, key, enabled, policy, hyper)

    def _vjp_layer(G, p_l, x_l, b_l):
        def f(pw, sw, xx):
            wq = quantize_weight_tree(pw, b_l["w_i"], b_l["w_f"], enabled,
                                      policy.quantize_weights)
            sq = (quantize_weight_tree(sw, b_l["w_i"], b_l["w_f"], enabled,
                                       policy.quantize_weights)
                  if quantize_shared else sw)
            return body_fn(wq, sq, xx, b_l)

        (y, aux), vjp = jax.vjp(f, p_l, shared, x_l)
        dW, dS, dX = vjp((G.astype(y.dtype),
                          jnp.asarray(aux_coef * policy.grad_scale,
                                      jnp.float32)))
        dW = jax.tree.map(lambda g: g.astype(jnp.float32) * inv_scale, dW)
        return dW, dS, dX

    if not overlap:
        def bwd(carry, xs):
            G, dshared_acc, gsq = carry
            p_l, opt_l, x_l, b_l, idx = xs
            dW, dS, dX = _vjp_layer(G, p_l, x_l, b_l)
            key = _key_for(idx)
            G_next = _quant_grad(dX, b_l["g_i"], b_l["g_f"], enabled, policy,
                                 key)

            def prep(g):
                if policy.compress_dw:
                    # per-layer dW through the int8 block-scaled wire format
                    # (and its all-reduce when mesh axes are named) — issued
                    # inside the scan body so it overlaps the next layer's
                    # G-step, the paper's timing overlap at pod scale
                    g = compressed_psum(g, policy.dw_psum_axes,
                                        num_replicas=policy.dw_num_replicas)
                elif policy.dw_psum_axes:
                    g = lax.psum(g, policy.dw_psum_axes)
                return _quant_update(g, b_l, key)
            dW = jax.tree.map(prep, dW)

            new_p, new_opt = apply_update(p_l, dW, opt_l, hyper, optim_cfg)
            gsq = gsq + sum(jnp.sum(jnp.square(g))
                            for g in jax.tree.leaves(dW))
            dshared_acc = jax.tree.map(
                lambda a, d: a + d.astype(jnp.float32), dshared_acc, dS)
            return (G_next, dshared_acc, gsq), (new_p, new_opt)

        xs = (stacked, opt_stacked, caches, _bits_xs(bits),
              jnp.arange(n_units, dtype=jnp.int32))
        (G_in, dshared, gsq), (new_stacked, new_opt) = xscan(
            bwd, (G_out, shared_f32, jnp.float32(0.0)), xs, reverse=True)
        return G_in, new_stacked, new_opt, dshared, gsq

    # ---- communication-overlapped software pipeline ----------------------
    decisions = _dw_leaf_transports(policy, stacked)
    if "ring" not in decisions:
        # every dW leaf rides a BLOCKING transport: its reduce completes
        # at start, so deferring the update `depth` iterations buys no
        # overlap and only pays for it (pending-carry rotation, dummy
        # warm-up finalizes, drain realignment — measured ~10% of step
        # walltime).  Land each layer's update in the SAME iteration with
        # the fused-psum / sharded-scatter strategies instead.
        _update_layer, uses_sharded = _make_blocking_layer_update(
            policy, hyper, optim_cfg, enabled, decisions)

        def bwd(carry, xs):
            G, dshared_acc, gsq, gsq_sh = carry
            p_l, opt_l, x_l, b_l, idx = xs
            dW, dS, dX = _vjp_layer(G, p_l, x_l, b_l)
            key = _key_for(idx)
            G_next = _quant_grad(dX, b_l["g_i"], b_l["g_f"], enabled,
                                 policy, key)
            new_p, new_opt, ginc, ginc_sh = _update_layer(
                p_l, dW, opt_l, b_l, key)
            dshared_acc = jax.tree.map(
                lambda a, d: a + d.astype(jnp.float32), dshared_acc, dS)
            return (G_next, dshared_acc, gsq + ginc, gsq_sh + ginc_sh), \
                (new_p, new_opt)

        xs = (stacked, opt_stacked, caches, _bits_xs(bits),
              jnp.arange(n_units, dtype=jnp.int32))
        (G_in, dshared, gsq, gsq_sh), (new_stacked, new_opt) = xscan(
            bwd, (G_out, shared_f32, jnp.float32(0.0), jnp.float32(0.0)),
            xs, reverse=True)
        if uses_sharded:
            # sharded leaves squared only this device's chunk
            gsq = gsq + lax.psum(gsq_sh, policy.dw_psum_axes)
        return G_in, new_stacked, new_opt, dshared, gsq

    depth = overlap_depth_for(policy, n_units)
    _start, _finalize, _pending0, _drain, _align = _overlapped_update_helpers(
        policy, hyper, optim_cfg, enabled, _key_for, depth)

    def bwd(carry, xs):
        G, dshared_acc, gsq, pending = carry
        p_l, opt_l, x_l, b_l, idx = xs
        dW, dS, dX = _vjp_layer(G, p_l, x_l, b_l)
        G_next = _quant_grad(dX, b_l["g_i"], b_l["g_f"], enabled, policy,
                             _key_for(idx))
        # start layer i's reduce; land layer i+depth's (its hops overlapped
        # the last `depth` iterations' VJP compute)
        handles = _start(dW)
        fin_p, fin_opt, gsq_inc = _finalize(pending[0])
        pending_new = pending[1:] + ({"p": p_l, "opt": opt_l, "h": handles,
                                      "bits": b_l, "idx": idx},)
        dshared_acc = jax.tree.map(
            lambda a, d: a + d.astype(jnp.float32), dshared_acc, dS)
        return (G_next, dshared_acc, gsq + gsq_inc, pending_new), \
            (fin_p, fin_opt)

    xs = (stacked, opt_stacked, caches, _bits_xs(bits),
          jnp.arange(n_units, dtype=jnp.int32))
    (G_in, dshared, gsq, pending), (fin_stacked, fin_opt) = xscan(
        bwd, (G_out, shared_f32, jnp.float32(0.0),
              _pending0(stacked, opt_stacked, _bits_xs(bits))), xs,
        reverse=True)
    # drain: layers depth-1..0's reduces are still in flight after the scan
    flushes, gsq_f = _drain(pending)
    return (G_in, _align([f[0] for f in flushes], fin_stacked),
            _align([f[1] for f in flushes], fin_opt), dshared, gsq + gsq_f)


# ---------------------------------------------------------------------------
# Stacked-dW update tail (the stage-sharded pipeline path)
# ---------------------------------------------------------------------------

def apply_stacked_updates(stacked: PyTree, dW: PyTree, opt_stacked: PyTree,
                          bits: BitSchedule, hyper: Hyper,
                          policy: QuantPolicy, optim_cfg: OptimizerConfig,
                          base_key: Optional[Array] = None):
    """Reduce + quantize + apply per-layer updates of a fully materialised
    stacked dW tree — the update tail of the stage-sharded pipeline path,
    where ``jax.vjp`` through ``dist.pipeline`` hands back all layers' dW
    at once instead of one layer per reverse-scan step.

    Per layer (mirroring ``backward_stack``'s fused step 4, same order and
    same per-layer PRNG keys, so both paths agree to float reassociation):
    the dW leaves go through ``compressed_psum`` (``policy.compress_dw``)
    or a dense ``lax.psum`` over ``policy.dw_psum_axes`` — composing the
    pipe axis with the data axis — then ``quantize_update`` (strict-paper
    ``q(alpha*dW)``), then the optimizer.

    ``policy.overlap == "off"``: one vmap over the layer axis.
    ``policy.overlap == "on"``: identical in structure to the overlapped
    backward scan — ring-decided leaves ride a reverse scan whose
    per-layer reduce is software-pipelined ``policy.overlap_depth`` steps
    deep (start layer i's reduce, land layer i+depth's while its hops
    overlap this step's update compute); when every leaf's transport is
    blocking the updates land same-iteration with the fused-psum /
    sharded-scatter strategies instead.  With no ``dw_psum_axes`` the
    reduces are identities and the results are bitwise equal to the
    vmapped path.

    Returns ``(new_stacked, new_opt, grad_sq_sum)``.
    """
    enabled = bits.enabled
    n_units = jax.tree.leaves(stacked)[0].shape[0]
    bxs = _bits_xs(bits)
    idxs = jnp.arange(n_units, dtype=jnp.int32)

    def _key_for(idx):
        return (jax.random.fold_in(base_key, idx)
                if (base_key is not None and policy.stochastic) else None)

    if policy.overlap != "on":
        def upd(p_l, g_l, s_l, b_l, idx):
            key = _key_for(idx)

            def prep(g):
                if policy.compress_dw:
                    g = compressed_psum(g, policy.dw_psum_axes,
                                        num_replicas=policy.dw_num_replicas)
                elif policy.dw_psum_axes:
                    g = lax.psum(g, policy.dw_psum_axes)
                return quantize_update(g, b_l, key, enabled, policy, hyper)

            g_l = jax.tree.map(prep, g_l)
            new_p, new_s = apply_update(p_l, g_l, s_l, hyper, optim_cfg)
            gsq = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(g_l))
            return new_p, new_s, gsq

        new_p, new_s, gsqs = jax.vmap(upd)(stacked, dW, opt_stacked, bxs,
                                           idxs)
        return new_p, new_s, jnp.sum(gsqs)

    decisions = _dw_leaf_transports(policy, stacked)
    if "ring" not in decisions:
        # all-blocking transports: same-iteration updates (see
        # backward_stack) — a reverse scan to keep the layer-major
        # collective order identical to the overlapped backward scan
        _update_layer, uses_sharded = _make_blocking_layer_update(
            policy, hyper, optim_cfg, enabled, decisions)

        def body(carry, xs):
            gsq, gsq_sh = carry
            p_l, g_l, s_l, b_l, idx = xs
            new_p, new_s, ginc, ginc_sh = _update_layer(
                p_l, g_l, s_l, b_l, _key_for(idx))
            return (gsq + ginc, gsq_sh + ginc_sh), (new_p, new_s)

        xs = (stacked, dW, opt_stacked, bxs, idxs)
        (gsq, gsq_sh), (new_p, new_s) = xscan(
            body, (jnp.float32(0.0), jnp.float32(0.0)), xs, reverse=True)
        if uses_sharded:
            gsq = gsq + lax.psum(gsq_sh, policy.dw_psum_axes)
        return new_p, new_s, gsq

    depth = overlap_depth_for(policy, n_units)
    _start, _finalize, _pending0, _drain, _align = _overlapped_update_helpers(
        policy, hyper, optim_cfg, enabled, _key_for, depth)

    def body(carry, xs):
        gsq, pending = carry
        p_l, g_l, s_l, b_l, idx = xs
        handles = _start(g_l)
        fin_p, fin_s, ginc = _finalize(pending[0])
        pending_new = pending[1:] + ({"p": p_l, "opt": s_l, "h": handles,
                                      "bits": b_l, "idx": idx},)
        return (gsq + ginc, pending_new), (fin_p, fin_s)

    xs = (stacked, dW, opt_stacked, bxs, idxs)
    (gsq, pending), (fin_p, fin_s) = xscan(
        body, (jnp.float32(0.0), _pending0(stacked, opt_stacked, bxs)), xs,
        reverse=True)
    # drain + re-align exactly like the overlapped backward scan above
    flushes, gsq_f = _drain(pending)
    return (_align([f[0] for f in flushes], fin_p),
            _align([f[1] for f in flushes], fin_s), gsq + gsq_f)
