"""The paper's LeNet-class evaluation network on the kernel datapath.

This is Fig. 3 made literal: a 5-layer MLP classifier whose train step runs
every SGD-unit frame through the fused Pallas kernels —

    forward            fxp_matmul      (per-layer (I,F) MACs)
    head G seed        bp_gstep        (Eq. 8 against W_out)
    hidden frames      bp_fused_unit   (Eq. 8 + Eq. 9 + Eq. 1, one pass)
    input/head update  sgd_dw_update   (Eq. 9 + Eq. 1 fused)

Layers are Python-unrolled (the paper's network is 5 layers) so each layer
carries its own *static* (I,F) design point — exactly how the chip loads a
Table-I schedule into its per-layer format registers.  Three backends share
the math: ``off`` (jnp oracles — the correctness contract), ``emulate``
(Pallas kernels, f32 MACs), and ``int8`` (int8 MXU operands with int32
wide accumulators).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.lenet5 import LeNetConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.ops import resolve_backend

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LeNetBits:
    """Per-layer static (I,F) design points (None entries = full precision).

    ``w``/``a``/``g`` each hold ``num_layers`` tuples: weights, activations
    (layer inputs), gradients (the G chain) — the three tensor classes the
    paper quantizes (Table I).
    """

    w: tuple
    a: tuple
    g: tuple

    @property
    def num_layers(self) -> int:
        return len(self.w)


def lenet_bits(num_layers: int, weight=(2, 12), act=(4, 10),
               grad=(2, 12)) -> LeNetBits:
    return LeNetBits(w=(weight,) * num_layers, a=(act,) * num_layers,
                     g=(grad,) * num_layers)


def lenet_bits_off(num_layers: int) -> LeNetBits:
    return LeNetBits(w=(None,) * num_layers, a=(None,) * num_layers,
                     g=(None,) * num_layers)


def lenet_bits_table(points: Sequence[tuple]) -> LeNetBits:
    """One (I,F) per layer applied to all three classes (Table-I style)."""
    pts = tuple(points)
    return LeNetBits(w=pts, a=pts, g=pts)


def init_lenet_params(key, cfg: LeNetConfig) -> dict:
    """Same layout as benchmarks/convergence: w_in + stacked hidden + w_out."""
    n_hidden = cfg.num_layers - 2
    ks = jax.random.split(key, 3)
    return {
        "w_in": jax.random.normal(ks[0], (cfg.input_dim, cfg.hidden),
                                  jnp.float32) * cfg.input_dim ** -0.5,
        "hidden": jax.random.normal(
            ks[1], (n_hidden, cfg.hidden, cfg.hidden),
            jnp.float32) * cfg.hidden ** -0.5,
        "w_out": jax.random.normal(ks[2], (cfg.hidden, cfg.num_classes),
                                   jnp.float32) * cfg.hidden ** -0.5,
    }


def make_lenet_train_step(cfg: LeNetConfig, bits: Optional[LeNetBits] = None,
                          kernel_backend: str = "off"):
    """Build ``step(params, batch, lr) -> (params, metrics)``.

    ``batch`` = (x [B, input_dim] f32, y [B] int32).  SGD only (the paper's
    optimizer); the update is fused into the backward kernels.
    """
    backend = resolve_backend(kernel_backend)
    bits = bits or lenet_bits_off(cfg.num_layers)
    assert bits.num_layers == cfg.num_layers, (bits.num_layers, cfg.num_layers)
    n_hidden = cfg.num_layers - 2
    datapath = "int8" if backend == "int8" else "emulate"

    def _mm(x, w, li):
        if backend == "off":
            return kref.fxp_matmul_ref(x, w, xa_bits=bits.a[li],
                                       w_bits=bits.w[li], out_bits=None,
                                       act="identity")
        return kops.fxp_matmul_op(x, w, xa_bits=bits.a[li], w_bits=bits.w[li],
                                  out_bits=None, act="identity",
                                  datapath=datapath)

    def _gstep(g, w, z, li):
        if backend == "off":
            return kref.bp_gstep_ref(g, w, z, g_bits=bits.g[li], act="relu")
        return kops.bp_gstep_op(g, w, z, g_bits=bits.g[li], act="relu",
                                datapath=datapath, g_in_bits=bits.g[li + 1]
                                if li + 1 < cfg.num_layers else None,
                                w_bits=bits.w[li + 1]
                                if li + 1 < cfg.num_layers else None)

    def _dw_update(x, g, w, lr, li):
        if backend == "off":
            return kref.sgd_dw_update_ref(x, g, w, lr, w_bits=None)
        return kops.sgd_dw_update_op(x, g, w, lr, w_bits=None,
                                     datapath=datapath, xa_bits=bits.a[li],
                                     g_in_bits=bits.g[li])

    def _frame(g, w, x, z, lr, li):
        """The layer-li TDM frame: consumes G_{z_li}, produces
        (G_{z_{li-1}}, W_li_new)."""
        if backend == "off":
            return kref.bp_fused_unit_ref(
                g, w, x, z, lr, g_bits=bits.g[li - 1], w_bits=bits.w[li],
                w_out_bits=None, act="relu")
        return kops.bp_fused_unit_op(
            g, w, x, z, lr, g_bits=bits.g[li - 1], w_bits=bits.w[li],
            w_out_bits=None, act="relu", datapath=datapath,
            g_in_bits=bits.g[li], xa_bits=bits.a[li])

    def step(params, batch, lr):
        x, y = batch
        bsz = x.shape[0]

        # ---- forward: cache every pre-activation (the Z registers) -------
        zs, hs = [], []
        z = _mm(x, params["w_in"], 0)
        h = jnp.maximum(z, 0.0)
        zs.append(z)
        hs.append(h)
        for i in range(n_hidden):
            z = _mm(h, params["hidden"][i], i + 1)
            h = jnp.maximum(z, 0.0)
            zs.append(z)
            hs.append(h)
        logits = _mm(h, params["w_out"], cfg.num_layers - 1)

        ls = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(ls, y[:, None], 1))
        acc = jnp.mean(jnp.argmax(logits, -1) == y)
        dlogits = (jax.nn.softmax(logits)
                   - jax.nn.one_hot(y, cfg.num_classes)) / bsz

        # ---- backward: the G chain, one fused frame per hidden layer -----
        # head: Eq. 8 seed against W_out + its fused update
        g = _gstep(dlogits, params["w_out"], zs[-1], cfg.num_layers - 2)
        new_w_out = _dw_update(hs[-1], dlogits, params["w_out"], lr,
                               cfg.num_layers - 1)
        new_hidden = [None] * n_hidden
        for i in reversed(range(n_hidden)):
            g, w_new = _frame(g, params["hidden"][i], hs[i], zs[i], lr, i + 1)
            new_hidden[i] = w_new
        new_w_in = _dw_update(x, g, params["w_in"], lr, 0)

        new_params = {
            "w_in": new_w_in,
            "hidden": jnp.stack(new_hidden) if new_hidden
            else params["hidden"],
            "w_out": new_w_out,
        }
        return new_params, {"loss": loss, "acc": acc}

    return step
