"""Train/eval step builders: the TaxoNN engine vs the autodiff baseline.

``make_train_step(cfg, policy, optim_cfg, engine)`` returns a jit-able

    step(params, opt_state, batch, hyper, bits) -> (params, opt_state, metrics)

engine="taxonn"   — the paper's unrolled G-chain with per-layer fused update
engine="autodiff" — monolithic jax.grad + global optimizer apply (the
                    "conventional accelerator" baseline the paper compares
                    against; also the correctness oracle for the engine)

``bits`` is a dict of runtime BitSchedules keyed by stack name ("blocks",
and "enc_blocks" for encdec).  One compiled step serves every schedule.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.taxonn import (
    QuantPolicy,
    _bits_xs,
    apply_stacked_updates,
    backward_stack,
    default_bits_for,
    forward_stack,
    grad_tap,
    grad_tap_stochastic,
    quantize_weight_tree,
)
from repro.kernels.ops import kernel_backend_ctx, resolve_backend
from repro.quant.fixed_point import quantize_ste
from repro.util.scan import xscan
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import Hyper, OptimizerConfig, apply_update, init_opt_state

Array = jax.Array

AUX_COEF = lm.AUX_COEF


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------

STACK_KEYS = ("blocks", "enc_blocks")
SHARED_KEYS = ("shared_attn",)


def boundary_keys(params: dict):
    return tuple(k for k in params
                 if k not in STACK_KEYS and k not in SHARED_KEYS)


def init_train_state(params: dict, optim_cfg: OptimizerConfig) -> dict:
    """Optimizer state mirrored on the params' top-level grouping so the
    engine can scan per-layer slices of each stack's state."""
    return {k: init_opt_state(v, optim_cfg) for k, v in params.items()}


def default_bits(cfg: ModelConfig, enabled: bool = True) -> dict:
    n = num_scan_units(cfg)
    bits = {"blocks": default_bits_for(n, enabled)}
    if cfg.family == "encdec":
        bits["enc_blocks"] = default_bits_for(cfg.num_encoder_layers, enabled)
    return bits


def num_scan_units(cfg: ModelConfig) -> int:
    """Engine-visible layers in the main stack (hybrid scans groups)."""
    if cfg.family == "hybrid":
        return lm.hybrid_groups(cfg)[0]
    return cfg.num_layers


# ---------------------------------------------------------------------------
# Resume-state capture: everything a bitwise restart needs beyond params
# ---------------------------------------------------------------------------

RESUME_SCHEMA = 1


def capture_resume_extra(cfg: ModelConfig, step: int, *, loader=None,
                         user_extra: Optional[dict] = None,
                         anneal=None) -> dict:
    """The checkpoint ``extra`` payload that makes a restart BITWISE.

    (params, opt_state) alone under-specify a resumed step: the restarted
    loop also needs (a) the data-pipeline step, so the step-indexed loader
    replays the exact batch stream, (b) the stochastic-rounding RNG
    convention — the engine folds a fixed base key with the step index, so
    recording the step pins the whole stream, and (c) the primed transport
    cache, so the resumed backward scan instantiates the SAME collective
    schedule the killed run measured (a re-measurement could flip a
    ring/psum/scatter decision and change reduction order), and (d) the
    kernel tune cache, so a resumed run replays the SAME block-shape /
    fusion decisions instead of re-deriving them.  Everything is
    msgpack-scalar/str, so it rides the checkpoint manifest unchanged.
    """
    from repro.dist.async_collectives import transport_cache_snapshot
    from repro.kernels.ops import tune_cache_snapshot
    extra = {
        "resume_schema": RESUME_SCHEMA,
        "arch": cfg.name,
        "family": cfg.family,
        "data_step": int(step),
        "transport_cache": transport_cache_snapshot(),
        "tune_cache": tune_cache_snapshot(),
    }
    if anneal is not None:
        # record the bit-anneal spec: the annealed bits are a pure function
        # of the step, so resume is bitwise automatically — the spec rides
        # along only to GUARD against resuming under a different ramp
        from repro.search.anneal import AnnealSchedule
        extra["bit_anneal"] = AnnealSchedule.parse(anneal).spec
    if loader is not None:
        extra["loader"] = {"served": int(loader.served),
                           "skips": int(loader.skips),
                           "stale_drops": int(getattr(loader, "stale_drops",
                                                      0))}
    if user_extra:
        extra.update(user_extra)
    return extra


def apply_resume_extra(extra: dict, cfg: ModelConfig,
                       ckpt_step: int, *, anneal=None) -> int:
    """Validate + install a checkpoint's resume payload.

    Rejects a checkpoint written by a different arch (restoring qwen state
    into gemma is silent corruption the shape check alone may not catch),
    installs the persisted transport-cache decisions, and returns the data
    step to resume from (falling back to the checkpoint step for pre-schema
    checkpoints, whose save convention was step == next data step).
    """
    extra = extra or {}
    arch = extra.get("arch")
    if arch is not None and arch != cfg.name:
        raise ValueError(
            f"checkpoint was written by arch {arch!r}; refusing to resume "
            f"it as {cfg.name!r}")
    ckpt_anneal = extra.get("bit_anneal")
    cur_anneal = None
    if anneal is not None:
        from repro.search.anneal import AnnealSchedule
        cur_anneal = AnnealSchedule.parse(anneal).spec
    if ckpt_anneal is not None and cur_anneal is not None \
            and ckpt_anneal != cur_anneal:
        raise ValueError(
            f"checkpoint was annealed under {ckpt_anneal!r}; resuming with "
            f"{cur_anneal!r} would change the bit ramp mid-run (pass the "
            f"same --bit-anneal spec to resume)")
    if (ckpt_anneal is None) != (cur_anneal is None):
        warnings.warn(
            f"bit-anneal mismatch at resume: checkpoint={ckpt_anneal!r} "
            f"current={cur_anneal!r} — the effective bit schedule changes "
            f"at the restart boundary", RuntimeWarning, stacklevel=2)
    cache = extra.get("transport_cache")
    if cache:
        from repro.dist.async_collectives import load_transport_cache
        n = load_transport_cache(cache)
        if n:
            print(f"[train] restored {n} transport-cache decision(s) from "
                  f"checkpoint", flush=True)
    tune = extra.get("tune_cache")
    if tune:
        from repro.kernels.ops import load_tune_cache
        n = load_tune_cache(tune)
        if n:
            print(f"[train] restored {n} tune-cache decision(s) from "
                  f"checkpoint", flush=True)
    return int(extra.get("data_step", ckpt_step))


# ---------------------------------------------------------------------------
# Per-family stack bodies: body(params_slice, shared, x, bits_l) -> (y, aux)
# ---------------------------------------------------------------------------

def _make_body(cfg: ModelConfig, positions, enc_out_in_shared: bool = False,
               moe_aux_parts: bool = False):
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def body(p, shared, x, b_l):
            return B.transformer_block(p, x, cfg, positions,
                                       moe_aux_parts=moe_aux_parts)
        return body

    if fam == "ssm":
        def body(p, shared, x, b_l):
            return B.mamba_block(p, x, cfg, positions)
        return body

    if fam == "hybrid":
        def body(gp, shared, x, b_l):
            h, _ = B.transformer_block(shared, x, cfg, positions)

            @jax.checkpoint
            def inner(hh, p):
                h2, aux = B.mamba_block(p, hh, cfg, positions)
                return h2, aux
            h, auxs = xscan(inner, h, gp)
            return h, jnp.sum(auxs)
        return body

    if fam == "encdec":
        def body(p, shared, x, b_l):
            (enc_out,) = shared
            return B.decoder_block(p, x, cfg, positions, enc_out)
        return body

    raise ValueError(fam)


def _enc_body(cfg: ModelConfig, positions):
    def body(p, shared, x, b_l):
        return B.transformer_block(p, x, cfg, positions, causal=False)
    return body


# ---------------------------------------------------------------------------
# Boundary (embed / head) functions
# ---------------------------------------------------------------------------

def _embed_fn(cfg: ModelConfig, batch, policy: QuantPolicy, bits0):
    """x0 from the boundary params; quantized with the first layer's format."""
    def f(bnd):
        emb = bnd["embed"]
        if policy.quantize_weights:
            emb = quantize_weight_tree(emb, bits0["w_i"], bits0["w_f"],
                                       bits0["enabled"], True)
        p = {"embed": emb}
        if cfg.family == "vlm":
            p["mm_proj"] = bnd["mm_proj"]
        x0, _ = lm.embed_input(p, cfg, batch)
        return x0
    return f


def _head_fn(cfg: ModelConfig, batch, policy: QuantPolicy, bits_last,
             grad_scale: float):
    np_off = batch["patch_embeds"].shape[1] if cfg.family == "vlm" else 0

    def f(bnd, xf):
        x = L.apply_norm(bnd["final_norm"], xf, cfg)
        if np_off:
            x = x[:, np_off:, :]
        w = bnd["embed"].T if cfg.tie_embeddings else bnd["lm_head"]
        if policy.quantize_weights:
            w = quantize_weight_tree(w, bits_last["w_i"], bits_last["w_f"],
                                     bits_last["enabled"], True)
        loss, metrics = lm.ce_from_weight(w, cfg, x, batch["labels"])
        return loss, metrics
    return f


def _bits_edge(bits, idx):
    return {"w_i": bits.w_i[idx], "w_f": bits.w_f[idx],
            "a_i": bits.a_i[idx], "a_f": bits.a_f[idx],
            "g_i": bits.g_i[idx], "g_f": bits.g_f[idx],
            "enabled": bits.enabled}


# ---------------------------------------------------------------------------
# Stage-sharded stack execution through dist.pipeline
# ---------------------------------------------------------------------------

def pipeline_exec_capabilities(cfg: ModelConfig,
                               policy: QuantPolicy) -> dict:
    """What the stage-sharded pipeline path can execute, per feature.

    Every entry maps a requirement of this (cfg, policy) combination to
    whether the pipeline path supports it.  Since the shared-operand story
    (broadcast-class operands replicated/sliced per stage, reduce-class aux
    summed post-drain) and the quant-feature parity work landed, every
    family and every QuantPolicy feature is supported — the map exists so
    ``_check_pipeline_exec`` DETECTS a missing capability instead of
    hard-coding a family allowlist, and so callers (tests, the train
    driver) can introspect support instead of parsing error text.
    """
    known = cfg.family in lm.SHARED_OPERAND_KIND
    return {
        f"family:{cfg.family}": known,
        "stochastic": True,        # per-(layer, batch-row) PRNG threading
        "quantize_updates": True,  # inside the vmapped/overlapped update
        "compress_dw": True,       # per-layer codec in the update tail
        "overlap": True,           # depth-pipelined reduce over dw axes
    }


def _check_pipeline_exec(cfg: ModelConfig, policy: QuantPolicy,
                         num_stages: int) -> None:
    """Build-time validation for executing the stack through dist.pipeline."""
    caps = pipeline_exec_capabilities(cfg, policy)
    active = [f"family:{cfg.family}"]
    active += [f for f in ("stochastic", "quantize_updates", "compress_dw")
               if getattr(policy, f)]
    if policy.overlap == "on":
        active.append("overlap")
    missing = [f for f in active if not caps.get(f, False)]
    if missing:
        raise NotImplementedError(
            f"pipeline execution (pipeline_stages={num_stages} > 1) does "
            f"not support {missing} for this configuration")
    n = num_scan_units(cfg)
    if n % num_stages:
        raise ValueError(
            f"num_layers={n} does not divide into pipeline_stages="
            f"{num_stages} equal stages")


def _unpipe(a, mesh):
    """Constrain an array leaving pipeline_apply to be replicated over the
    mesh (no-op without a pipe-axis mesh or outside a partitionable ctx)."""
    if mesh is None or "pipe" not in getattr(mesh, "axis_names", ()):
        return a
    try:
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, P(*([None] * a.ndim))))
    except Exception:
        return a


def _pipeline_stack_forward(body, stacked, bits, policy: QuantPolicy,
                            x0: Array, sched, num_stages: int,
                            num_microbatches: int, mesh, shared=(),
                            shared_kind: str = "none",
                            moe_experts: Optional[int] = None,
                            rng: Optional[Array] = None):
    """Run the blocks stack stage-sharded through dist.pipeline.

    The stack's [L, ...] params reshape to [S, L/S, ...] stages and the
    batch splits into M microbatches; ``pipeline_apply`` executes them
    under ``sched`` with stages placed on the mesh's "pipe" axis.  Each
    stage runs its own layers (unrolled — see the in-body comment on why
    not an inner scan) with the engine's forward quantization, and
    a ``grad_tap`` at every layer input quantizes the backward cotangent —
    so ``jax.vjp`` of this function IS the engine's G-chain (values match
    the sequential scan bit-exactly; per-layer dW matches the reverse
    scan's).  Unlike the scan path the full stacked dW tree materialises
    here: stage-sharding trades the paper's one-layer gradient residency
    for the pipe axis's parallelism.

    Shared operands (``shared_kind``, see ``models.lm.SHARED_OPERAND_KIND``):

    * ``"weights"`` (hybrid's weight-tied attn block): ``shared`` is
      replicated to every stage — each layer quantizes it with its own
      (I,F) just like the scan engine — and the vjp of the broadcast sums
      the per-stage gradients.
    * ``"activation"`` (encdec's encoder output): ``shared`` leaves are
      full-batch activations; each stage slices the rows of the microbatch
      it is currently processing (the microbatch index rides the rotating
      pipeline value), and the slice's vjp scatter-adds the per-stage
      cotangents back into the full-batch gradient.

    Reduce-class side outputs (moe's load-balance aux) ride the pipeline
    value as per-microbatch accumulators and are combined after the drain.
    Because the aux is bilinear in two batch-mean statistics (expert pick
    fraction x mean router prob), each stage writes its layers' per-
    microbatch STATISTICS (``moe_experts`` set) and the post-drain
    recombination averages them over microbatches before the product —
    reproducing the scan engine's full-batch aux (and its gradient)
    instead of the mean of per-microbatch aux values, which differs.
    Families with scalar aux accumulate the scalar and normalize by M.

    With ``policy.stochastic`` and an ``rng`` key, the backward cotangent
    taps round stochastically with noise keyed per (layer, global batch
    row): layer keys fold the unit index, row keys fold ``m * mb + b`` —
    deterministic in (stage, microbatch, layer) and identical to the scan
    engine's full-batch draws.

    Returns ``(y [B, ...], aux_sum scalar)``.
    """
    from repro.dist.pipeline import pipeline_apply
    n_units = jax.tree.leaves(stacked)[0].shape[0]
    bsz = x0.shape[0]
    S, M = num_stages, num_microbatches
    # batch % M validated by the caller (the train step's pipe branch,
    # which needs the quotient before this function can even be built)
    lps = n_units // S
    mbsz = bsz // M
    enabled = bits.enabled
    use_stoch = (policy.quantize_grads and policy.stochastic
                 and rng is not None)
    stage_p = jax.tree.map(lambda a: a.reshape((S, lps) + a.shape[1:]),
                           stacked)
    stage_b = jax.tree.map(lambda a: a.reshape((S, lps) + a.shape[1:]),
                           _bits_xs(bits))
    stage_l = jnp.arange(n_units, dtype=jnp.int32).reshape(S, lps)  # unit
    x_mb = x0.reshape((M, mbsz) + x0.shape[1:])

    def stage_body(bundle, val):
        p_s, b_s, l_s = bundle
        m = val["m"]
        if shared_kind == "activation":
            sh = tuple(jax.lax.dynamic_slice_in_dim(s, m * mbsz, mbsz, 0)
                       for s in shared)
        else:
            sh = shared

        # remat-per-layer (the paper's recompute-in-backward discipline,
        # same as the scan engine's cached-X_i + re-linearize): under
        # jax.vjp the PRIMAL pass runs this body un-linearized, which is
        # what keeps the pipeline's forward values — and therefore the
        # loss — bit-identical to the scan engine's plain forward, and the
        # backward re-linearizes each layer at exactly the per-layer
        # inputs the forward produced (the engine's cached X_i).  Without
        # it, partial-eval restructures the body (residual materialisation
        # changes FMA/fusion rounding) and sub-ulp drift leaks into the
        # forward.
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def layer(carry, xs_l):
            p_l, b_l, l_idx = xs_l
            hh = carry["h"]
            if policy.quantize_grads:
                if use_stoch:
                    kd = jax.random.key_data(jax.random.fold_in(rng, l_idx))
                    hh = grad_tap_stochastic(hh, b_l["g_i"], b_l["g_f"],
                                             enabled, kd, m * mbsz)
                else:
                    hh = grad_tap(hh, b_l["g_i"], b_l["g_f"], enabled)
            if policy.quantize_acts:
                hq = (enabled * quantize_ste(hh.astype(jnp.float32),
                                             b_l["a_i"], b_l["a_f"])
                      + (1.0 - enabled) * hh.astype(jnp.float32)
                      ).astype(hh.dtype)
            else:
                hq = hh
            wq = quantize_weight_tree(p_l, b_l["w_i"], b_l["w_f"], enabled,
                                      policy.quantize_weights)
            sq = (quantize_weight_tree(sh, b_l["w_i"], b_l["w_f"], enabled,
                                       policy.quantize_weights)
                  if shared_kind == "weights" else sh)
            y, aux_l = body(wq, sq, hq, b_l)
            new = dict(carry, h=y)
            if moe_experts:
                # this unit's statistics land in its own row; other units'
                # rows (written by other stages) pass through untouched
                new["frac"] = jax.lax.dynamic_update_index_in_dim(
                    carry["frac"], aux_l["frac"], l_idx, 0)
                new["p"] = jax.lax.dynamic_update_index_in_dim(
                    carry["p"], aux_l["p"], l_idx, 0)
            else:
                new["aux"] = carry["aux"] + aux_l
            return new, None

        # the per-stage layer loop is UNROLLED, not scanned: partial-eval
        # of an inner lax.scan stacks per-layer residuals, which perturbs
        # fusion inside the scan body (observed as sub-ulp forward drift
        # on the mamba families, amplified to grid steps by the act
        # quantizer); the unrolled graph keeps each remat'd layer's
        # primal bit-identical to the plain forward, at the cost of
        # per-tick HLO growing with L/S.  Pipeline stages keep L/S small
        # by construction, and the outer tick scan stays rolled.
        carry = {k: v for k, v in val.items() if k != "m"}
        for j in range(lps):
            xs_j = (jax.tree.map(lambda a: a[j], p_s),
                    {k: v[j] for k, v in b_s.items()}, l_s[j])
            carry, _ = layer(carry, xs_j)
        return dict(carry, m=m)

    val0 = {"h": x_mb, "m": jnp.arange(M, dtype=jnp.int32)}
    if moe_experts:
        val0["frac"] = jnp.zeros((M, n_units, moe_experts), jnp.float32)
        val0["p"] = jnp.zeros((M, n_units, moe_experts), jnp.float32)
    else:
        val0["aux"] = jnp.zeros((M,), jnp.float32)
    out = pipeline_apply((stage_p, stage_b, stage_l), val0, stage_body,
                         mesh, schedule=sched)
    # the collected outputs leave the pipe axis here: pin them replicated
    # so the head (and the aux recombination) runs the same single-program
    # reductions as the scan reference instead of partitioner-split ones
    # (sharded reductions reassociate, and the quantizers amplify that)
    out = jax.tree.map(lambda a: _unpipe(a, mesh), out)
    y = out["h"].reshape((bsz,) + out["h"].shape[2:])
    if moe_experts:
        # full-batch statistics = mean of per-microbatch statistics; the
        # bilinear recombination AFTER the mean reproduces the scan
        # engine's full-batch aux and, through this vjp, its gradient
        frac = jnp.mean(out["frac"], axis=0)          # [L, E]
        probs_mean = jnp.mean(out["p"], axis=0)       # [L, E]
        aux_sum = jnp.sum(jax.vmap(L.moe_aux_from_stats)(frac, probs_mean))
    else:
        aux_sum = jnp.sum(out["aux"]) / M
    return y, aux_sum


# ---------------------------------------------------------------------------
# The TaxoNN train step
# ---------------------------------------------------------------------------

def _pipeline_metrics(pipeline_schedule, pipeline_stages, num_microbatches):
    """Resolve the pipeline knob into (Schedule | None, static metric dict).

    The schedule is validated eagerly (unknown names and uneven
    virtual-stage counts fail at step-build time, not mid-training) and its
    tick-table estimates are folded into every step's metrics so the
    bubble/memory tradeoff is visible in training logs.
    """
    if pipeline_schedule is None:
        return None, {}
    from repro.dist.pipeline import get_schedule
    sched = get_schedule(pipeline_schedule)
    S = int(pipeline_stages) if pipeline_stages else 1
    M = int(num_microbatches) if num_microbatches else 1
    sched.validate(S, M)
    plan = sched.plan(S, M)
    return sched, {
        "pipe_bubble": jnp.float32(plan.bubble),
        "pipe_ticks": jnp.int32(plan.num_ticks),
        "pipe_peak_mb": jnp.int32(plan.peak_activation_microbatches),
    }


@dataclasses.dataclass(frozen=True)
class StepOptions:
    """Everything that selects HOW a train step executes, in one frozen
    value — the successor of ``make_train_step``'s kwarg sprawl (engine,
    kernel_backend, pipeline_*, overlap, transport each arrived as a new
    keyword in a different PR).  ``None`` fields defer to the policy
    (kernel_backend/overlap/transport) or mean "feature off" (pipeline_*).

    Build one directly, or seed it from a policy's knobs and override:

        opts = StepOptions(engine="autodiff")
        opts = StepOptions.from_policy(policy, overlap="on")
        step = make_train_step(cfg, policy, ocfg, opts)
    """

    engine: str = "taxonn"
    kernel_backend: Optional[str] = None
    pipeline_schedule: Any = None
    pipeline_stages: Optional[int] = None
    num_microbatches: Optional[int] = None
    overlap: Optional[str] = None
    transport: Optional[str] = None
    bit_anneal: Any = None  # spec str | AnnealSchedule | None

    def __post_init__(self):
        if self.engine not in ("taxonn", "autodiff"):
            raise ValueError(f"engine must be 'taxonn' or 'autodiff', "
                             f"got {self.engine!r}")
        if isinstance(self.bit_anneal, str):
            from repro.search.anneal import AnnealSchedule
            object.__setattr__(self, "bit_anneal",
                               AnnealSchedule.parse(self.bit_anneal))
        elif self.bit_anneal is not None:
            from repro.search.anneal import AnnealSchedule
            if not isinstance(self.bit_anneal, AnnealSchedule):
                raise ValueError(
                    f"bit_anneal must be an anneal spec string or an "
                    f"AnnealSchedule, got {type(self.bit_anneal).__name__}")
        if self.kernel_backend not in (None, "off", "emulate", "int8", "auto"):
            raise ValueError(f"kernel_backend must be 'off', 'emulate', "
                             f"'int8' or 'auto', got {self.kernel_backend!r}")
        if self.overlap not in (None, "off", "on"):
            raise ValueError(f"overlap must be 'off' or 'on', "
                             f"got {self.overlap!r}")
        if self.transport not in (None, "auto", "ring", "psum", "scatter"):
            raise ValueError(f"transport must be 'auto', 'ring', 'psum' or "
                             f"'scatter', got {self.transport!r}")

    @classmethod
    def from_policy(cls, policy: QuantPolicy, **overrides) -> "StepOptions":
        """Seed the execution knobs from the policy's own fields (the
        values ``make_train_step`` would resolve to anyway), then apply
        explicit overrides — handy when one policy drives several step
        variants."""
        base = dict(kernel_backend=policy.kernel_backend,
                    overlap=policy.overlap,
                    transport=policy.dw_transport,
                    bit_anneal=getattr(policy, "bit_anneal", None))
        base.update(overrides)
        return cls(**base)

    def replace(self, **kw) -> "StepOptions":
        return dataclasses.replace(self, **kw)


_DEPRECATED_STEP_KWARGS = ("engine", "kernel_backend", "pipeline_schedule",
                           "pipeline_stages", "num_microbatches", "overlap",
                           "transport")


def make_train_step(cfg: ModelConfig, policy: Optional[QuantPolicy] = None,
                    optim_cfg: Optional[OptimizerConfig] = None,
                    options: Optional[StepOptions] = None,
                    **deprecated_kwargs):
    """Build the train step described by ``options`` (a ``StepOptions``).

    The legacy per-knob keywords (``engine=``, ``kernel_backend=``,
    ``pipeline_schedule=``, ``pipeline_stages=``, ``num_microbatches=``,
    ``overlap=``, ``transport=``) still work through a shim that folds
    them into a ``StepOptions`` and emits a ``DeprecationWarning`` — new
    code should pass ``options=StepOptions(...)`` instead.
    """
    if deprecated_kwargs:
        unknown = set(deprecated_kwargs) - set(_DEPRECATED_STEP_KWARGS)
        if unknown:
            raise TypeError(f"make_train_step got unexpected keyword "
                            f"arguments {sorted(unknown)}")
        warnings.warn(
            f"make_train_step kwargs {sorted(deprecated_kwargs)} are "
            f"deprecated; pass options=StepOptions(...) instead",
            DeprecationWarning, stacklevel=2)
        if options is not None:
            clash = [k for k, v in deprecated_kwargs.items()
                     if getattr(options, k) is not None and v is not None
                     and (k != "engine" or v != options.engine)]
            if clash:
                raise ValueError(f"both options= and legacy kwargs set "
                                 f"{sorted(clash)}")
        options = dataclasses.replace(options or StepOptions(),
                                      **deprecated_kwargs)
    options = options or StepOptions()
    return _make_train_step(cfg, policy, optim_cfg, options)


def _make_train_step(cfg: ModelConfig, policy: Optional[QuantPolicy],
                     optim_cfg: Optional[OptimizerConfig],
                     options: StepOptions):
    """``kernel_backend`` overrides ``policy.kernel_backend`` ("off" |
    "emulate" | "int8" | "auto"; auto = off on CPU, int8 on TPU) and selects
    the datapath for the dense-unit matmuls in the step's hot loops.

    ``overlap`` ("off" | "on") overrides ``policy.overlap``: with "on" the
    engine's backward scan software-pipelines each layer's dW all-reduce
    ``policy.overlap_depth`` scan steps deep (start at layer i, wait while
    the next ``depth`` layers compute — see ``core.taxonn.backward_stack``
    / ``dist.async_collectives``).

    ``transport`` ("auto" | "ring" | "psum" | "scatter") overrides
    ``policy.dw_transport``: which wire the overlapped dW reduce rides —
    "auto" asks the per-bucket transport autotuner
    (``dist.async_collectives.decide_transport``; ``REPRO_TRANSPORT``
    forces it globally), "ring" the chunked ppermute ring, "psum" the
    fused blocking collective, "scatter" the reduce-scatter +
    sharded-update + all-gather path (dense SGD only; degrades to psum
    otherwise).  Prime the autotuner's measured decisions BEFORE tracing
    via ``dist.async_collectives.prime_transport_cache``; inside the
    trace it falls back to cached decisions or a platform model.

    ``pipeline_schedule`` ("gpipe" | "1f1b" | "interleaved" or a
    ``repro.dist.pipeline.Schedule``) declares the pipeline schedule this
    step runs under when the mesh has a "pipe" axis of ``pipeline_stages``
    devices and the batch is split into ``num_microbatches`` microbatches.
    It is validated at build time and surfaces the schedule's tick-table
    estimates (``pipe_bubble`` / ``pipe_ticks`` / ``pipe_peak_mb``) in the
    step metrics.  With ``pipeline_stages > 1`` the TaxoNN engine's blocks
    stack EXECUTES stage-sharded through ``dist.pipeline.pipeline_apply``
    (the schedule places stages on the mesh's "pipe" axis; see
    ``_pipelined_stack``); the returned step exposes the schedule as
    ``step.pipeline_schedule``.
    """
    policy = policy or QuantPolicy.off()
    if options.overlap is not None:
        policy = dataclasses.replace(policy, overlap=options.overlap)
    if options.transport is not None:
        policy = dataclasses.replace(policy, dw_transport=options.transport)
    optim_cfg = optim_cfg or OptimizerConfig()
    backend = resolve_backend(
        options.kernel_backend if options.kernel_backend is not None
        else getattr(policy, "kernel_backend", "auto"))
    engine = options.engine
    pipeline_stages = options.pipeline_stages
    sched, pipe_metrics = _pipeline_metrics(
        options.pipeline_schedule, options.pipeline_stages,
        options.num_microbatches)
    anneal = options.bit_anneal
    if anneal is None:
        pol_spec = getattr(policy, "bit_anneal", None)
        if pol_spec:
            from repro.search.anneal import AnnealSchedule
            anneal = AnnealSchedule.parse(pol_spec)

    if engine == "autodiff":
        def auto_step(params, opt_state, batch, hyper: Hyper, bits=None,
                      rng=None):  # rng accepted for signature parity
            with kernel_backend_ctx(backend):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)
            gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads))
            new_params, new_opt = {}, {}
            for k in params:  # grouped like the engine's state layout
                new_params[k], new_opt[k] = apply_update(
                    params[k], grads[k], opt_state[k], hyper, optim_cfg)
            metrics["grad_norm"] = jnp.sqrt(gsq)
            metrics.update(pipe_metrics)
            return new_params, new_opt, metrics
        auto_step.pipeline_schedule = sched
        auto_step.bit_anneal = anneal  # accepted for parity; bits unused
        return auto_step

    if engine != "taxonn":
        raise ValueError(engine)

    fam = cfg.family
    scale = policy.grad_scale
    pipe_exec = sched is not None and pipeline_stages and int(
        pipeline_stages) > 1
    if pipe_exec:
        _check_pipeline_exec(cfg, policy, int(pipeline_stages))

    def _step_impl(params, opt_state, batch, hyper: Hyper, bits: dict,
                   rng: Optional[Array] = None):
        if rng is not None:
            # normalize to a typed key so the scan engine and the pipeline
            # path fold the SAME key stream (legacy uint32 keys wrap here)
            rng = jnp.asarray(rng)
            if not jnp.issubdtype(rng.dtype, jax.dtypes.prng_key):
                rng = jax.random.wrap_key_data(rng)
        if anneal is not None:
            # step-indexed F-bit ramp: bits stay traced data, so the anneal
            # composes with the scan, pipeline, overlap and stochastic paths
            # for free, and resume at step N continues the ramp bitwise
            bits = anneal.apply_tree(bits, hyper.step)
        main_bits = bits["blocks"]
        bnd_keys = boundary_keys(params)
        bnd = {k: params[k] for k in bnd_keys}

        tokens = batch["tokens"]
        bsz, tlen = tokens.shape
        total_t = tlen + (batch["patch_embeds"].shape[1]
                          if fam == "vlm" else 0)
        positions = jnp.broadcast_to(jnp.arange(total_t), (bsz, total_t))

        # ---- encoder forward (encdec only) ------------------------------
        enc_caches = enc_out = enc_pos = None
        enc_vjp = None
        if fam == "encdec":
            dt = lm.compute_dtype(cfg)
            frames = batch["frames"].astype(dt)
            enc_x0 = frames + lm._sinusoid(frames.shape[1], cfg.d_model).astype(dt)
            enc_pos = jnp.broadcast_to(
                jnp.arange(frames.shape[1]), (bsz, frames.shape[1]))
            e_last, enc_caches, _ = forward_stack(
                _enc_body(cfg, enc_pos), params["enc_blocks"], (),
                enc_x0, bits["enc_blocks"], policy)
            enc_out, enc_vjp = jax.vjp(
                lambda en, xx: L.apply_norm(en, xx, cfg),
                bnd["enc_norm"], e_last)

        # ---- embed (with VJP for the input-side embedding gradient) -----
        embed_f = _embed_fn(cfg, batch, policy, _bits_edge(main_bits, 0))
        x0, embed_vjp = jax.vjp(embed_f, bnd)

        # ---- main stack forward, caching quantized X_i -------------------
        # hybrid: shared = the weight-tied attn block (quantized per layer)
        # encdec: shared = encoder output ACTIVATION (quantized once here)
        quantize_shared = fam == "hybrid"
        shared = (params["shared_attn"],) if fam == "hybrid" else ()
        if fam == "encdec":
            if policy.quantize_acts:
                eb = _bits_edge(bits["enc_blocks"], -1)
                enc_q = (eb["enabled"] * quantize_ste(
                    enc_out.astype(jnp.float32), eb["a_i"], eb["a_f"])
                    + (1.0 - eb["enabled"]) * enc_out.astype(jnp.float32)
                ).astype(enc_out.dtype)
            else:
                enc_q = enc_out
            shared = (enc_q,)
        body = _make_body(cfg, positions)

        def body_sh(p, sh, x, b_l):
            if fam == "hybrid":
                return body(p, sh[0], x, b_l)
            return body(p, sh, x, b_l)

        pipe_vjp = None
        if pipe_exec:
            # stage-sharded execution through dist.pipeline: the bodies run
            # per-microbatch, so they need microbatch-shaped positions
            S_pipe = int(pipeline_stages)
            M_pipe = int(options.num_microbatches or 1)
            if bsz % M_pipe:
                raise ValueError(f"global batch {bsz} does not divide into "
                                 f"num_microbatches={M_pipe}")
            pos_mb = jnp.broadcast_to(jnp.arange(total_t),
                                      (bsz // M_pipe, total_t))
            body_mb = _make_body(cfg, pos_mb, moe_aux_parts=fam == "moe")

            def body_sh_mb(p, sh, x, b_l):
                if fam == "hybrid":
                    return body_mb(p, sh[0], x, b_l)
                return body_mb(p, sh, x, b_l)

            mesh = jax.sharding.get_abstract_mesh()
            shared_kind = lm.SHARED_OPERAND_KIND[fam]

            def fwd_pipe(blocks, shared_, x0_):
                return _pipeline_stack_forward(
                    body_sh_mb, blocks, main_bits, policy, x0_, sched,
                    S_pipe, M_pipe, mesh, shared=shared_,
                    shared_kind=shared_kind,
                    moe_experts=(cfg.num_experts if fam == "moe" else None),
                    rng=rng)

            # shared rides as a vjp argument: broadcast-class operands
            # (hybrid's weight-tied attn, encdec's encoder output) get
            # their gradient summed across stages by the transpose;
            # reduce-class side outputs (moe's aux statistics) ride the
            # pipeline value and are recombined post-drain into aux_sum
            (x_final, aux_sum), pipe_vjp = jax.vjp(
                fwd_pipe, params["blocks"], shared, x0)
        else:
            x_final, caches, aux_sum = forward_stack(
                body_sh, params["blocks"], shared, x0, main_bits, policy,
                quantize_shared=quantize_shared)

        # ---- head (loss) --------------------------------------------------
        head_f = _head_fn(cfg, batch, policy, _bits_edge(main_bits, -1), scale)
        loss, head_vjp, metrics = jax.vjp(head_f, bnd, x_final, has_aux=True)
        d_bnd_head, G_final = head_vjp(jnp.asarray(scale, jnp.float32))
        metrics["aux"] = aux_sum
        metrics["loss_total"] = loss + AUX_COEF * aux_sum

        # ---- the G-chain: reverse scan with fused per-layer updates ------
        if pipe_exec:
            # vjp through the stage-sharded pipeline (grad taps reproduce
            # the engine's per-layer G quantization); the update tail
            # (core.taxonn.apply_stacked_updates) reduces each layer's dW
            # over dw_psum_axes — compressed or dense, overlapped or
            # blocking — quantizes the update (strict-paper mode) and
            # applies it, with the scan engine's per-layer PRNG keys.
            # The aux seed is the scalar loss coefficient; the post-drain
            # recombination inside fwd_pipe distributes it per layer and
            # microbatch by the chain rule.
            d_blocks, dshared, G_in = pipe_vjp(
                (G_final, jnp.asarray(AUX_COEF * scale, jnp.float32)))
            d_blocks = jax.tree.map(
                lambda g: g.astype(jnp.float32) / scale, d_blocks)
            new_blocks, new_blocks_opt, gsq = apply_stacked_updates(
                params["blocks"], d_blocks, opt_state["blocks"], main_bits,
                hyper, policy, optim_cfg, base_key=rng)
        else:
            G_in, new_blocks, new_blocks_opt, dshared, gsq = backward_stack(
                body_sh, params["blocks"], shared, opt_state["blocks"],
                caches, main_bits, G_final, hyper, policy, optim_cfg,
                AUX_COEF, base_key=rng, quantize_shared=quantize_shared)

        new_params = dict(params)
        new_opt = dict(opt_state)
        new_params["blocks"] = new_blocks
        new_opt["blocks"] = new_blocks_opt

        # ---- shared-attn update (hybrid) ---------------------------------
        if fam == "hybrid":
            d_shared_params = jax.tree.map(lambda g: g / scale, dshared[0])
            new_params["shared_attn"], new_opt["shared_attn"] = apply_update(
                params["shared_attn"], d_shared_params,
                opt_state["shared_attn"], hyper, optim_cfg)
            gsq = gsq + sum(jnp.sum(jnp.square(g))
                            for g in jax.tree.leaves(d_shared_params))

        # ---- encoder backward (encdec) ------------------------------------
        d_bnd_enc = None
        if fam == "encdec":
            (d_enc_out,) = dshared  # accumulated over decoder layers (SCALED)
            d_enc_norm, d_e_last = enc_vjp(d_enc_out.astype(enc_out.dtype))
            _, new_enc, new_enc_opt, _, gsq_e = backward_stack(
                _enc_body(cfg, enc_pos), params["enc_blocks"], (),
                opt_state["enc_blocks"], enc_caches, bits["enc_blocks"],
                d_e_last, hyper, policy, optim_cfg, AUX_COEF, base_key=rng)
            new_params["enc_blocks"] = new_enc
            new_opt["enc_blocks"] = new_enc_opt
            gsq = gsq + gsq_e
            d_bnd_enc = jax.tree.map(
                lambda w: jnp.zeros(w.shape, jnp.float32), bnd)
            d_bnd_enc["enc_norm"] = jax.tree.map(
                lambda g: g.astype(jnp.float32) / scale, d_enc_norm)

        # ---- boundary updates (embed gets head + input contributions) ----
        (d_bnd_embed,) = embed_vjp(G_in)
        d_bnd = jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) + b.astype(jnp.float32)) / scale,
            d_bnd_head, d_bnd_embed)
        if d_bnd_enc is not None:
            d_bnd = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 d_bnd, d_bnd_enc)
        bnd_new, bnd_opt_new = {}, {}
        for k in bnd_keys:
            bnd_new[k], bnd_opt_new[k] = apply_update(
                bnd[k], d_bnd[k], opt_state[k], hyper, optim_cfg)
            gsq = gsq + sum(jnp.sum(jnp.square(g))
                            for g in jax.tree.leaves(d_bnd[k]))
        new_params.update(bnd_new)
        new_opt.update(bnd_opt_new)

        metrics["grad_norm"] = jnp.sqrt(gsq)
        metrics.update(pipe_metrics)
        return new_params, new_opt, metrics

    def step(params, opt_state, batch, hyper: Hyper, bits: dict,
             rng: Optional[Array] = None):
        with kernel_backend_ctx(backend):  # active at trace time
            return _step_impl(params, opt_state, batch, hyper, bits, rng)

    step.pipeline_schedule = sched
    step.bit_anneal = anneal
    return step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = lm.loss_fn(params, cfg, batch)
        return metrics
    return eval_step
