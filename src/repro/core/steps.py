"""Train/eval step builders: the TaxoNN engine vs the autodiff baseline.

``make_train_step(cfg, policy, optim_cfg, engine)`` returns a jit-able

    step(params, opt_state, batch, hyper, bits) -> (params, opt_state, metrics)

engine="taxonn"   — the paper's unrolled G-chain with per-layer fused update
engine="autodiff" — monolithic jax.grad + global optimizer apply (the
                    "conventional accelerator" baseline the paper compares
                    against; also the correctness oracle for the engine)

``bits`` is a dict of runtime BitSchedules keyed by stack name ("blocks",
and "enc_blocks" for encdec).  One compiled step serves every schedule.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.taxonn import (
    QuantPolicy,
    backward_stack,
    default_bits_for,
    forward_stack,
    quantize_weight_tree,
)
from repro.kernels.ops import kernel_backend_ctx, resolve_backend
from repro.quant.fixed_point import quantize_ste
from repro.util.scan import xscan
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import Hyper, OptimizerConfig, apply_update, init_opt_state

Array = jax.Array

AUX_COEF = lm.AUX_COEF


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------

STACK_KEYS = ("blocks", "enc_blocks")
SHARED_KEYS = ("shared_attn",)


def boundary_keys(params: dict):
    return tuple(k for k in params
                 if k not in STACK_KEYS and k not in SHARED_KEYS)


def init_train_state(params: dict, optim_cfg: OptimizerConfig) -> dict:
    """Optimizer state mirrored on the params' top-level grouping so the
    engine can scan per-layer slices of each stack's state."""
    return {k: init_opt_state(v, optim_cfg) for k, v in params.items()}


def default_bits(cfg: ModelConfig, enabled: bool = True) -> dict:
    n = num_scan_units(cfg)
    bits = {"blocks": default_bits_for(n, enabled)}
    if cfg.family == "encdec":
        bits["enc_blocks"] = default_bits_for(cfg.num_encoder_layers, enabled)
    return bits


def num_scan_units(cfg: ModelConfig) -> int:
    """Engine-visible layers in the main stack (hybrid scans groups)."""
    if cfg.family == "hybrid":
        return lm.hybrid_groups(cfg)[0]
    return cfg.num_layers


# ---------------------------------------------------------------------------
# Per-family stack bodies: body(params_slice, shared, x, bits_l) -> (y, aux)
# ---------------------------------------------------------------------------

def _make_body(cfg: ModelConfig, positions, enc_out_in_shared: bool = False):
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def body(p, shared, x, b_l):
            return B.transformer_block(p, x, cfg, positions)
        return body

    if fam == "ssm":
        def body(p, shared, x, b_l):
            return B.mamba_block(p, x, cfg, positions)
        return body

    if fam == "hybrid":
        def body(gp, shared, x, b_l):
            h, _ = B.transformer_block(shared, x, cfg, positions)

            @jax.checkpoint
            def inner(hh, p):
                h2, aux = B.mamba_block(p, hh, cfg, positions)
                return h2, aux
            h, auxs = xscan(inner, h, gp)
            return h, jnp.sum(auxs)
        return body

    if fam == "encdec":
        def body(p, shared, x, b_l):
            (enc_out,) = shared
            return B.decoder_block(p, x, cfg, positions, enc_out)
        return body

    raise ValueError(fam)


def _enc_body(cfg: ModelConfig, positions):
    def body(p, shared, x, b_l):
        return B.transformer_block(p, x, cfg, positions, causal=False)
    return body


# ---------------------------------------------------------------------------
# Boundary (embed / head) functions
# ---------------------------------------------------------------------------

def _embed_fn(cfg: ModelConfig, batch, policy: QuantPolicy, bits0):
    """x0 from the boundary params; quantized with the first layer's format."""
    def f(bnd):
        emb = bnd["embed"]
        if policy.quantize_weights:
            emb = quantize_weight_tree(emb, bits0["w_i"], bits0["w_f"],
                                       bits0["enabled"], True)
        p = {"embed": emb}
        if cfg.family == "vlm":
            p["mm_proj"] = bnd["mm_proj"]
        x0, _ = lm.embed_input(p, cfg, batch)
        return x0
    return f


def _head_fn(cfg: ModelConfig, batch, policy: QuantPolicy, bits_last,
             grad_scale: float):
    np_off = batch["patch_embeds"].shape[1] if cfg.family == "vlm" else 0

    def f(bnd, xf):
        x = L.apply_norm(bnd["final_norm"], xf, cfg)
        if np_off:
            x = x[:, np_off:, :]
        w = bnd["embed"].T if cfg.tie_embeddings else bnd["lm_head"]
        if policy.quantize_weights:
            w = quantize_weight_tree(w, bits_last["w_i"], bits_last["w_f"],
                                     bits_last["enabled"], True)
        loss, metrics = lm.ce_from_weight(w, cfg, x, batch["labels"])
        return loss, metrics
    return f


def _bits_edge(bits, idx):
    return {"w_i": bits.w_i[idx], "w_f": bits.w_f[idx],
            "a_i": bits.a_i[idx], "a_f": bits.a_f[idx],
            "g_i": bits.g_i[idx], "g_f": bits.g_f[idx],
            "enabled": bits.enabled}


# ---------------------------------------------------------------------------
# The TaxoNN train step
# ---------------------------------------------------------------------------

def _pipeline_metrics(pipeline_schedule, pipeline_stages, num_microbatches):
    """Resolve the pipeline knob into (Schedule | None, static metric dict).

    The schedule is validated eagerly (unknown names and uneven
    virtual-stage counts fail at step-build time, not mid-training) and its
    tick-table estimates are folded into every step's metrics so the
    bubble/memory tradeoff is visible in training logs.
    """
    if pipeline_schedule is None:
        return None, {}
    from repro.dist.pipeline import get_schedule
    sched = get_schedule(pipeline_schedule)
    S = int(pipeline_stages) if pipeline_stages else 1
    M = int(num_microbatches) if num_microbatches else 1
    sched.validate(S, M)
    plan = sched.plan(S, M)
    return sched, {
        "pipe_bubble": jnp.float32(plan.bubble),
        "pipe_ticks": jnp.int32(plan.num_ticks),
        "pipe_peak_mb": jnp.int32(plan.peak_activation_microbatches),
    }


def make_train_step(cfg: ModelConfig, policy: Optional[QuantPolicy] = None,
                    optim_cfg: Optional[OptimizerConfig] = None,
                    engine: str = "taxonn",
                    kernel_backend: Optional[str] = None,
                    pipeline_schedule=None,
                    pipeline_stages: Optional[int] = None,
                    num_microbatches: Optional[int] = None):
    """``kernel_backend`` overrides ``policy.kernel_backend`` ("off" |
    "emulate" | "int8" | "auto"; auto = off on CPU, int8 on TPU) and selects
    the datapath for the dense-unit matmuls in the step's hot loops.

    ``pipeline_schedule`` ("gpipe" | "1f1b" | "interleaved" or a
    ``repro.dist.pipeline.Schedule``) declares the pipeline schedule this
    step runs under when the mesh has a "pipe" axis of ``pipeline_stages``
    devices and the batch is split into ``num_microbatches`` microbatches.
    It is validated at build time and surfaces the schedule's tick-table
    estimates (``pipe_bubble`` / ``pipe_ticks`` / ``pipe_peak_mb``) in the
    step metrics; the returned step exposes it as ``step.pipeline_schedule``.
    """
    policy = policy or QuantPolicy.off()
    optim_cfg = optim_cfg or OptimizerConfig()
    backend = resolve_backend(
        kernel_backend if kernel_backend is not None
        else getattr(policy, "kernel_backend", "auto"))
    sched, pipe_metrics = _pipeline_metrics(
        pipeline_schedule, pipeline_stages, num_microbatches)

    if engine == "autodiff":
        def auto_step(params, opt_state, batch, hyper: Hyper, bits=None):
            with kernel_backend_ctx(backend):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)
            gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads))
            new_params, new_opt = {}, {}
            for k in params:  # grouped like the engine's state layout
                new_params[k], new_opt[k] = apply_update(
                    params[k], grads[k], opt_state[k], hyper, optim_cfg)
            metrics["grad_norm"] = jnp.sqrt(gsq)
            metrics.update(pipe_metrics)
            return new_params, new_opt, metrics
        auto_step.pipeline_schedule = sched
        return auto_step

    if engine != "taxonn":
        raise ValueError(engine)

    fam = cfg.family
    scale = policy.grad_scale

    def _step_impl(params, opt_state, batch, hyper: Hyper, bits: dict,
                   rng: Optional[Array] = None):
        main_bits = bits["blocks"]
        bnd_keys = boundary_keys(params)
        bnd = {k: params[k] for k in bnd_keys}

        tokens = batch["tokens"]
        bsz, tlen = tokens.shape
        total_t = tlen + (batch["patch_embeds"].shape[1]
                          if fam == "vlm" else 0)
        positions = jnp.broadcast_to(jnp.arange(total_t), (bsz, total_t))

        # ---- encoder forward (encdec only) ------------------------------
        enc_caches = enc_out = enc_pos = None
        enc_vjp = None
        if fam == "encdec":
            dt = lm.compute_dtype(cfg)
            frames = batch["frames"].astype(dt)
            enc_x0 = frames + lm._sinusoid(frames.shape[1], cfg.d_model).astype(dt)
            enc_pos = jnp.broadcast_to(
                jnp.arange(frames.shape[1]), (bsz, frames.shape[1]))
            e_last, enc_caches, _ = forward_stack(
                _enc_body(cfg, enc_pos), params["enc_blocks"], (),
                enc_x0, bits["enc_blocks"], policy)
            enc_out, enc_vjp = jax.vjp(
                lambda en, xx: L.apply_norm(en, xx, cfg),
                bnd["enc_norm"], e_last)

        # ---- embed (with VJP for the input-side embedding gradient) -----
        embed_f = _embed_fn(cfg, batch, policy, _bits_edge(main_bits, 0))
        x0, embed_vjp = jax.vjp(embed_f, bnd)

        # ---- main stack forward, caching quantized X_i -------------------
        # hybrid: shared = the weight-tied attn block (quantized per layer)
        # encdec: shared = encoder output ACTIVATION (quantized once here)
        quantize_shared = fam == "hybrid"
        shared = (params["shared_attn"],) if fam == "hybrid" else ()
        if fam == "encdec":
            if policy.quantize_acts:
                eb = _bits_edge(bits["enc_blocks"], -1)
                enc_q = (eb["enabled"] * quantize_ste(
                    enc_out.astype(jnp.float32), eb["a_i"], eb["a_f"])
                    + (1.0 - eb["enabled"]) * enc_out.astype(jnp.float32)
                ).astype(enc_out.dtype)
            else:
                enc_q = enc_out
            shared = (enc_q,)
        body = _make_body(cfg, positions)

        def body_sh(p, sh, x, b_l):
            if fam == "hybrid":
                return body(p, sh[0], x, b_l)
            return body(p, sh, x, b_l)

        x_final, caches, aux_sum = forward_stack(
            body_sh, params["blocks"], shared, x0, main_bits, policy,
            quantize_shared=quantize_shared)

        # ---- head (loss) --------------------------------------------------
        head_f = _head_fn(cfg, batch, policy, _bits_edge(main_bits, -1), scale)
        loss, head_vjp, metrics = jax.vjp(head_f, bnd, x_final, has_aux=True)
        d_bnd_head, G_final = head_vjp(jnp.asarray(scale, jnp.float32))
        metrics["aux"] = aux_sum
        metrics["loss_total"] = loss + AUX_COEF * aux_sum

        # ---- the G-chain: reverse scan with fused per-layer updates ------
        G_in, new_blocks, new_blocks_opt, dshared, gsq = backward_stack(
            body_sh, params["blocks"], shared, opt_state["blocks"], caches,
            main_bits, G_final, hyper, policy, optim_cfg, AUX_COEF,
            base_key=rng, quantize_shared=quantize_shared)

        new_params = dict(params)
        new_opt = dict(opt_state)
        new_params["blocks"] = new_blocks
        new_opt["blocks"] = new_blocks_opt

        # ---- shared-attn update (hybrid) ---------------------------------
        if fam == "hybrid":
            d_shared_params = jax.tree.map(lambda g: g / scale, dshared[0])
            new_params["shared_attn"], new_opt["shared_attn"] = apply_update(
                params["shared_attn"], d_shared_params,
                opt_state["shared_attn"], hyper, optim_cfg)
            gsq = gsq + sum(jnp.sum(jnp.square(g))
                            for g in jax.tree.leaves(d_shared_params))

        # ---- encoder backward (encdec) ------------------------------------
        d_bnd_enc = None
        if fam == "encdec":
            (d_enc_out,) = dshared  # accumulated over decoder layers (SCALED)
            d_enc_norm, d_e_last = enc_vjp(d_enc_out.astype(enc_out.dtype))
            _, new_enc, new_enc_opt, _, gsq_e = backward_stack(
                _enc_body(cfg, enc_pos), params["enc_blocks"], (),
                opt_state["enc_blocks"], enc_caches, bits["enc_blocks"],
                d_e_last, hyper, policy, optim_cfg, AUX_COEF, base_key=rng)
            new_params["enc_blocks"] = new_enc
            new_opt["enc_blocks"] = new_enc_opt
            gsq = gsq + gsq_e
            d_bnd_enc = jax.tree.map(
                lambda w: jnp.zeros(w.shape, jnp.float32), bnd)
            d_bnd_enc["enc_norm"] = jax.tree.map(
                lambda g: g.astype(jnp.float32) / scale, d_enc_norm)

        # ---- boundary updates (embed gets head + input contributions) ----
        (d_bnd_embed,) = embed_vjp(G_in)
        d_bnd = jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) + b.astype(jnp.float32)) / scale,
            d_bnd_head, d_bnd_embed)
        if d_bnd_enc is not None:
            d_bnd = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 d_bnd, d_bnd_enc)
        bnd_new, bnd_opt_new = {}, {}
        for k in bnd_keys:
            bnd_new[k], bnd_opt_new[k] = apply_update(
                bnd[k], d_bnd[k], opt_state[k], hyper, optim_cfg)
            gsq = gsq + sum(jnp.sum(jnp.square(g))
                            for g in jax.tree.leaves(d_bnd[k]))
        new_params.update(bnd_new)
        new_opt.update(bnd_opt_new)

        metrics["grad_norm"] = jnp.sqrt(gsq)
        metrics.update(pipe_metrics)
        return new_params, new_opt, metrics

    def step(params, opt_state, batch, hyper: Hyper, bits: dict,
             rng: Optional[Array] = None):
        with kernel_backend_ctx(backend):  # active at trace time
            return _step_impl(params, opt_state, batch, hyper, bits, rng)

    step.pipeline_schedule = sched
    return step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = lm.loss_fn(params, cfg, batch)
        return metrics
    return eval_step
