# The paper's primary contribution: the TaxoNN unrolled-SGD manual-BP engine
# with per-layer fused updates and per-layer (I,F) quantization.
from repro.core.taxonn import (
    QuantPolicy,
    default_bits_for,
    forward_stack,
    backward_stack,
)
from repro.core.steps import StepOptions, make_train_step, make_eval_step
from repro.core.lenet import (
    LeNetBits,
    init_lenet_params,
    lenet_bits,
    lenet_bits_off,
    lenet_bits_table,
    make_lenet_train_step,
)

__all__ = [
    "QuantPolicy", "default_bits_for", "forward_stack", "backward_stack",
    "StepOptions", "make_train_step", "make_eval_step",
    "LeNetBits", "init_lenet_params", "lenet_bits", "lenet_bits_off",
    "lenet_bits_table", "make_lenet_train_step",
]
