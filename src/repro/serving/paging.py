"""Paged KV-cache bookkeeping: free-list block pool + prefix-sharing index.

The device side of paging is a block pool ([L, N_blocks, block, kv_heads,
head_dim] per K/V, see ``serving.engine.init_paged_state``); everything in
this module is HOST-side control state over the block axis:

  * ``BlockPool`` — free-list + refcounts.  Block 0 is reserved as the
    null block (masked slots write there; nothing reads it unmasked), so
    id 0 doubles as table padding.
  * ``PrefixIndex`` — prompt-prefix hash -> (token count, block ids).
    After a prefill completes, every block-aligned prefix boundary AND the
    full prompt length are registered; a later request reuses the longest
    matching registered prefix, paying retain() instead of prefill FLOPs.
    Because stored K/V is per-token (per-token int8 scales included), the
    reused bytes are bitwise what the request's own prefill would have
    written — the prefix-sharing bitwise test rests on this.

Copy-on-write: a reused boundary may sit mid-block (the entry's last block
is partially filled), and registration itself keeps a reference on a
request's final block.  Any write into a block with refcount > 1 must
therefore copy it first — the scheduler calls ``hooks.copy_block`` and
swaps the fresh id into the table (see ``BatchScheduler._ensure_block``).

Both structures snapshot to numpy pytrees and restore exactly, extending
the scheduler's checkpointability guarantee to the paged state.
"""
from __future__ import annotations

import hashlib
from collections import deque
from typing import Deque, Dict, List, Tuple

import numpy as np


class PoolExhausted(RuntimeError):
    """No free blocks left — admission control should have prevented this."""


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` positions."""
    return -(-int(n_tokens) // int(block_size))


class BlockPool:
    """Host-side free-list + refcounts over the device pool's block axis."""

    NULL = 0  # reserved null block: table padding / masked-slot writes

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved null "
                             f"block), got {num_blocks}")
        self.num_blocks = int(num_blocks)
        self.refs = np.zeros(self.num_blocks, np.int32)
        self.refs[self.NULL] = 1  # permanently held
        self.free: Deque[int] = deque(range(1, self.num_blocks))

    def available(self) -> int:
        return len(self.free)

    def alloc(self) -> int:
        if not self.free:
            raise PoolExhausted(
                f"block pool exhausted ({self.num_blocks} blocks)")
        bid = self.free.popleft()
        assert self.refs[bid] == 0, bid
        self.refs[bid] = 1
        return bid

    def retain(self, bid: int):
        assert self.refs[bid] > 0, bid
        self.refs[bid] += 1

    def release(self, bid: int):
        assert self.refs[bid] > 0, bid
        self.refs[bid] -= 1
        if self.refs[bid] == 0:
            self.free.append(bid)

    # -- checkpointability -------------------------------------------------

    def snapshot(self) -> dict:
        return {"num_blocks": int(self.num_blocks),
                "refs": self.refs.copy(),
                "free": np.asarray(list(self.free), np.int32)}

    @classmethod
    def restore(cls, snap: dict) -> "BlockPool":
        pool = cls(int(snap["num_blocks"]))
        pool.refs = np.asarray(snap["refs"], np.int32).copy()
        pool.free = deque(int(b) for b in np.asarray(snap["free"]).ravel())
        return pool


class PrefixIndex:
    """Prompt-prefix hash -> (n_tokens, block ids), holding one reference
    per block per entry.  ``drop(pool)`` releases everything — after all
    requests complete AND the index is dropped, every non-null refcount is
    zero (tested).

    Entries are LRU-ordered: dict insertion order doubles as recency
    (``lookup`` hits move the entry to the MRU end), so ``evict_lru`` can
    release individual cold entries until a block deficit is covered —
    the admission gate's alternative to dropping the whole index.  The
    order survives snapshot/restore (both walk insertion order)."""

    def __init__(self):
        self._entries: Dict[bytes, Tuple[int, Tuple[int, ...]]] = {}
        # raw token prefixes, kept so snapshots can rebuild the hashes
        self._tokens: Dict[bytes, np.ndarray] = {}

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def key(tokens: np.ndarray) -> bytes:
        t = np.ascontiguousarray(np.asarray(tokens, np.int32))
        return hashlib.sha1(t.tobytes()).digest() + len(t).to_bytes(4, "big")

    def insert(self, tokens: np.ndarray, block_ids: List[int], pool: BlockPool):
        k = self.key(tokens)
        if k in self._entries:
            return
        for bid in block_ids:
            pool.retain(bid)
        self._entries[k] = (len(tokens), tuple(int(b) for b in block_ids))
        self._tokens[k] = np.asarray(tokens, np.int32).copy()

    def register(self, prompt: np.ndarray, table: List[int], block_size: int,
                 pool: BlockPool):
        """Register every block boundary of a completed prefill, plus the
        full prompt (whose last block may be partial — the COW case)."""
        p = len(prompt)
        ends = list(range(block_size, p + 1, block_size))
        if p % block_size:
            ends.append(p)
        for e in ends:
            self.insert(prompt[:e], table[:blocks_for(e, block_size)], pool)

    def lookup(self, prompt: np.ndarray, limit: int
               ) -> Tuple[int, Tuple[int, ...]]:
        """Longest registered prefix of ``prompt`` with <= ``limit`` tokens
        (callers pass len(prompt)-1: at least one token must prefill so the
        first sampled token has logits).  Returns (0, ()) on miss."""
        lengths = sorted({n for n, _ in self._entries.values()
                          if n <= limit}, reverse=True)
        for n in lengths:
            k = self.key(prompt[:n])
            hit = self._entries.get(k)
            if hit is not None:
                self._touch(k)
                return hit
        return 0, ()

    def _touch(self, k: bytes):
        """Move an entry to the MRU end (dict insertion order is recency)."""
        self._entries[k] = self._entries.pop(k)
        self._tokens[k] = self._tokens.pop(k)

    def evict_lru(self, pool: BlockPool, need_free: int) -> int:
        """Release least-recently-used entries until at least ``need_free``
        blocks came back to the pool's free list (or the index is empty).
        Returns the number of blocks actually freed — less than the entry's
        block count when running requests still reference its blocks."""
        before = pool.available()
        while self._entries and pool.available() - before < need_free:
            k = next(iter(self._entries))
            _, blocks = self._entries.pop(k)
            del self._tokens[k]
            for bid in blocks:
                pool.release(bid)
        return pool.available() - before

    def drop(self, pool: BlockPool):
        for _, blocks in self._entries.values():
            for bid in blocks:
                pool.release(bid)
        self._entries.clear()
        self._tokens.clear()

    # -- checkpointability -------------------------------------------------

    def snapshot(self) -> dict:
        toks, blocks = [], []
        for k, (n, bids) in self._entries.items():
            toks.append(self._tokens[k])
            blocks.append(np.asarray(bids, np.int32))
        return {"tokens": toks, "blocks": blocks}

    @classmethod
    def restore(cls, snap: dict) -> "PrefixIndex":
        """Rebuild WITHOUT re-retaining: the pool snapshot's refcounts
        already include the index's references."""
        idx = cls()
        for t, b in zip(snap["tokens"], snap["blocks"]):
            t = np.asarray(t, np.int32)
            k = cls.key(t)
            idx._entries[k] = (len(t),
                               tuple(int(x) for x in np.asarray(b).ravel()))
            idx._tokens[k] = t.copy()
        return idx
