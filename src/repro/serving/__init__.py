from repro.serving.engine import (
    init_decode_state,
    decode_step,
    prefill,
    greedy_generate,
)
from repro.serving.scheduler import BatchScheduler, Request

__all__ = ["init_decode_state", "decode_step", "prefill", "greedy_generate",
           "BatchScheduler", "Request"]
