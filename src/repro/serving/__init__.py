from repro.serving.engine import (
    init_decode_state,
    decode_step,
    prefill,
    greedy_generate,
    init_paged_state,
    paged_decode_step,
    paged_prefill_chunk,
    paged_supported,
)
from repro.serving.paging import BlockPool, PoolExhausted, PrefixIndex
from repro.serving.scheduler import (
    BatchScheduler,
    EngineHooks,
    Request,
    ServeConfig,
)

__all__ = ["init_decode_state", "decode_step", "prefill", "greedy_generate",
           "init_paged_state", "paged_decode_step", "paged_prefill_chunk",
           "paged_supported", "BlockPool", "PoolExhausted", "PrefixIndex",
           "BatchScheduler", "EngineHooks", "Request", "ServeConfig"]
