"""Serving engine: prefill + single-token decode against per-layer caches.

Cache layout is stacked on a leading layer axis so the decode step is a
single ``lax.scan`` over (layer params, layer cache) — the serving analogue
of the training stacks.  Cache kinds per family:

  dense/moe/vlm : GQA KV ring buffers (ring = SWA window when configured —
                  the sliding window makes the cache O(window), a serving
                  memory win) or MLA compressed c_kv/k_pe latents.
  ssm           : O(1) SSD state + conv tail — this is why the long_500k
                  cell is SSM/hybrid-only.
  hybrid        : per-group attn KV (the weight-tied block still needs
                  per-application caches) + per-layer mamba states.
  encdec        : decoder self-attn KV + precomputed cross-attn K/V.

Quantized (int8-scaled) KV storage is available via ``cache_dtype`` — the
paper's low-bitwidth discipline applied to serving state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.api import constrain
from repro.kernels import decode_prologue as DP
from repro.kernels.ops import kernel_backend_ctx
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import lm
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.util.scan import xscan

Array = jax.Array


# ---------------------------------------------------------------------------
# Cache init (zeros; shapes only — used by input_specs for the dry-run)
# ---------------------------------------------------------------------------

def _stack_cache(n: int, one_fn):
    one = one_fn()
    return jax.tree.map(
        lambda x: jnp.zeros((n,) + x.shape, x.dtype), one)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      cache_dtype=jnp.bfloat16) -> dict:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        caches = _stack_cache(
            cfg.num_layers, lambda: B.init_block_cache(cfg, batch, max_len,
                                                       cache_dtype))
    elif fam == "ssm":
        caches = _stack_cache(
            cfg.num_layers, lambda: S.init_mamba_cache(cfg, batch, cache_dtype))
    elif fam == "hybrid":
        G, K = lm.hybrid_groups(cfg)
        attn = _stack_cache(G, lambda: L.init_kv_cache(cfg, batch, max_len,
                                                       cache_dtype))
        mamba = jax.tree.map(
            lambda x: jnp.zeros((G, K) + x.shape, x.dtype),
            S.init_mamba_cache(cfg, batch, cache_dtype))
        caches = {"attn": attn, "mamba": mamba}
    elif fam == "encdec":
        caches = _stack_cache(
            cfg.num_layers,
            lambda: B.init_decoder_cache(cfg, batch, max_len, cfg.encoder_seq,
                                         cache_dtype))
    else:
        raise ValueError(fam)
    return {"caches": caches, "pos": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, state: dict, tokens: Array):
    """One decode step. tokens: [B, 1] int32. Returns (logits [B,V], state)."""
    fam = cfg.family
    dt = lm.compute_dtype(cfg)
    pos = state["pos"]
    caches = state["caches"]

    x = params["embed"][tokens].astype(dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if fam == "encdec":
        x = x + lm._sinusoid(1, cfg.d_model, offset=pos).astype(dt)

    if fam in ("dense", "moe", "vlm"):
        def body(h, xs):
            p, c = xs
            h2, c2 = B.transformer_block_decode(p, h, cfg, c, pos)
            return h2, c2
        x, new_caches = xscan(body, x, (params["blocks"], caches))

    elif fam == "ssm":
        def body(h, xs):
            p, c = xs
            h2, c2 = B.mamba_block_decode(p, h, cfg, c, pos)
            return h2, c2
        x, new_caches = xscan(body, x, (params["blocks"], caches))

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(h, xs):
            gp, ac, mc = xs
            h, ac2 = B.transformer_block_decode(shared, h, cfg, ac, pos)

            def inner(hh, ys):
                p, c = ys
                h2, c2 = B.mamba_block_decode(p, hh, cfg, c, pos)
                return h2, c2
            h, mc2 = xscan(inner, h, (gp, mc))
            return h, (ac2, mc2)
        x, (new_attn, new_mamba) = xscan(
            group, x, (params["blocks"], caches["attn"], caches["mamba"]))
        new_caches = {"attn": new_attn, "mamba": new_mamba}

    elif fam == "encdec":
        def body(h, xs):
            p, c = xs
            h2, c2 = B.decoder_block_decode(p, h, cfg, c, pos)
            return h2, c2
        x, new_caches = xscan(body, x, (params["blocks"], caches))

    else:
        raise ValueError(fam)

    x = L.apply_norm(params["final_norm"], x, cfg)
    w = lm.head_weight(params, cfg)
    logits = constrain(
        (x[:, 0, :] @ w.astype(x.dtype)).astype(jnp.float32), "bv")
    return logits, {"caches": new_caches, "pos": pos + 1}


# ---------------------------------------------------------------------------
# Prefill: forward + seed caches
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch: dict, max_len: int,
            cache_dtype=jnp.bfloat16, kernel_backend: Optional[str] = None):
    """Run the full-context forward, returning (last_logits, decode state).

    ``kernel_backend`` selects the dense-unit datapath for the prefill
    matmuls ("off" | "emulate" | "int8" | None = "auto": off on CPU, int8
    on TPU) — prefill is compute-bound, exactly where the paper's low-bit
    MXU reuse pays; the per-token decode loop stays on the jnp path."""
    with kernel_backend_ctx(kernel_backend or "auto"):
        return _prefill_impl(params, cfg, batch, max_len, cache_dtype)


def _prefill_impl(params, cfg: ModelConfig, batch: dict, max_len: int,
                  cache_dtype=jnp.bfloat16):
    fam = cfg.family
    x, positions = lm.embed_input(params, cfg, batch)
    t = x.shape[1]

    if fam in ("dense", "moe", "vlm"):
        def body(h, p):
            h2, c = B.transformer_block_prefill(p, h, cfg, positions, max_len,
                                                cache_dtype)
            return h2, c
        x, caches = xscan(body, x, params["blocks"])

    elif fam == "ssm":
        def body(h, p):
            h2, c = B.mamba_block_prefill(p, h, cfg, positions, cache_dtype)
            return h2, c
        x, caches = xscan(body, x, params["blocks"])

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(h, gp):
            h, ac = B.transformer_block_prefill(shared, h, cfg, positions,
                                                max_len, cache_dtype)

            def inner(hh, p):
                h2, c = B.mamba_block_prefill(p, hh, cfg, positions,
                                              cache_dtype)
                return h2, c
            h, mc = xscan(inner, h, gp)
            return h, (ac, mc)
        x, (attn_c, mamba_c) = xscan(group, x, params["blocks"])
        caches = {"attn": attn_c, "mamba": mamba_c}

    elif fam == "encdec":
        enc_out = lm.encode(params, cfg, batch["frames"])

        def body(h, p):
            h2, c = B.decoder_block_prefill(p, h, cfg, positions, enc_out,
                                            max_len, cache_dtype)
            return h2, c
        x, caches = xscan(body, x, params["blocks"])

    else:
        raise ValueError(fam)

    x = L.apply_norm(params["final_norm"], x, cfg)
    w = lm.head_weight(params, cfg)
    logits = (x[:, -1, :] @ w.astype(x.dtype)).astype(jnp.float32)
    return logits, {"caches": caches, "pos": jnp.asarray(t, jnp.int32)}


# ---------------------------------------------------------------------------
# Paged KV: block-pool state + decode/prefill against per-request block tables
# ---------------------------------------------------------------------------
#
# The pool stores every layer's K/V in fixed-size blocks on a leading block
# axis: [L, N_blocks, block, kv_heads, head_dim].  A request owns an ordered
# block table (host-side, see serving/paging.py); token at absolute position
# p lives in table[p // block] at offset p % block.  Block 0 is the reserved
# null block: masked-out slots write there and nothing ever reads it
# unmasked.  ``cache_dtype=jnp.int8`` switches the payload to int8 with a
# PER-TOKEN absmax scale ([L, N, block] f32) — per-token scaling makes the
# stored bytes independent of chunking, which is what lets prefix sharing
# reuse blocks bitwise across requests.

PAGED_FAMILIES = ("dense", "moe", "vlm")


def paged_supported(cfg: ModelConfig) -> bool:
    """Paged decode covers the GQA-KV attention families; MLA latents, SWA
    rings, SSM state and cross-attention keep the contiguous path."""
    return (cfg.family in PAGED_FAMILIES and not cfg.use_mla
            and cfg.swa_window is None)


def init_paged_state(cfg: ModelConfig, num_blocks: int, block_size: int,
                     cache_dtype=jnp.bfloat16) -> dict:
    if not paged_supported(cfg):
        raise ValueError(f"paged KV unsupported for {cfg.family} "
                         f"(mla={cfg.use_mla}, swa={cfg.swa_window})")
    shape = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads,
             cfg.head_dim)
    if jnp.dtype(cache_dtype) == jnp.int8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, cache_dtype),
            "v": jnp.zeros(shape, cache_dtype)}


def constrain_pool(pool: dict) -> dict:
    """Shard the pool over the ambient mesh: blocks over the data axes, KV
    heads over "model" (see dist.api.make_default_rules); no-op unmeshed."""
    return {k: constrain(x, "lnshd" if x.ndim == 5 else "lns")
            for k, x in pool.items()}


def quant_kv_rows(x: Array):
    """Per-token int8 absmax: x [R, H, D] -> (int8 [R, H, D], scale [R]).

    This IS the serving KV quantization spec: scale = max(|row|, 1e-8)/127,
    payload = clip(round(x/scale), -127, 127).  ``search.export`` restates
    the same rule and the conformance suite (tests/test_bit_search.py)
    holds the two bit-for-bit equal, so a trained ``BitPlan`` exported to
    int8 serving sees exactly these numerics.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 2))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[:, None, None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


_quant_rows = quant_kv_rows  # internal alias (pre-export-path name)


def _pool_update(pool_l: dict, k: Array, v: Array, tables: Array,
                 qpos: Array) -> dict:
    """Write [B, C] new tokens' K/V into one layer's blocks.

    Distinct (slot, position) pairs hit distinct rows — except masked slots,
    whose tables are all-null: their rows collide on block 0, which is fine
    because the null block is never read unmasked.
    """
    bs = pool_l["k"].shape[1]
    bids = jnp.take_along_axis(tables, qpos // bs, axis=1).reshape(-1)
    offs = (qpos % bs).reshape(-1)
    kr = k.reshape((-1,) + k.shape[2:])
    vr = v.reshape((-1,) + v.shape[2:])
    out = dict(pool_l)
    if "k_scale" in pool_l:
        qk, sk = _quant_rows(kr)
        qv, sv = _quant_rows(vr)
        out["k"] = pool_l["k"].at[bids, offs].set(qk)
        out["v"] = pool_l["v"].at[bids, offs].set(qv)
        out["k_scale"] = pool_l["k_scale"].at[bids, offs].set(sk)
        out["v_scale"] = pool_l["v_scale"].at[bids, offs].set(sv)
    else:
        out["k"] = pool_l["k"].at[bids, offs].set(kr.astype(pool_l["k"].dtype))
        out["v"] = pool_l["v"].at[bids, offs].set(vr.astype(pool_l["v"].dtype))
    return out


def _pool_gather(pool_l: dict, tables: Array, dt) -> tuple[Array, Array]:
    """Gather each slot's blocks in table order -> [B, M*block, Hkv, hd]."""
    kk = pool_l["k"][tables]
    vv = pool_l["v"][tables]
    b, m, bs, h, d = kk.shape
    kk = kk.reshape(b, m * bs, h, d)
    vv = vv.reshape(b, m * bs, h, d)
    if "k_scale" in pool_l:
        ks = pool_l["k_scale"][tables].reshape(b, m * bs)
        vs = pool_l["v_scale"][tables].reshape(b, m * bs)
        kk = kk.astype(dt) * ks[..., None, None].astype(dt)
        vv = vv.astype(dt) * vs[..., None, None].astype(dt)
    else:
        kk = kk.astype(dt)
        vv = vv.astype(dt)
    return kk, vv


def _paged_attention(params, h: Array, cfg: ModelConfig, pool_l: dict,
                     tables: Array, qpos: Array, attn_impl):
    """Attention over paged KV.  h: [B, C, D]; qpos: [B, C] absolute
    positions.  Writes the C new tokens' K/V, then attends over each slot's
    gathered blocks with kpos <= qpos masking — op-for-op the same math as
    ``layers.attention_decode``, so paged == contiguous bitwise (tested).
    """
    q, k, v = L._project_qkv(params, h, cfg, qpos)
    return _paged_attention_tail(params, q, k, v, h.dtype, cfg, pool_l,
                                 tables, qpos, attn_impl)


def _paged_attention_tail(params, q: Array, k: Array, v: Array, dt,
                          cfg: ModelConfig, pool_l: dict, tables: Array,
                          qpos: Array, attn_impl):
    """Pool write + gather/kernel attention + output projection — everything
    after the prologue, shared by the unfused path above and the fused
    decode-prologue kernel (kernels.decode_prologue)."""
    pool_l = _pool_update(pool_l, k, v, tables, qpos)
    groups = q.shape[2] // cfg.num_kv_heads
    scale = cfg.head_dim ** -0.5
    if attn_impl == "kernel" and q.shape[1] == 1:
        from repro.kernels import paged_attention as PA
        out = PA.paged_attention(q[:, 0], pool_l, tables, qpos[:, 0],
                                 groups=groups, scale=scale)[:, None]
    else:
        kk, vv = _pool_gather(pool_l, tables, dt)
        kk = L._expand_kv(kk, groups)
        vv = L._expand_kv(vv, groups)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                       preferred_element_type=jnp.float32) * scale
        kpos = jnp.arange(kk.shape[1])
        ok = kpos[None, None, :] <= qpos[:, :, None]  # [B, C, T]
        s = s + jnp.where(ok, 0.0, L.NEG_INF)[:, None]
        p = jax.nn.softmax(s, axis=-1).astype(dt)
        out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    y = jnp.einsum("bthk,hkd->btd", out, L._masked_wo(params, cfg, dt))
    return y, pool_l


def _paged_block(p, x: Array, cfg: ModelConfig, pool_l: dict, tables: Array,
                 qpos: Array, attn_impl, prologue: bool = False):
    if prologue and DP.prologue_active(cfg, x):
        # §Kernels: fused RMSNorm+QKV+rope prologue in front of the paged
        # pool write + paged-attention kernel (one HBM round-trip)
        q, k, v = DP.decode_prologue(p["attn_norm"], p["attn"], x, cfg,
                                     qpos[:, 0])
        attn_out, pool_l = _paged_attention_tail(
            p["attn"], q, k, v, x.dtype, cfg, pool_l, tables, qpos,
            attn_impl)
    else:
        h = L.apply_norm(p["attn_norm"], x, cfg)
        attn_out, pool_l = _paged_attention(p["attn"], h, cfg, pool_l,
                                            tables, qpos, attn_impl)
    x = x + attn_out
    h = L.apply_norm(p["mlp_norm"], x, cfg)
    if cfg.family == "moe":
        mlp_out, _ = L.moe(p["moe"], h, cfg)
    else:
        mlp_out = L.mlp(p["mlp"], h, cfg)
    return x + mlp_out, pool_l


def _embed_tokens(params, cfg: ModelConfig, tokens: Array, dt) -> Array:
    x = params["embed"][tokens].astype(dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    return x


def paged_decode_step(params, cfg: ModelConfig, pool: dict, tables: Array,
                      seq_lens: Array, tokens: Array, attn_impl=None):
    """One decode step over the slot batch against the paged pool.

    tokens: [B, 1] int32; tables: [B, M] int32 block tables (null rows for
    empty slots); seq_lens: [B] int32 — tokens already cached per slot,
    i.e. the incoming token's write position.  attn_impl: None/"ref" = the
    jnp gather path, "kernel" = the fused Pallas paged-attention kernel.
    Returns (logits [B, V] f32, pool).
    """
    if not paged_supported(cfg):
        raise ValueError(f"paged decode unsupported for {cfg.family}")
    dt = lm.compute_dtype(cfg)
    pool = constrain_pool(pool)
    x = _embed_tokens(params, cfg, tokens, dt)
    qpos = seq_lens.astype(jnp.int32)[:, None]

    def body(h, xs):
        p, pl_ = xs
        h2, pl2 = _paged_block(p, h, cfg, pl_, tables, qpos, attn_impl,
                               prologue=True)
        return h2, pl2
    x, new_pool = xscan(body, x, (params["blocks"], pool))
    x = L.apply_norm(params["final_norm"], x, cfg)
    w = lm.head_weight(params, cfg)
    logits = constrain(
        (x[:, 0, :] @ w.astype(x.dtype)).astype(jnp.float32), "bv")
    return logits, constrain_pool(new_pool)


def paged_prefill_chunk(params, cfg: ModelConfig, pool: dict, table: Array,
                        tokens: Array, start) -> tuple[Array, dict]:
    """Prefill ``tokens`` [1, C] at absolute positions start..start+C-1.

    Each chunk attends over the pool contents written so far (earlier
    chunks / reused prefix blocks) plus its own causally-masked K/V — so a
    prompt prefills in per-tick budgets without a contiguous cache.
    Returns (last-token logits [1, V], pool).
    """
    if not paged_supported(cfg):
        raise ValueError(f"paged prefill unsupported for {cfg.family}")
    dt = lm.compute_dtype(cfg)
    pool = constrain_pool(pool)
    c = tokens.shape[1]
    qpos = (jnp.asarray(start, jnp.int32)
            + jnp.arange(c, dtype=jnp.int32))[None, :]
    x = _embed_tokens(params, cfg, tokens, dt)

    def body(h, xs):
        p, pl_ = xs
        h2, pl2 = _paged_block(p, h, cfg, pl_, table, qpos, "ref")
        return h2, pl2
    x, new_pool = xscan(body, x, (params["blocks"], pool))
    x = L.apply_norm(params["final_norm"], x, cfg)
    w = lm.head_weight(params, cfg)
    logits = constrain(
        (x[:, -1, :] @ w.astype(x.dtype)).astype(jnp.float32), "bv")
    return logits, constrain_pool(new_pool)


def greedy_generate(params, cfg: ModelConfig, batch: dict, max_len: int,
                    num_steps: int, cache_dtype=jnp.bfloat16,
                    kernel_backend: Optional[str] = None):
    """Prefill + greedy decode loop (reference serving driver)."""
    logits, state = prefill(params, cfg, batch, max_len, cache_dtype,
                            kernel_backend=kernel_backend)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(num_steps):
        out.append(tok)
        logits, state = decode_step(params, cfg, state, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
