"""Serving engine: prefill + single-token decode against per-layer caches.

Cache layout is stacked on a leading layer axis so the decode step is a
single ``lax.scan`` over (layer params, layer cache) — the serving analogue
of the training stacks.  Cache kinds per family:

  dense/moe/vlm : GQA KV ring buffers (ring = SWA window when configured —
                  the sliding window makes the cache O(window), a serving
                  memory win) or MLA compressed c_kv/k_pe latents.
  ssm           : O(1) SSD state + conv tail — this is why the long_500k
                  cell is SSM/hybrid-only.
  hybrid        : per-group attn KV (the weight-tied block still needs
                  per-application caches) + per-layer mamba states.
  encdec        : decoder self-attn KV + precomputed cross-attn K/V.

Quantized (int8-scaled) KV storage is available via ``cache_dtype`` — the
paper's low-bitwidth discipline applied to serving state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.api import constrain
from repro.kernels.ops import kernel_backend_ctx
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import lm
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.util.scan import xscan

Array = jax.Array


# ---------------------------------------------------------------------------
# Cache init (zeros; shapes only — used by input_specs for the dry-run)
# ---------------------------------------------------------------------------

def _stack_cache(n: int, one_fn):
    one = one_fn()
    return jax.tree.map(
        lambda x: jnp.zeros((n,) + x.shape, x.dtype), one)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      cache_dtype=jnp.bfloat16) -> dict:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        caches = _stack_cache(
            cfg.num_layers, lambda: B.init_block_cache(cfg, batch, max_len,
                                                       cache_dtype))
    elif fam == "ssm":
        caches = _stack_cache(
            cfg.num_layers, lambda: S.init_mamba_cache(cfg, batch, cache_dtype))
    elif fam == "hybrid":
        G, K = lm.hybrid_groups(cfg)
        attn = _stack_cache(G, lambda: L.init_kv_cache(cfg, batch, max_len,
                                                       cache_dtype))
        mamba = jax.tree.map(
            lambda x: jnp.zeros((G, K) + x.shape, x.dtype),
            S.init_mamba_cache(cfg, batch, cache_dtype))
        caches = {"attn": attn, "mamba": mamba}
    elif fam == "encdec":
        caches = _stack_cache(
            cfg.num_layers,
            lambda: B.init_decoder_cache(cfg, batch, max_len, cfg.encoder_seq,
                                         cache_dtype))
    else:
        raise ValueError(fam)
    return {"caches": caches, "pos": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, state: dict, tokens: Array):
    """One decode step. tokens: [B, 1] int32. Returns (logits [B,V], state)."""
    fam = cfg.family
    dt = lm.compute_dtype(cfg)
    pos = state["pos"]
    caches = state["caches"]

    x = params["embed"][tokens].astype(dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if fam == "encdec":
        x = x + lm._sinusoid(1, cfg.d_model, offset=pos).astype(dt)

    if fam in ("dense", "moe", "vlm"):
        def body(h, xs):
            p, c = xs
            h2, c2 = B.transformer_block_decode(p, h, cfg, c, pos)
            return h2, c2
        x, new_caches = xscan(body, x, (params["blocks"], caches))

    elif fam == "ssm":
        def body(h, xs):
            p, c = xs
            h2, c2 = B.mamba_block_decode(p, h, cfg, c, pos)
            return h2, c2
        x, new_caches = xscan(body, x, (params["blocks"], caches))

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(h, xs):
            gp, ac, mc = xs
            h, ac2 = B.transformer_block_decode(shared, h, cfg, ac, pos)

            def inner(hh, ys):
                p, c = ys
                h2, c2 = B.mamba_block_decode(p, hh, cfg, c, pos)
                return h2, c2
            h, mc2 = xscan(inner, h, (gp, mc))
            return h, (ac2, mc2)
        x, (new_attn, new_mamba) = xscan(
            group, x, (params["blocks"], caches["attn"], caches["mamba"]))
        new_caches = {"attn": new_attn, "mamba": new_mamba}

    elif fam == "encdec":
        def body(h, xs):
            p, c = xs
            h2, c2 = B.decoder_block_decode(p, h, cfg, c, pos)
            return h2, c2
        x, new_caches = xscan(body, x, (params["blocks"], caches))

    else:
        raise ValueError(fam)

    x = L.apply_norm(params["final_norm"], x, cfg)
    w = lm.head_weight(params, cfg)
    logits = constrain(
        (x[:, 0, :] @ w.astype(x.dtype)).astype(jnp.float32), "bv")
    return logits, {"caches": new_caches, "pos": pos + 1}


# ---------------------------------------------------------------------------
# Prefill: forward + seed caches
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch: dict, max_len: int,
            cache_dtype=jnp.bfloat16, kernel_backend: Optional[str] = None):
    """Run the full-context forward, returning (last_logits, decode state).

    ``kernel_backend`` selects the dense-unit datapath for the prefill
    matmuls ("off" | "emulate" | "int8" | None = "auto": off on CPU, int8
    on TPU) — prefill is compute-bound, exactly where the paper's low-bit
    MXU reuse pays; the per-token decode loop stays on the jnp path."""
    with kernel_backend_ctx(kernel_backend or "auto"):
        return _prefill_impl(params, cfg, batch, max_len, cache_dtype)


def _prefill_impl(params, cfg: ModelConfig, batch: dict, max_len: int,
                  cache_dtype=jnp.bfloat16):
    fam = cfg.family
    x, positions = lm.embed_input(params, cfg, batch)
    t = x.shape[1]

    if fam in ("dense", "moe", "vlm"):
        def body(h, p):
            h2, c = B.transformer_block_prefill(p, h, cfg, positions, max_len,
                                                cache_dtype)
            return h2, c
        x, caches = xscan(body, x, params["blocks"])

    elif fam == "ssm":
        def body(h, p):
            h2, c = B.mamba_block_prefill(p, h, cfg, positions, cache_dtype)
            return h2, c
        x, caches = xscan(body, x, params["blocks"])

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(h, gp):
            h, ac = B.transformer_block_prefill(shared, h, cfg, positions,
                                                max_len, cache_dtype)

            def inner(hh, p):
                h2, c = B.mamba_block_prefill(p, hh, cfg, positions,
                                              cache_dtype)
                return h2, c
            h, mc = xscan(inner, h, gp)
            return h, (ac, mc)
        x, (attn_c, mamba_c) = xscan(group, x, params["blocks"])
        caches = {"attn": attn_c, "mamba": mamba_c}

    elif fam == "encdec":
        enc_out = lm.encode(params, cfg, batch["frames"])

        def body(h, p):
            h2, c = B.decoder_block_prefill(p, h, cfg, positions, enc_out,
                                            max_len, cache_dtype)
            return h2, c
        x, caches = xscan(body, x, params["blocks"])

    else:
        raise ValueError(fam)

    x = L.apply_norm(params["final_norm"], x, cfg)
    w = lm.head_weight(params, cfg)
    logits = (x[:, -1, :] @ w.astype(x.dtype)).astype(jnp.float32)
    return logits, {"caches": caches, "pos": jnp.asarray(t, jnp.int32)}


def greedy_generate(params, cfg: ModelConfig, batch: dict, max_len: int,
                    num_steps: int, cache_dtype=jnp.bfloat16,
                    kernel_backend: Optional[str] = None):
    """Prefill + greedy decode loop (reference serving driver)."""
    logits, state = prefill(params, cfg, batch, max_len, cache_dtype,
                            kernel_backend=kernel_backend)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(num_steps):
        out.append(tok)
        logits, state = decode_step(params, cfg, state, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
