"""Slot-based continuous batching for the decode loop.

A fixed-size batch of decode slots runs every step; finished or empty slots
are refilled from a FIFO of pending requests (prefill writes the new
request's cache into the slot).  This is the standard continuous-batching
scheme adapted to JAX's static shapes: the batch dimension is fixed, slot
occupancy is a host-side mask, and per-slot positions live in the cache
state.

The scheduler is host-side control logic and is CHECKPOINTABLE as a tested
fact (tests/test_serving.py::test_scheduler_snapshot_resumes_identically):
``snapshot()`` captures the queue state (pending FIFO, slot occupancy, next
tokens, per-request progress) together with the device-side cache state as
host arrays, and ``BatchScheduler.restore`` rebuilds a scheduler that
continues the stream with IDENTICAL outputs — mid-decode preemption costs
nothing but the snapshot.  The snapshot is a pytree of arrays/ints, so it
round-trips through ``repro.ckpt.save_checkpoint`` unchanged.  The
device-side steps stay pure and jitted.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [T] int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Drives (prefill_fn, decode_fn) over a fixed slot batch.

    prefill_fn(tokens [1,T]) -> (logits [1,V], slot_state)
    decode_fn(state, tokens [B,1]) -> (logits [B,V], state)
    merge_fn(state, slot_state, slot_idx) -> state   (writes one slot's cache)
    """

    def __init__(self, num_slots: int, prefill_fn: Callable,
                 decode_fn: Callable, merge_fn: Callable, init_state,
                 eos_id: int = -1):
        self.num_slots = num_slots
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.merge_fn = merge_fn
        self.state = init_state
        self.eos_id = eos_id
        self.pending: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * num_slots
        self.next_tokens = np.zeros((num_slots, 1), np.int32)
        self.steps_run = 0

    def submit(self, req: Request):
        self.pending.append(req)

    def _fill_slots(self):
        for i in range(self.num_slots):
            if self.slots[i] is None and self.pending:
                req = self.pending.popleft()
                logits, slot_state = self.prefill_fn(req.prompt[None, :])
                self.state = self.merge_fn(self.state, slot_state, i)
                tok = int(np.argmax(np.asarray(logits)[0]))
                req.generated.append(tok)
                self.next_tokens[i, 0] = tok
                self.slots[i] = req

    def step(self) -> int:
        """One decode step over the batch. Returns #active slots."""
        self._fill_slots()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        logits, self.state = self.decode_fn(
            self.state, jnp.asarray(self.next_tokens))
        toks = np.argmax(np.asarray(logits), axis=-1)
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.generated.append(tok)
            self.next_tokens[i, 0] = tok
            if tok == self.eos_id or len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None
        self.steps_run += 1
        return len(active)

    # -- checkpointability: the docstring claim, made mechanical ----------

    def snapshot(self) -> dict:
        """Host-side copy of the full scheduler state (a pytree of numpy
        arrays, ints and bools — msgpack/np.save-friendly, so it rides
        ``repro.ckpt.save_checkpoint`` as-is)."""
        def pack(r: Request) -> dict:
            return {"uid": int(r.uid),
                    "prompt": np.asarray(r.prompt, np.int32).copy(),
                    "max_new_tokens": int(r.max_new_tokens),
                    "generated": np.asarray(r.generated, np.int32),
                    "done": bool(r.done)}

        return {
            "num_slots": int(self.num_slots),
            "eos_id": int(self.eos_id),
            "steps_run": int(self.steps_run),
            "next_tokens": np.asarray(self.next_tokens).copy(),
            # slot occupancy: pack occupied slots with their index so the
            # pytree has no None leaves (None is a structure change)
            "slot_idx": np.asarray(
                [i for i, r in enumerate(self.slots) if r is not None],
                np.int32),
            "slot_reqs": [pack(r) for r in self.slots if r is not None],
            "pending": [pack(r) for r in self.pending],
            "state": jax.tree.map(np.asarray, self.state),
        }

    @classmethod
    def restore(cls, snap: dict, prefill_fn: Callable, decode_fn: Callable,
                merge_fn: Callable) -> "BatchScheduler":
        """Rebuild a scheduler from ``snapshot()`` output; the continued
        decode stream is identical to the uninterrupted one (the functions
        are stateless — only the snapshot carries state)."""
        def unpack(d: dict) -> Request:
            return Request(uid=int(d["uid"]),
                           prompt=np.asarray(d["prompt"], np.int32),
                           max_new_tokens=int(d["max_new_tokens"]),
                           generated=[int(t) for t in
                                      np.asarray(d["generated"]).ravel()],
                           done=bool(d["done"]))

        state = jax.tree.map(jnp.asarray, snap["state"])
        sched = cls(int(snap["num_slots"]), prefill_fn, decode_fn, merge_fn,
                    state, eos_id=int(snap["eos_id"]))
        sched.steps_run = int(snap["steps_run"])
        sched.next_tokens = np.asarray(snap["next_tokens"], np.int32).copy()
        for i, req in zip(np.asarray(snap["slot_idx"]).ravel(),
                          snap["slot_reqs"]):
            sched.slots[int(i)] = unpack(req)
        for req in snap["pending"]:
            sched.pending.append(unpack(req))
        return sched

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen: Dict[int, Request] = {}
        for _ in range(max_steps):
            for r in list(self.slots) + list(self.pending):
                if r is not None:
                    seen[r.uid] = r
            if self.step() == 0 and not self.pending:
                break
        for r in seen.values():
            if r.done:
                finished.append(r)
        return finished
