"""Slot-based continuous batching for the decode loop.

A fixed-size batch of decode slots runs every step; finished or empty slots
are refilled from a FIFO of pending requests (prefill writes the new
request's cache into the slot).  This is the standard continuous-batching
scheme adapted to JAX's static shapes: the batch dimension is fixed, slot
occupancy is a host-side mask, and per-slot positions live in the cache
state.

The scheduler is host-side control logic (fault-tolerant: its queue state is
trivially checkpointable); the device-side steps stay pure and jitted.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [T] int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Drives (prefill_fn, decode_fn) over a fixed slot batch.

    prefill_fn(tokens [1,T]) -> (logits [1,V], slot_state)
    decode_fn(state, tokens [B,1]) -> (logits [B,V], state)
    merge_fn(state, slot_state, slot_idx) -> state   (writes one slot's cache)
    """

    def __init__(self, num_slots: int, prefill_fn: Callable,
                 decode_fn: Callable, merge_fn: Callable, init_state,
                 eos_id: int = -1):
        self.num_slots = num_slots
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.merge_fn = merge_fn
        self.state = init_state
        self.eos_id = eos_id
        self.pending: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * num_slots
        self.next_tokens = np.zeros((num_slots, 1), np.int32)
        self.steps_run = 0

    def submit(self, req: Request):
        self.pending.append(req)

    def _fill_slots(self):
        for i in range(self.num_slots):
            if self.slots[i] is None and self.pending:
                req = self.pending.popleft()
                logits, slot_state = self.prefill_fn(req.prompt[None, :])
                self.state = self.merge_fn(self.state, slot_state, i)
                tok = int(np.argmax(np.asarray(logits)[0]))
                req.generated.append(tok)
                self.next_tokens[i, 0] = tok
                self.slots[i] = req

    def step(self) -> int:
        """One decode step over the batch. Returns #active slots."""
        self._fill_slots()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        logits, self.state = self.decode_fn(
            self.state, jnp.asarray(self.next_tokens))
        toks = np.argmax(np.asarray(logits), axis=-1)
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.generated.append(tok)
            self.next_tokens[i, 0] = tok
            if tok == self.eos_id or len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None
        self.steps_run += 1
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen: Dict[int, Request] = {}
        for _ in range(max_steps):
            for r in list(self.slots) + list(self.pending):
                if r is not None:
                    seen[r.uid] = r
            if self.step() == 0 and not self.pending:
                break
        for r in seen.values():
            if r.done:
                finished.append(r)
        return finished
