"""Continuous batching over a fixed slot batch — contiguous or paged KV.

The scheduler is host-side control logic around jitted device steps.  Two
cache regimes share one driver:

  * ``mode="contiguous"`` — the original scheme: whole-prompt prefill into
    a per-slot contiguous cache, then batched decode.  This is the legacy
    behavior, bit-for-bit, including the snapshot format.
  * ``mode="paged"`` — the PR-8 scale-out path: a block pool
    (``serving.paging``) replaces per-slot caches.  Prompts prefill in
    per-tick token budgets (*chunked prefill*) interleaved with decode, so
    a long admission never stalls running streams; admission is FIFO or
    priority against free-block accounting; shared prompt prefixes reuse
    blocks copy-on-write via the prefix index.

API (PR 8): construct with ``BatchScheduler(ServeConfig(...), EngineHooks
(...))``.  The legacy positional ``BatchScheduler(num_slots, prefill_fn,
decode_fn, merge_fn, init_state, eos_id=...)`` still works through an
adapter that emits a DeprecationWarning — as does the ``eos_id=-1``
"never matches" sentinel, which ``ServeConfig`` replaces with an explicit
``eos_id=None``.

The scheduler stays CHECKPOINTABLE as a tested fact
(tests/test_serving.py, tests/test_paging.py): ``snapshot()`` captures
queue state + device cache as host arrays — in paged mode that extends to
the pool tensor, the free-list/refcounts, per-slot block tables and the
prefix index — and ``BatchScheduler.restore`` continues the stream with
IDENTICAL outputs, even mid-chunked-prefill.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from collections import deque
from functools import partial
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.paging import (BlockPool, PoolExhausted, PrefixIndex,
                                  blocks_for)

_CACHE_DTYPES = ("bfloat16", "float32", "int8")
_KERNEL_BACKENDS = ("auto", "off", "emulate", "int8")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [T] int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    priority: int = 0           # higher admits first under admission="priority"


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine-facing serving configuration (replaces the loose kwargs of
    the legacy ctor).  ``eos_id`` is REQUIRED: pass the tokenizer's real id,
    or ``None`` to run every request to its max_new_tokens — the old ``-1``
    sentinel (an id no tokenizer emits) is accepted with a
    DeprecationWarning and mapped to ``None``."""
    num_slots: int
    eos_id: Optional[int]
    max_len: int = 64
    mode: str = "paged"                  # "paged" | "contiguous"
    block_size: int = 8
    num_blocks: Optional[int] = None     # None: 1 null + slots*max_blocks
    prefill_chunk: Optional[int] = None  # tokens/tick budget; None: block_size
    cache_dtype: str = "bfloat16"
    prefix_sharing: bool = True
    admission: str = "fifo"              # "fifo" | "priority"
    attn_impl: Optional[str] = None      # None/"ref" | "kernel" (paged decode)
    kernel_backend: Optional[str] = None  # None | "auto"/"off"/"emulate"/
    #   "int8": backend installed around the DECODE hooks only, enabling the
    #   fused decode-prologue kernel (prefill stays unfused so prefix-shared
    #   block bytes are chunk-invariant)

    def __post_init__(self):
        if self.eos_id == -1:
            warnings.warn(
                "eos_id=-1 was the legacy 'never matches' sentinel; pass "
                "eos_id=None explicitly", DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "eos_id", None)
        if self.mode not in ("paged", "contiguous"):
            raise ValueError(f"mode must be 'paged' or 'contiguous', "
                             f"got {self.mode!r}")
        if self.admission not in ("fifo", "priority"):
            raise ValueError(f"admission must be 'fifo' or 'priority', "
                             f"got {self.admission!r}")
        if self.cache_dtype not in _CACHE_DTYPES:
            raise ValueError(f"cache_dtype must be one of {_CACHE_DTYPES}, "
                             f"got {self.cache_dtype!r}")
        if self.kernel_backend is not None \
                and self.kernel_backend not in _KERNEL_BACKENDS:
            raise ValueError(f"kernel_backend must be None or one of "
                             f"{_KERNEL_BACKENDS}, got {self.kernel_backend!r}")
        if self.num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if self.mode == "paged":
            if self.block_size < 1:
                raise ValueError("block_size must be >= 1")
            if self.max_len % self.block_size:
                raise ValueError(
                    f"max_len ({self.max_len}) must be a multiple of "
                    f"block_size ({self.block_size})")
            if self.prefill_chunk is not None and self.prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1")

    @property
    def max_blocks_per_seq(self) -> int:
        return self.max_len // self.block_size

    @property
    def resolved_num_blocks(self) -> int:
        # +2 per slot: admission reserves COW-copy slack on top of each
        # request's worst-case footprint (see BatchScheduler._admit)
        if self.num_blocks is not None:
            return self.num_blocks
        return 1 + self.num_slots * (self.max_blocks_per_seq + 2)

    @property
    def chunk_tokens(self) -> int:
        return self.prefill_chunk or self.block_size

    def jnp_cache_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                "int8": jnp.int8}[self.cache_dtype]

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class EngineHooks:
    """The device-step surface the scheduler drives (replaces the legacy
    positional callable triple).

    contiguous mode:
      prefill(tokens [1,T]) -> (logits [1,V], slot_state)
      decode(state, tokens [B,1]) -> (logits [B,V], state)
      merge(state, slot_state, i) -> state
      init_state: batched decode state
    paged mode:
      decode(pool, tables [B,M], lens [B], tokens [B,1]) -> (logits, pool)
      prefill_chunk(pool, table [1,M], tokens [1,C], start) -> (logits, pool)
      copy_block(pool, src, dst) -> pool      (COW block copy on device)
      init_state: the block pool pytree
    """
    prefill: Optional[Callable] = None
    decode: Optional[Callable] = None
    merge: Optional[Callable] = None
    prefill_chunk: Optional[Callable] = None
    copy_block: Optional[Callable] = None
    init_state: Any = None

    @classmethod
    def for_model(cls, params, cfg, serve: ServeConfig) -> "EngineHooks":
        """Build jitted closures over (params, cfg) for either mode.

        ``serve.kernel_backend`` installs a kernel backend around the
        DECODE hook only (trace- and call-time), turning on the fused
        decode-prologue kernel; prefill is left unfused so prefix-shared
        block bytes stay identical regardless of chunking."""
        from repro.kernels import ops as kops
        from repro.serving import engine as E

        def _decode_backend(fn):
            if serve.kernel_backend is None:
                return fn

            def wrapped(*args):
                with kops.kernel_backend_ctx(serve.kernel_backend):
                    return fn(*args)
            return wrapped

        dtype = serve.jnp_cache_dtype()
        if serve.mode == "paged":
            pool = E.init_paged_state(cfg, serve.resolved_num_blocks,
                                      serve.block_size, dtype)
            decode = _decode_backend(jax.jit(
                lambda pool, tables, lens, toks: E.paged_decode_step(
                    params, cfg, pool, tables, lens, toks, serve.attn_impl),
                donate_argnums=(0,)))
            chunk = jax.jit(
                lambda pool, table, toks, start: E.paged_prefill_chunk(
                    params, cfg, pool, table, toks, start),
                donate_argnums=(0,))
            copy = jax.jit(
                lambda pool, src, dst: {
                    k: x.at[:, dst].set(x[:, src]) for k, x in pool.items()},
                donate_argnums=(0,))
            return cls(decode=decode, prefill_chunk=chunk, copy_block=copy,
                       init_state=pool)
        state = E.init_decode_state(cfg, serve.num_slots, serve.max_len,
                                    dtype)

        prefill_one = jax.jit(
            lambda tokens: E.prefill(params, cfg,
                                     {"tokens": jnp.asarray(tokens)},
                                     serve.max_len, dtype))

        decode = _decode_backend(jax.jit(
            lambda state, toks: E.decode_step(params, cfg, state, toks),
            donate_argnums=(0,)))

        @partial(jax.jit, donate_argnums=(0,))
        def merge(state, slot_state, i):
            def wr(dst, src):
                return dst.at[:, i].set(src[:, 0])
            return {"caches": jax.tree.map(wr, state["caches"],
                                           slot_state["caches"]),
                    "pos": slot_state["pos"]}

        return cls(prefill=prefill_one, decode=decode, merge=merge,
                   init_state=state)


_LEGACY_CTOR_MSG = (
    "BatchScheduler(num_slots, prefill_fn, decode_fn, merge_fn, init_state) "
    "is deprecated; use BatchScheduler(ServeConfig(...), EngineHooks(...))")


class BatchScheduler:
    """Drives ``EngineHooks`` over a fixed slot batch (see module docstring
    for the contiguous/paged split and the legacy-ctor adapter)."""

    def __init__(self, config, hooks=None, decode_fn=None, merge_fn=None,
                 init_state=None, eos_id=-1):
        if isinstance(config, ServeConfig):
            if not isinstance(hooks, EngineHooks):
                raise TypeError("new-style BatchScheduler takes "
                                "(ServeConfig, EngineHooks)")
        else:
            # legacy positional ctor: (num_slots, prefill, decode, merge,
            # init_state, eos_id=-1)
            warnings.warn(_LEGACY_CTOR_MSG, DeprecationWarning, stacklevel=2)
            num_slots = int(config)
            if eos_id == -1:
                warnings.warn(
                    "eos_id=-1 was the legacy 'never matches' sentinel; "
                    "pass an explicit eos_id (or None)",
                    DeprecationWarning, stacklevel=2)
                eos = None
            else:
                eos = eos_id
            config = ServeConfig(num_slots=num_slots, eos_id=eos,
                                 mode="contiguous")
            hooks = EngineHooks(prefill=hooks, decode=decode_fn,
                                merge=merge_fn, init_state=init_state)
        self._setup(config, hooks)

    def _setup(self, config: ServeConfig, hooks: EngineHooks):
        self.config = config
        self.hooks = hooks
        self.num_slots = config.num_slots
        self.eos_id = config.eos_id
        self.pending: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * self.num_slots
        self.next_tokens = np.zeros((self.num_slots, 1), np.int32)
        self.steps_run = 0
        self.tick_log: List[dict] = []
        self.stats = {"prefix_hits": 0, "reused_tokens": 0, "cow_copies": 0,
                      "prefill_tokens": 0, "prefix_evictions": 0,
                      "evicted_blocks": 0}
        if config.mode == "paged":
            if hooks.decode is None or hooks.prefill_chunk is None \
                    or hooks.copy_block is None:
                raise ValueError("paged mode needs decode, prefill_chunk and "
                                 "copy_block hooks")
            self.pool = hooks.init_state
            self.block_pool = BlockPool(config.resolved_num_blocks)
            self.prefix: Optional[PrefixIndex] = (
                PrefixIndex() if config.prefix_sharing else None)
            self._tables: List[List[int]] = [[] for _ in range(self.num_slots)]
            self._pos = np.zeros(self.num_slots, np.int64)
            self._prefilling = np.zeros(self.num_slots, bool)
        else:
            self.state = hooks.init_state

    # legacy attribute aliases (the old ctor stored the callables directly)
    @property
    def prefill_fn(self):
        return self.hooks.prefill

    @property
    def decode_fn(self):
        return self.hooks.decode

    @property
    def merge_fn(self):
        return self.hooks.merge

    def submit(self, req: Request):
        if self.config.mode == "paged":
            total = len(req.prompt) + req.max_new_tokens
            if total > self.config.max_len:
                raise ValueError(
                    f"request {req.uid}: prompt+max_new ({total}) exceeds "
                    f"max_len ({self.config.max_len})")
        self.pending.append(req)

    # ------------------------------------------------------------------
    # contiguous mode (legacy behavior, unchanged)
    # ------------------------------------------------------------------

    def _fill_slots(self):
        for i in range(self.num_slots):
            if self.slots[i] is None and self.pending:
                req = self.pending.popleft()
                logits, slot_state = self.hooks.prefill(req.prompt[None, :])
                self.state = self.hooks.merge(self.state, slot_state, i)
                tok = int(np.argmax(np.asarray(logits)[0]))
                req.generated.append(tok)
                self.next_tokens[i, 0] = tok
                self.slots[i] = req

    def _step_contiguous(self) -> int:
        self._fill_slots()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        logits, self.state = self.hooks.decode(
            self.state, jnp.asarray(self.next_tokens))
        toks = np.argmax(np.asarray(logits), axis=-1)
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.generated.append(tok)
            self.next_tokens[i, 0] = tok
            if (self.eos_id is not None and tok == self.eos_id) \
                    or len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None
        self.steps_run += 1
        return len(active)

    # ------------------------------------------------------------------
    # paged mode
    # ------------------------------------------------------------------

    def _ensure_block(self, slot: int, bi: int):
        """Make the slot's table cover block index ``bi`` with an
        exclusively-owned block: append a fresh one past the end, or
        copy-on-write a shared one (refcount > 1 means a prefix-index entry
        or another request also reads it)."""
        table = self._tables[slot]
        if bi == len(table):
            table.append(self.block_pool.alloc())
        elif self.block_pool.refs[table[bi]] > 1:
            src = table[bi]
            dst = self.block_pool.alloc()
            self.pool = self.hooks.copy_block(
                self.pool, np.int32(src), np.int32(dst))
            self.block_pool.release(src)
            table[bi] = dst
            self.stats["cow_copies"] += 1

    def _committed_blocks(self) -> int:
        """Blocks running requests will still allocate: the rest of each
        request's footprint (tables grow lazily during prefill/decode) plus
        one COW-copy slack each."""
        bs = self.config.block_size
        tot = 0
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            footprint = blocks_for(len(r.prompt) + r.max_new_tokens, bs)
            tot += max(0, footprint - len(self._tables[i])) + 1
        return tot

    def _admit(self):
        bs = self.config.block_size
        while self.pending:
            slot = next((i for i, r in enumerate(self.slots) if r is None),
                        None)
            if slot is None:
                break
            if self.config.admission == "priority":
                req = max(self.pending, key=lambda r: r.priority)
            else:
                req = self.pending[0]
            p = len(req.prompt)
            reuse_n, reuse_blocks = 0, ()
            if self.prefix is not None:
                reuse_n, reuse_blocks = self.prefix.lookup(req.prompt, p - 1)
            # +2 slack: the partial boundary block and the request's own
            # final block can each need one COW copy beyond the count
            need = (blocks_for(p + req.max_new_tokens, bs)
                    - len(reuse_blocks) + 2)
            deficit = (need - self.block_pool.available()
                       + self._committed_blocks())
            if deficit > 0 and self.prefix is not None and len(self.prefix):
                freed = self.prefix.evict_lru(self.block_pool, deficit)
                if freed:
                    self.stats["prefix_evictions"] += 1
                    self.stats["evicted_blocks"] += freed
                    # eviction may have dropped the entry this request
                    # planned to reuse — re-resolve against the survivors
                    reuse_n, reuse_blocks = self.prefix.lookup(req.prompt,
                                                               p - 1)
                    need = (blocks_for(p + req.max_new_tokens, bs)
                            - len(reuse_blocks) + 2)
            if self.block_pool.available() - self._committed_blocks() < need:
                break  # head-of-line: wait for running requests to free
            self.pending.remove(req)
            for b in reuse_blocks:
                self.block_pool.retain(b)
            self.slots[slot] = req
            self._tables[slot] = list(reuse_blocks)
            self._pos[slot] = reuse_n
            self._prefilling[slot] = True
            self.next_tokens[slot, 0] = 0
            if reuse_n:
                self.stats["prefix_hits"] += 1
                self.stats["reused_tokens"] += reuse_n

    def _finish(self, i: int):
        req = self.slots[i]
        req.done = True
        for bid in self._tables[i]:
            self.block_pool.release(bid)
        self._tables[i] = []
        self._pos[i] = 0
        self._prefilling[i] = False
        self.next_tokens[i, 0] = 0
        self.slots[i] = None

    def _table_row(self, i: int) -> np.ndarray:
        row = np.zeros((1, self.config.max_blocks_per_seq), np.int32)
        t = self._tables[i]
        row[0, :len(t)] = t
        return row

    def _prefill_tick(self) -> int:
        """Spend up to ``chunk_tokens`` of prefill budget across prefilling
        slots; requests whose prompt completes sample their first token."""
        budget = self.config.chunk_tokens
        bs = self.config.block_size
        total = 0
        for i in range(self.num_slots):
            if budget <= 0:
                break
            req = self.slots[i]
            if req is None or not self._prefilling[i]:
                continue
            pos = int(self._pos[i])
            p = len(req.prompt)
            c = min(budget, p - pos)
            for bi in range(pos // bs, (pos + c - 1) // bs + 1):
                self._ensure_block(i, bi)
            toks = jnp.asarray(
                np.asarray(req.prompt[pos:pos + c], np.int32))[None, :]
            logits, self.pool = self.hooks.prefill_chunk(
                self.pool, jnp.asarray(self._table_row(i)), toks,
                np.int32(pos))
            pos += c
            self._pos[i] = pos
            budget -= c
            total += c
            if pos == p:
                self._prefilling[i] = False
                if self.prefix is not None:
                    self.prefix.register(np.asarray(req.prompt, np.int32),
                                         self._tables[i], bs, self.block_pool)
                tok = int(np.argmax(np.asarray(logits)[0]))
                req.generated.append(tok)
                self.next_tokens[i, 0] = tok
                if (self.eos_id is not None and tok == self.eos_id) \
                        or len(req.generated) >= req.max_new_tokens:
                    self._finish(i)
        self.stats["prefill_tokens"] += total
        return total

    def _decode_tick(self) -> int:
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and not self._prefilling[i]]
        if not active:
            return 0
        bs = self.config.block_size
        for i in active:
            # the incoming token writes at position _pos[i]
            self._ensure_block(i, int(self._pos[i]) // bs)
        m = self.config.max_blocks_per_seq
        tables = np.zeros((self.num_slots, m), np.int32)
        lens = np.zeros(self.num_slots, np.int32)
        toks = np.zeros((self.num_slots, 1), np.int32)
        for i in active:
            t = self._tables[i]
            tables[i, :len(t)] = t
            lens[i] = self._pos[i]
            toks[i, 0] = self.next_tokens[i, 0]
        # inactive rows stay all-null (block 0) / len 0 / token 0: their
        # writes land in the null block, which is never read unmasked
        logits, self.pool = self.hooks.decode(
            self.pool, jnp.asarray(tables), jnp.asarray(lens),
            jnp.asarray(toks))
        out = np.argmax(np.asarray(logits), axis=-1)
        for i in active:
            req = self.slots[i]
            tok = int(out[i])
            req.generated.append(tok)
            self._pos[i] += 1
            self.next_tokens[i, 0] = tok
            if (self.eos_id is not None and tok == self.eos_id) \
                    or len(req.generated) >= req.max_new_tokens:
                self._finish(i)
        self.steps_run += 1
        return len(active)

    def _step_paged(self) -> int:
        self._admit()
        pre = self._prefill_tick()
        n = self._decode_tick()
        prefilling = int(np.sum(self._prefilling))
        self.tick_log.append({"decoded": n, "prefill_tokens": pre,
                              "prefilling": prefilling})
        if n == 0 and pre == 0 and self.pending \
                and all(r is None for r in self.slots):
            raise PoolExhausted(
                "admission deadlock: pending requests cannot fit the block "
                "pool even after LRU prefix eviction, and no running "
                "request can free blocks — size num_blocks for "
                "num_slots * max_len")
        return n + prefilling

    def release_prefix_cache(self):
        """Drop every prefix-index entry, releasing its block references;
        blocks unreferenced by live requests return to the free list."""
        if self.config.mode == "paged" and self.prefix is not None:
            self.prefix.drop(self.block_pool)

    def step(self) -> int:
        """One scheduler tick.  Returns the number of slots that made
        progress (decoded or still prefilling) — 0 means idle."""
        if self.config.mode == "paged":
            return self._step_paged()
        return self._step_contiguous()

    # -- checkpointability: the docstring claim, made mechanical ----------

    @staticmethod
    def _pack(r: Request) -> dict:
        return {"uid": int(r.uid),
                "prompt": np.asarray(r.prompt, np.int32).copy(),
                "max_new_tokens": int(r.max_new_tokens),
                "generated": np.asarray(r.generated, np.int32),
                "done": bool(r.done),
                "priority": int(r.priority)}

    @staticmethod
    def _unpack(d: dict) -> Request:
        return Request(uid=int(d["uid"]),
                       prompt=np.asarray(d["prompt"], np.int32),
                       max_new_tokens=int(d["max_new_tokens"]),
                       generated=[int(t) for t in
                                  np.asarray(d["generated"]).ravel()],
                       done=bool(d["done"]),
                       priority=int(d.get("priority", 0)))

    def snapshot(self) -> dict:
        """Host-side copy of the full scheduler state (a pytree of numpy
        arrays, ints and bools — msgpack/np.save-friendly, so it rides
        ``repro.ckpt.save_checkpoint`` as-is).  Paged mode extends the
        legacy format with the pool tensor, block accounting, per-slot
        tables and the prefix index."""
        eos_enc = -1 if self.eos_id is None else int(self.eos_id)
        base = {
            "num_slots": int(self.num_slots),
            "eos_id": eos_enc,
            "steps_run": int(self.steps_run),
            "next_tokens": np.asarray(self.next_tokens).copy(),
            # slot occupancy: pack occupied slots with their index so the
            # pytree has no None leaves (None is a structure change)
            "slot_idx": np.asarray(
                [i for i, r in enumerate(self.slots) if r is not None],
                np.int32),
            "slot_reqs": [self._pack(r) for r in self.slots if r is not None],
            "pending": [self._pack(r) for r in self.pending],
        }
        if self.config.mode == "contiguous":
            base["state"] = jax.tree.map(np.asarray, self.state)
            return base
        c = self.config
        for req, i in zip(base["slot_reqs"], base["slot_idx"]):
            req["table"] = np.asarray(self._tables[int(i)], np.int32)
            req["pos"] = int(self._pos[int(i)])
            req["prefilling"] = bool(self._prefilling[int(i)])
        base["serve"] = {
            "max_len": int(c.max_len),
            "block_size": int(c.block_size),
            "num_blocks": int(c.resolved_num_blocks),
            "prefill_chunk": int(c.chunk_tokens),
            "prefix_sharing": int(c.prefix_sharing),
            "admission_priority": int(c.admission == "priority"),
            # 0 = unset, else 1 + index into _KERNEL_BACKENDS (ints only:
            # string leaves break the checkpoint layer's jax tree mapping)
            "kernel_backend": (0 if c.kernel_backend is None else
                               1 + _KERNEL_BACKENDS.index(c.kernel_backend)),
        }
        from repro.kernels import ops as kops
        # tune-cache decisions carry None/str values, which jax pytree
        # flattening would drop/mangle — ride as JSON bytes instead
        base["tune_cache"] = np.frombuffer(
            json.dumps(kops.tune_cache_snapshot()).encode(), np.uint8).copy()
        base["pool"] = jax.tree.map(np.asarray, self.pool)
        base["block_pool"] = self.block_pool.snapshot()
        base["prefix"] = (self.prefix.snapshot() if self.prefix is not None
                          else {"tokens": [], "blocks": []})
        return base

    @classmethod
    def restore(cls, snap: dict, prefill_fn: Optional[Callable] = None,
                decode_fn: Optional[Callable] = None,
                merge_fn: Optional[Callable] = None, *,
                hooks: Optional[EngineHooks] = None) -> "BatchScheduler":
        """Rebuild a scheduler from ``snapshot()`` output; the continued
        decode stream is identical to the uninterrupted one (the hooks are
        stateless — only the snapshot carries state).  Contiguous snapshots
        accept the legacy positional callables; paged snapshots need
        ``hooks=`` (decode / prefill_chunk / copy_block)."""
        eos = int(snap["eos_id"])
        eos = None if eos == -1 else eos
        if "pool" in snap:
            if hooks is None:
                raise ValueError("restoring a paged snapshot requires "
                                 "hooks=EngineHooks(...)")
            s = snap["serve"]
            kbi = int(s.get("kernel_backend", 0))  # 0 on pre-PR-9 snapshots
            kb = None if kbi == 0 else _KERNEL_BACKENDS[kbi - 1]
            config = ServeConfig(
                num_slots=int(snap["num_slots"]), eos_id=eos, mode="paged",
                max_len=int(s["max_len"]), block_size=int(s["block_size"]),
                num_blocks=int(s["num_blocks"]),
                prefill_chunk=int(s["prefill_chunk"]),
                cache_dtype=str(np.asarray(snap["pool"]["k"]).dtype),
                prefix_sharing=bool(int(s["prefix_sharing"])),
                admission=("priority" if int(s["admission_priority"])
                           else "fifo"),
                kernel_backend=kb)
            tc = snap.get("tune_cache")
            if tc is not None and np.asarray(tc).size:
                from repro.kernels import ops as kops
                n = kops.load_tune_cache(json.loads(
                    np.asarray(tc, np.uint8).tobytes().decode()))
                if n:
                    print(f"[serve] restored {n} tune-cache decision(s) "
                          f"from snapshot")
            hooks = dataclasses.replace(
                hooks, init_state=jax.tree.map(jnp.asarray, snap["pool"]))
            sched = cls(config, hooks)
            sched.block_pool = BlockPool.restore(snap["block_pool"])
            if config.prefix_sharing:
                sched.prefix = PrefixIndex.restore(snap["prefix"])
            for i, rd in zip(np.asarray(snap["slot_idx"]).ravel(),
                             snap["slot_reqs"]):
                i = int(i)
                sched.slots[i] = cls._unpack(rd)
                sched._tables[i] = [int(b) for b in
                                    np.asarray(rd["table"]).ravel()]
                sched._pos[i] = int(rd["pos"])
                sched._prefilling[i] = bool(rd["prefilling"])
        else:
            if hooks is None:
                hooks = EngineHooks(prefill=prefill_fn, decode=decode_fn,
                                    merge=merge_fn)
            config = ServeConfig(num_slots=int(snap["num_slots"]),
                                 eos_id=eos, mode="contiguous")
            hooks = dataclasses.replace(
                hooks, init_state=jax.tree.map(jnp.asarray, snap["state"]))
            sched = cls(config, hooks)
            for i, rd in zip(np.asarray(snap["slot_idx"]).ravel(),
                             snap["slot_reqs"]):
                sched.slots[int(i)] = cls._unpack(rd)
        sched.steps_run = int(snap["steps_run"])
        sched.next_tokens = np.asarray(snap["next_tokens"], np.int32).copy()
        for rd in snap["pending"]:
            sched.pending.append(cls._unpack(rd))
        return sched

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen: Dict[int, Request] = {}
        for _ in range(max_steps):
            for r in list(self.slots) + list(self.pending):
                if r is not None:
                    seen[r.uid] = r
            if self.step() == 0 and not self.pending:
                break
        for r in seen.values():
            if r.done:
                finished.append(r)
        return finished
