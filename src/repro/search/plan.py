"""BitPlan: the persisted artifact of a bitwidth sensitivity sweep.

A plan assigns one (I, F) fixed-point format to each contiguous
layer-group of the stack, together with the probe evidence that led to
the choice (per-group probe loss, the f32 baseline, and whether the
loss-delta target was met).  Plans serialize to JSON so a searched
configuration can be committed, diffed, and loaded back into a
``BitSchedule`` for training or exported to the serving int8 path
(``repro.search.export``).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Sequence, Tuple

from repro.quant.fixed_point import BitSchedule, schedule_from_formats

PLAN_SCHEMA = 1


def layer_groups(num_layers: int, num_groups: int) -> Tuple[Tuple[int, ...], ...]:
    """Partition ``range(num_layers)`` into ``num_groups`` contiguous groups.

    ``num_groups <= 0`` means one group per layer.  Remainder layers go to
    the later groups (the paper widens formats toward the output side, so
    the tail groups being slightly larger is the conservative split).
    """
    if num_layers <= 0:
        raise ValueError("num_layers must be positive")
    if num_groups <= 0 or num_groups > num_layers:
        num_groups = num_layers
    base, rem = divmod(num_layers, num_groups)
    groups, start = [], 0
    for g in range(num_groups):
        size = base + (1 if g >= num_groups - rem else 0)
        groups.append(tuple(range(start, start + size)))
        start += size
    return tuple(groups)


@dataclasses.dataclass(frozen=True)
class GroupChoice:
    """The selected format for one contiguous layer-group."""

    group: int
    layers: Tuple[int, ...]
    i_bits: int
    f_bits: int
    probe_loss: float
    met_target: bool

    @property
    def bitwidth(self) -> int:
        return self.i_bits + self.f_bits + 1


@dataclasses.dataclass(frozen=True)
class BitPlan:
    """Per-layer-group (I,F) selection with its probe evidence.

    ``groups`` partitions ``range(num_layers)``; ``grid`` is the candidate
    ladder the sweep searched (ascending bitwidth); ``final_loss`` is the
    probe loss of the assembled plan (all groups at their chosen format at
    once), which is the number the acceptance target is judged against.
    """

    num_layers: int
    groups: Tuple[GroupChoice, ...]
    baseline_loss: float
    final_loss: float
    target: float
    seed: int
    grid: Tuple[Tuple[int, int], ...]
    probe_steps: int
    probes: int = 0  # number of probe trainings the sweep ran

    def __post_init__(self):
        covered = sorted(l for g in self.groups for l in g.layers)
        if covered != list(range(self.num_layers)):
            raise ValueError(
                f"plan groups {covered} do not partition "
                f"range({self.num_layers})")

    @property
    def met_target(self) -> bool:
        return self.final_loss <= self.baseline_loss + self.target

    def formats(self) -> Tuple[Tuple[int, int], ...]:
        """Per-layer (I, F), expanded from the group choices."""
        fmt = [None] * self.num_layers
        for g in self.groups:
            for layer in g.layers:
                fmt[layer] = (g.i_bits, g.f_bits)
        return tuple(fmt)

    def to_bit_schedule(self, *, enabled: bool = True) -> BitSchedule:
        return schedule_from_formats(self.formats(), enabled=enabled)

    def describe(self) -> str:
        parts = ", ".join(
            f"L{g.layers[0]}-{g.layers[-1]}:({g.i_bits},{g.f_bits})"
            for g in self.groups)
        return (f"{parts} | baseline {self.baseline_loss:.4f} "
                f"final {self.final_loss:.4f} target +{self.target:.3f} "
                f"met={self.met_target}")

    # -- JSON round-trip ----------------------------------------------------

    def to_json(self) -> dict:
        return {
            "schema": PLAN_SCHEMA,
            "num_layers": self.num_layers,
            "baseline_loss": self.baseline_loss,
            "final_loss": self.final_loss,
            "target": self.target,
            "seed": self.seed,
            "grid": [list(p) for p in self.grid],
            "probe_steps": self.probe_steps,
            "probes": self.probes,
            "met_target": self.met_target,
            "groups": [
                {
                    "group": g.group,
                    "layers": list(g.layers),
                    "i_bits": g.i_bits,
                    "f_bits": g.f_bits,
                    "probe_loss": g.probe_loss,
                    "met_target": g.met_target,
                }
                for g in self.groups
            ],
        }

    @classmethod
    def from_json(cls, obj: dict) -> "BitPlan":
        schema = obj.get("schema", 1)
        if schema != PLAN_SCHEMA:
            raise ValueError(f"unknown BitPlan schema {schema}")
        groups = tuple(
            GroupChoice(
                group=int(g["group"]),
                layers=tuple(int(x) for x in g["layers"]),
                i_bits=int(g["i_bits"]),
                f_bits=int(g["f_bits"]),
                probe_loss=float(g["probe_loss"]),
                met_target=bool(g["met_target"]),
            )
            for g in obj["groups"]
        )
        return cls(
            num_layers=int(obj["num_layers"]),
            groups=groups,
            baseline_loss=float(obj["baseline_loss"]),
            final_loss=float(obj["final_loss"]),
            target=float(obj["target"]),
            seed=int(obj["seed"]),
            grid=tuple((int(p[0]), int(p[1])) for p in obj["grid"]),
            probe_steps=int(obj["probe_steps"]),
            probes=int(obj.get("probes", 0)),
        )

    def save(self, path: str) -> None:
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "BitPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))


def plan_from_formats(
    formats: Sequence[Tuple[int, int]],
    *,
    baseline_loss: float = 0.0,
    final_loss: float = 0.0,
    target: float = 0.0,
    seed: int = 0,
    probe_steps: int = 0,
) -> BitPlan:
    """Wrap an explicit per-layer format list as a (one-layer-per-group)
    plan — handy for exporting hand-picked schedules like Table I."""
    groups = tuple(
        GroupChoice(group=k, layers=(k,), i_bits=int(i), f_bits=int(f),
                    probe_loss=final_loss, met_target=True)
        for k, (i, f) in enumerate(formats)
    )
    return BitPlan(
        num_layers=len(groups), groups=groups, baseline_loss=baseline_loss,
        final_loss=final_loss, target=target, seed=seed,
        grid=tuple((int(i), int(f)) for i, f in formats),
        probe_steps=probe_steps,
    )
