"""Bitwidth search: per-layer (I,F) sensitivity sweeps, QAT annealing
schedules, and the train -> serve int8 export path.

The subsystem has four parts:

* ``plan``        — ``BitPlan``: the per-layer-group (I,F) artifact a
                    sweep produces (JSON round-trippable, loadable back
                    into a ``BitSchedule``).
* ``sensitivity`` — short seeded training probes per layer-group over a
                    candidate (I,F) grid; picks the minimal format per
                    group meeting a loss-delta target.
* ``anneal``      — step-indexed F-bit ramps (``"0:16,200:12,400:10"``)
                    threaded through ``StepOptions``/``QuantPolicy`` as
                    runtime data, so one compiled step serves the whole
                    ramp and checkpoint resume is bitwise exact.
* ``export``      — converts a trained plan into the serving engine's
                    int8 configuration and proves train-time quant
                    matches the serving KV/prologue numerics bit-for-bit.

``sensitivity`` and ``export`` pull in the training/serving stacks, so
they are loaded lazily — importing ``repro.search`` alone stays cheap
(and keeps ``core.steps`` -> ``search.anneal`` import-cycle free).
"""
from repro.search.anneal import AnnealSchedule
from repro.search.plan import BitPlan, GroupChoice, layer_groups

__all__ = [
    "AnnealSchedule",
    "BitPlan",
    "GroupChoice",
    "layer_groups",
    "sensitivity",
    "export",
]

_LAZY_SUBMODULES = ("sensitivity", "export")


def __getattr__(name: str):
    if name in _LAZY_SUBMODULES:
        import importlib

        return importlib.import_module(f"repro.search.{name}")
    raise AttributeError(f"module 'repro.search' has no attribute {name!r}")
