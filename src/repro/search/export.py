"""Train -> serve export: turn a searched ``BitPlan`` into the serving
engine's int8 configuration, with a provable numerics contract.

The contract has three parts, each checked bit-for-bit by
``verify_train_serve_parity`` (and drilled in tests/test_bit_search.py):

1. **Grid embedding** — a train-time (I,F) format with bitwidth <= 8
   embeds into int8 *exactly*: payload is the fixed-point integer ``k``,
   scale is ``2^-F``, so ``dequantize(quantize_int8_fxp(x_q)) == x_q``
   for any ``x_q`` already on the (I,F) grid.  Wider formats keep their
   8 MSBs: the serve-side value equals train-time quantization at the
   effective format ``(I, F - shift)`` — the precision loss is exactly
   "drop ``shift`` low fractional bits", nothing else.
2. **KV cache** — the per-token absmax rule used by the paged int8 pool
   (``serving.engine.quant_kv_rows``) is restated here
   (``kv_reference``) and held bitwise equal, so the exported config
   documents precisely what the serving cache stores.
3. **Decode prologue** — the fused int8 decode prologue consumes
   weights quantized by the rule exported here
   (``export_prologue_weights``): ``decode_prologue`` under the int8
   backend is bitwise equal to the reference path fed those exported
   payloads.

Everything downstream of a ``ServeQuantPlan`` is therefore explainable
in train-time terms: no hidden requantization between the two stacks.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.quant.fixed_point import quantize
from repro.quant.int8 import (dequantize_int8, int8_spec,
                              quantize_int8_absmax, quantize_int8_fxp,
                              transport_bits)
from repro.search.plan import BitPlan

SERVE_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class LayerQuant:
    """One layer's serve-side quantization: either the exact (I,F) grid
    ("fxp", bitwidth <= 8) or dynamic per-tensor absmax ("absmax")."""

    layer: int
    i_bits: int
    f_bits: int
    mode: str          # "fxp" | "absmax"
    scale: float       # int8 scale for fxp mode (2^(shift-F))
    qmin: int
    qmax: int
    shift: int         # dropped low fractional bits (0 = exact embedding)

    @property
    def exact(self) -> bool:
        return self.shift == 0

    @property
    def eff_f_bits(self) -> int:
        """Fractional bits that survive the int8 embedding."""
        return self.f_bits - self.shift


@dataclasses.dataclass(frozen=True)
class ServeQuantPlan:
    """The serving-side rendering of a trained ``BitPlan``."""

    layers: Tuple[LayerQuant, ...]
    cache_dtype: str = "int8"      # ServeConfig.cache_dtype
    kernel_backend: str = "int8"   # kernel datapath for the prologue

    def serve_config_kwargs(self) -> dict:
        """kwargs to splat into ``serving.ServeConfig``."""
        return {"cache_dtype": jnp.int8}

    def to_json(self) -> dict:
        return {
            "schema": SERVE_SCHEMA,
            "cache_dtype": self.cache_dtype,
            "kernel_backend": self.kernel_backend,
            "kv_rule": "per-token absmax: scale=max(|row|,1e-8)/127, "
                       "payload=clip(round(x/scale),-127,127)",
            "layers": [dataclasses.asdict(lq) for lq in self.layers],
        }

    @classmethod
    def from_json(cls, obj: dict) -> "ServeQuantPlan":
        if obj.get("schema", 1) != SERVE_SCHEMA:
            raise ValueError(f"unknown ServeQuantPlan schema {obj.get('schema')}")
        layers = tuple(
            LayerQuant(layer=int(l["layer"]), i_bits=int(l["i_bits"]),
                       f_bits=int(l["f_bits"]), mode=str(l["mode"]),
                       scale=float(l["scale"]), qmin=int(l["qmin"]),
                       qmax=int(l["qmax"]), shift=int(l["shift"]))
            for l in obj["layers"])
        return cls(layers=layers, cache_dtype=str(obj["cache_dtype"]),
                   kernel_backend=str(obj["kernel_backend"]))


def to_serve_plan(plan: BitPlan) -> ServeQuantPlan:
    """Render each layer's trained (I,F) format as its int8 serving rule."""
    layers = []
    for idx, (i_b, f_b) in enumerate(plan.formats()):
        if i_b > 7:
            raise ValueError(
                f"layer {idx} format ({i_b},{f_b}): I > 7 cannot keep its "
                f"MSBs in int8 (effective F would be negative)")
        spec = int8_spec(i_b, f_b)
        mode = "fxp" if transport_bits((i_b, f_b)) is not None else "absmax"
        layers.append(LayerQuant(
            layer=idx, i_bits=i_b, f_bits=f_b, mode=mode, scale=spec.scale,
            qmin=spec.qmin, qmax=spec.qmax, shift=spec.shift))
    return ServeQuantPlan(layers=tuple(layers))


def save_serve_plan(sp: ServeQuantPlan, path: str) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(sp.to_json(), f, indent=2, sort_keys=True)
        f.write("\n")


def load_serve_plan(path: str) -> ServeQuantPlan:
    with open(path) as f:
        return ServeQuantPlan.from_json(json.load(f))


# ---------------------------------------------------------------------------
# The exported numerics rules (restated independently of the engine)
# ---------------------------------------------------------------------------

def kv_reference(x):
    """The exported KV-cache rule — must stay bitwise equal to
    ``serving.engine.quant_kv_rows`` (enforced by the conformance suite)."""
    xf = jnp.asarray(x).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(1, 2))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[:, None, None]), -127, 127)
    return q.astype(jnp.int8), scale


def export_prologue_weights(attn_params: dict):
    """The exported decode-prologue weight rule: per-tensor absmax int8 on
    the 2D-reshaped QKV projections, scales stacked [1, 3] — exactly what
    ``kernels.decode_prologue`` computes internally under the int8 backend.

    Returns ``(qwq, qwk, qwv, wscales)`` ready for ``DP._ref_int8``.
    """
    wq, wk, wv = attn_params["wq"], attn_params["wk"], attn_params["wv"]
    d, h, hd = wq.shape
    hkv = wk.shape[1]
    qwq, swq = quantize_int8_absmax(wq.reshape(d, h * hd))
    qwk, swk = quantize_int8_absmax(wk.reshape(d, hkv * hd))
    qwv, swv = quantize_int8_absmax(wv.reshape(d, hkv * hd))
    return qwq, qwk, qwv, jnp.stack([swq, swk, swv]).reshape(1, 3)


def serve_layer_quant(x, lq: LayerQuant):
    """Apply one exported layer rule to a tensor: (payload, scale)."""
    if lq.mode == "fxp":
        return quantize_int8_fxp(x, lq.i_bits, lq.f_bits)
    return quantize_int8_absmax(x)


# ---------------------------------------------------------------------------
# The conformance checks
# ---------------------------------------------------------------------------

def check_grid_embedding(plan: BitPlan, key=None) -> dict:
    """Part 1 of the contract, per layer of the plan.

    For tensors already on the train-time (I,F) grid, the serve-side
    dequantized value must equal train-time quantization at the effective
    format (I, F - shift) bitwise — and the tensor itself when the format
    embeds exactly (bitwidth <= 8).
    """
    key = key if key is not None else jax.random.key(0)
    max_diff_msb = 0.0
    max_diff_exact = 0.0
    for idx, (i_b, f_b) in enumerate(plan.formats()):
        spec = int8_spec(i_b, f_b)
        k = jax.random.fold_in(key, idx)
        # span the representable range including saturation edges
        x = jax.random.uniform(k, (512,), jnp.float32,
                               -1.5 * 2.0 ** i_b, 1.5 * 2.0 ** i_b)
        x_q = quantize(x, i_b, f_b)
        payload, scale = quantize_int8_fxp(x_q, i_b, f_b)
        deq = dequantize_int8(payload, scale)
        want = quantize(x_q, i_b, f_b - spec.shift)
        max_diff_msb = max(max_diff_msb,
                           float(jnp.max(jnp.abs(deq - want))))
        if spec.exact:
            max_diff_exact = max(max_diff_exact,
                                 float(jnp.max(jnp.abs(deq - x_q))))
    return {"grid_msb_max_diff": max_diff_msb,
            "grid_exact_max_diff": max_diff_exact,
            "ok": max_diff_msb == 0.0 and max_diff_exact == 0.0}


def check_kv_parity(key=None, rows: int = 64, heads: int = 4,
                    head_dim: int = 16) -> dict:
    """Part 2: exported KV rule == the engine's, payloads and scales."""
    from repro.serving import engine

    key = key if key is not None else jax.random.key(1)
    x = 3.0 * jax.random.normal(key, (rows, heads, head_dim), jnp.float32)
    q_eng, s_eng = engine.quant_kv_rows(x)
    q_exp, s_exp = kv_reference(x)
    payload_diff = int(jnp.max(jnp.abs(
        q_eng.astype(jnp.int32) - q_exp.astype(jnp.int32))))
    scale_diff = float(jnp.max(jnp.abs(s_eng - s_exp)))
    return {"kv_payload_max_diff": payload_diff,
            "kv_scale_max_diff": scale_diff,
            "ok": payload_diff == 0 and scale_diff == 0.0}


def check_prologue_parity(key=None) -> dict:
    """Part 3: ``decode_prologue`` under the int8 backend == the reference
    int8 path fed weights quantized by the exported rule, bitwise."""
    from repro.kernels import decode_prologue as DP
    from repro.kernels import ops as kops
    from repro.models.config import ModelConfig

    key = key if key is not None else jax.random.key(2)
    cfg = ModelConfig(name="bit-export-parity", family="dense", num_layers=1,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=64, compute_dtype="float32")
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    norm = {"scale": 1.0 + 0.1 * jax.random.normal(ks[0], (d,), jnp.float32)}
    attn = {"wq": jax.random.normal(ks[1], (d, h, hd), jnp.float32) * 0.1,
            "wk": jax.random.normal(ks[2], (d, hkv, hd), jnp.float32) * 0.1,
            "wv": jax.random.normal(ks[3], (d, hkv, hd), jnp.float32) * 0.1}
    x = jax.random.normal(ks[4], (3, 1, d), jnp.float32)
    pos = jnp.array([0, 5, 17], jnp.int32)

    qwq, qwk, qwv, wscales = export_prologue_weights(attn)
    stat = dict(use_rope=bool(cfg.use_rope), theta=float(cfg.rope_theta),
                eps=float(cfg.norm_eps), h=h, hkv=hkv, hd=hd)
    ref = jax.jit(lambda xx: DP._ref_int8(
        xx[:, 0, :], norm["scale"].reshape(1, d), qwq, qwk, qwv, wscales,
        None, pos, **stat))
    want = ref(x)

    with kops.kernel_backend_ctx("int8"):
        got = jax.jit(
            lambda xx: DP.decode_prologue(norm, attn, xx, cfg, pos))(x)

    diffs = [float(jnp.max(jnp.abs(g[:, 0] - w)))
             for g, w in zip(got, want)]
    return {"prologue_max_diff": max(diffs), "ok": max(diffs) == 0.0}


def verify_train_serve_parity(plan: BitPlan, key=None) -> dict:
    """Run all three conformance checks; ``result['ok']`` is the verdict."""
    key = key if key is not None else jax.random.key(plan.seed)
    out = {}
    out.update(check_grid_embedding(plan, jax.random.fold_in(key, 0)))
    grid_ok = out.pop("ok")
    out.update(check_kv_parity(jax.random.fold_in(key, 1)))
    kv_ok = out.pop("ok")
    out.update(check_prologue_parity(jax.random.fold_in(key, 2)))
    prologue_ok = out.pop("ok")
    out["grid_ok"] = grid_ok
    out["kv_ok"] = kv_ok
    out["prologue_ok"] = prologue_ok
    out["ok"] = grid_ok and kv_ok and prologue_ok
    return out


def assert_parity(plan: BitPlan, key=None) -> dict:
    res = verify_train_serve_parity(plan, key)
    if not res["ok"]:
        raise AssertionError(f"train<->serve parity violated: {res}")
    return res
