"""Progressive bitwidth annealing: step-indexed F-bit ramps for QAT.

Grammar
-------
A schedule is a comma-separated list of ``step:value`` milestones::

    "0:off,100:16,400:12"

* ``step`` — global training step the milestone takes effect (ascending,
  the first milestone must be step 0).
* ``value`` — either ``off`` (quantization disabled until the next
  milestone) or an integer F-bit **floor**: every layer's fractional
  bits become ``max(schedule_F, value)`` for all three tensor classes.

So the example trains full-precision for 100 steps, then quantized with
at least 16 fractional bits, and from step 400 on at the underlying
per-layer schedule (floored at 12).  Ramps descend from wide formats to
the target schedule — the standard QAT recipe of easing into
low-precision arithmetic instead of starting there.

Why this composes with everything
---------------------------------
``apply`` is pure traced arithmetic on the ``BitSchedule`` pytree and
the (traced) step counter: bits stay runtime data, so one compiled train
step serves the entire ramp (no recompiles at milestones), the annealed
bits flow unchanged through the pipeline/overlap/stochastic-rounding
paths, and resume from a checkpoint at step N continues the ramp
bitwise — the effective bits are a pure function of the step, which is
restored with the checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.quant.fixed_point import BitSchedule

# Fractional-bit floors above this would push I+F past the exact-pow2
# range of the fixed-point emulation (see quant.fixed_point._pow2_int).
_MAX_F_FLOOR = 24

_OFF = -1  # milestone value meaning "quantization disabled"


@dataclasses.dataclass(frozen=True)
class AnnealSchedule:
    """Parsed, validated annealing schedule (hashable, jit-friendly)."""

    milestones: Tuple[Tuple[int, int], ...]  # (step, f_floor) with -1 = off

    @classmethod
    def parse(cls, spec: str) -> "AnnealSchedule":
        if isinstance(spec, AnnealSchedule):
            return spec
        if not isinstance(spec, str) or not spec.strip():
            raise ValueError(f"empty anneal spec: {spec!r}")
        milestones = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            try:
                step_s, val_s = part.split(":")
                step = int(step_s)
            except ValueError:
                raise ValueError(
                    f"bad anneal milestone {part!r} (want 'STEP:FBITS' or "
                    f"'STEP:off') in spec {spec!r}") from None
            val_s = val_s.strip().lower()
            if val_s == "off":
                val = _OFF
            else:
                try:
                    val = int(val_s)
                except ValueError:
                    raise ValueError(
                        f"bad anneal value {val_s!r} in spec {spec!r}") from None
                if not 0 <= val <= _MAX_F_FLOOR:
                    raise ValueError(
                        f"anneal F floor {val} out of range [0, {_MAX_F_FLOOR}]"
                        f" in spec {spec!r}")
            if step < 0:
                raise ValueError(f"negative milestone step in spec {spec!r}")
            milestones.append((step, val))
        if not milestones:
            raise ValueError(f"no milestones in anneal spec {spec!r}")
        if milestones[0][0] != 0:
            raise ValueError(
                f"first anneal milestone must be step 0, got "
                f"{milestones[0][0]} in spec {spec!r}")
        steps = [m[0] for m in milestones]
        if steps != sorted(set(steps)):
            raise ValueError(f"anneal milestones must strictly ascend: {spec!r}")
        return cls(milestones=tuple(milestones))

    @property
    def spec(self) -> str:
        """Canonical spec string (round-trips through ``parse``)."""
        return ",".join(
            f"{s}:{'off' if v == _OFF else v}" for s, v in self.milestones)

    @property
    def final_step(self) -> int:
        return self.milestones[-1][0]

    def f_floor_at(self, step: int) -> int:
        """Static (Python int) lookup — for logging / tests."""
        val = self.milestones[0][1]
        for s, v in self.milestones:
            if step >= s:
                val = v
        return val

    def apply(self, bits: BitSchedule, step) -> BitSchedule:
        """Annealed view of ``bits`` at ``step`` (traced; no recompiles)."""
        steps = jnp.asarray([m[0] for m in self.milestones], jnp.int32)
        floors = jnp.asarray(
            [max(m[1], 0) for m in self.milestones], jnp.int32)
        on = jnp.asarray(
            [0.0 if m[1] == _OFF else 1.0 for m in self.milestones],
            jnp.float32)
        s = jnp.asarray(step, jnp.int32)
        idx = jnp.clip(jnp.sum((s >= steps).astype(jnp.int32)) - 1,
                       0, len(self.milestones) - 1)
        floor = floors[idx]
        return dataclasses.replace(
            bits,
            w_f=jnp.maximum(bits.w_f, floor),
            a_f=jnp.maximum(bits.a_f, floor),
            g_f=jnp.maximum(bits.g_f, floor),
            enabled=bits.enabled * on[idx],
        )

    def apply_tree(self, bits, step):
        """Apply to a dict of schedules (the ``bits`` arg of a train step)."""
        if isinstance(bits, BitSchedule):
            return self.apply(bits, step)
        return {k: self.apply(v, step) for k, v in bits.items()}

    def describe(self) -> str:
        return f"anneal[{self.spec}]"
