"""Per-layer (I,F) bitwidth sensitivity sweep via short seeded probes.

The sweep answers the question ROADMAP item 4 poses: *which* per-layer
format does a model actually need?  For each contiguous layer-group it
trains short probes over an ascending candidate grid — all other groups
pinned at a wide safe format — and picks the narrowest candidate whose
probe loss lands within ``target`` of the f32 baseline.  The assembled
plan is then probed once end-to-end and escalated (narrowest group
widened one grid step at a time) until it meets the target too.

Cost model: because every quantizer in ``quant.fixed_point`` takes its
bitwidths as *traced* data, the whole sweep — baseline, every candidate,
every escalation round — reuses ONE compiled train step.  A sweep is
``(groups x grid + 2 + escalations)`` short trainings with a single
compile, not a recompile per format.

Determinism: probes consume a precomputed batch list from the
deterministic synthetic dataset, params come from a fixed seed, and
rounding is round-to-nearest-even — the same ``SweepConfig`` always
yields the same ``BitPlan`` (drilled in tests/test_bit_search.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.lenet5 import CONFIG as LENET
from repro.core.steps import (StepOptions, default_bits, init_train_state,
                              make_train_step, num_scan_units)
from repro.core.taxonn import QuantPolicy, backward_stack, forward_stack
from repro.data import SyntheticClassificationDataset, SyntheticLMDataset
from repro.optim import Hyper, OptimizerConfig, apply_update, init_opt_state
from repro.quant.fixed_point import BitSchedule, schedule_from_formats
from repro.search.plan import BitPlan, GroupChoice, layer_groups

# Ascending-bitwidth candidate ladder.  Includes sub-int8 points (bitwidth
# <= 8 exports to serving int8 exactly — see search.export) and the paper's
# Table-I neighborhood at the wide end.
DEFAULT_GRID: Tuple[Tuple[int, int], ...] = (
    (1, 3), (1, 5), (2, 6), (2, 8), (2, 10), (2, 12),
)


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """Knobs of a sensitivity sweep."""

    grid: Tuple[Tuple[int, int], ...] = DEFAULT_GRID
    num_groups: int = 0          # <= 0: one group per layer
    target: float = 0.08         # allowed probe-loss delta vs f32 baseline
    probe_steps: int = 120       # train steps per probe
    batch: int = 128
    lr: float = 0.05
    seed: int = 0
    safe_format: Tuple[int, int] = (4, 16)  # pin for not-under-test groups
    max_escalations: int = 4

    def sorted_grid(self) -> Tuple[Tuple[int, int], ...]:
        return tuple(sorted(self.grid, key=lambda p: (p[0] + p[1], p[1])))


# ---------------------------------------------------------------------------
# LeNet-class probe (mirrors benchmarks/convergence.py, engine primitives)
# ---------------------------------------------------------------------------

def _init_mlp(key, d_in, d_h, d_out, n_hidden):
    ks = jax.random.split(key, 3)
    return {
        "w_in": jax.random.normal(ks[0], (d_in, d_h), jnp.float32) * d_in ** -0.5,
        "hidden": jax.random.normal(
            ks[1], (n_hidden, d_h, d_h), jnp.float32) * d_h ** -0.5,
        "w_out": jax.random.normal(ks[2], (d_h, d_out), jnp.float32) * d_h ** -0.5,
    }


def _make_mlp_step(policy: QuantPolicy, ocfg: OptimizerConfig):
    def body(w, shared, x, b_l):
        return jax.nn.relu(x @ w), jnp.float32(0.0)

    def step(params, opt, batch, hyper, bits):
        x, y = batch

        def in_f(w):
            return jax.nn.relu(x @ w)
        h0, in_vjp = jax.vjp(in_f, params["w_in"])

        h_final, caches, _ = forward_stack(body, params["hidden"], (),
                                           h0, bits, policy)

        def head_f(w, h):
            logits = h @ w
            ls = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(ls, y[:, None], 1))
        loss, head_vjp = jax.vjp(head_f, params["w_out"], h_final)
        d_wout, G = head_vjp(jnp.float32(policy.grad_scale))

        G0, new_hidden, new_opt_h, _, _ = backward_stack(
            body, params["hidden"], (), opt["hidden"], caches, bits, G,
            hyper, policy, ocfg, 0.0)

        (d_win,) = in_vjp(G0)
        inv = 1.0 / policy.grad_scale
        new_win, new_opt_in = apply_update(
            params["w_in"], d_win * inv, opt["w_in"], hyper, ocfg)
        new_wout, new_opt_out = apply_update(
            params["w_out"], d_wout * inv, opt["w_out"], hyper, ocfg)
        return ({"w_in": new_win, "hidden": new_hidden, "w_out": new_wout},
                {"w_in": new_opt_in, "hidden": new_opt_h,
                 "w_out": new_opt_out}, loss)
    return step


def make_lenet_probe(sweep: SweepConfig) -> Tuple[Callable[[BitSchedule], float], int]:
    """Build ``probe(schedule) -> loss`` over the LeNet-class MLP.

    Returns ``(probe, num_layers)``.  The probe closes over one jitted
    step, one param init and one precomputed batch list, so repeated
    calls (the whole sweep) share a single compile and are deterministic
    in the schedule alone.  The probe loss is the mean over the final
    quarter of steps (smoother than the last step, still end-of-probe).
    """
    n_hidden = LENET.num_layers - 2
    ds = SyntheticClassificationDataset(
        input_dim=LENET.input_dim, num_classes=LENET.num_classes,
        n_train=8192, n_test=2048, noise=3.5)
    batches = [
        (jnp.asarray(xb), jnp.asarray(yb))
        for xb, yb in ds.train_batches(sweep.batch, sweep.probe_steps,
                                       sweep.seed)
    ]
    params0 = _init_mlp(jax.random.key(sweep.seed), LENET.input_dim,
                        LENET.hidden, LENET.num_classes, n_hidden)
    ocfg = OptimizerConfig(kind="sgd")
    opt0 = {k: init_opt_state(v, ocfg) for k, v in params0.items()}
    # One quantize-capable policy for every probe: the f32 baseline is the
    # same step with ``enabled=0.0`` in the schedule, so nothing recompiles.
    policy = QuantPolicy(grad_scale=64.0)
    step = jax.jit(_make_mlp_step(policy, ocfg))

    def probe(schedule: BitSchedule) -> float:
        params, opt = params0, opt0
        losses: List[float] = []
        for i, b in enumerate(batches):
            hyper = Hyper(lr=jnp.float32(sweep.lr), step=jnp.int32(i))
            params, opt, loss = step(params, opt, b, hyper, schedule)
            losses.append(float(loss))
        tail = max(1, len(losses) // 4)
        return float(sum(losses[-tail:]) / tail)

    return probe, n_hidden


# ---------------------------------------------------------------------------
# Shared selection loop
# ---------------------------------------------------------------------------

def select_plan(probe: Callable[[BitSchedule], float], num_layers: int,
                sweep: SweepConfig,
                log: Optional[Callable[[str], None]] = None) -> BitPlan:
    """Greedy per-group selection + whole-plan validation/escalation."""
    say = log or (lambda s: None)
    grid = sweep.sorted_grid()
    groups = layer_groups(num_layers, sweep.num_groups)
    probes = 0

    baseline = probe(schedule_from_formats(
        [sweep.safe_format] * num_layers, enabled=False))
    probes += 1
    say(f"baseline loss {baseline:.4f} (target +{sweep.target:.3f})")

    # chosen[g] = index into grid for group g
    chosen: List[int] = []
    records: List[GroupChoice] = []
    for g, layers in enumerate(groups):
        pick, pick_loss, met = len(grid) - 1, float("inf"), False
        for ci, (i_b, f_b) in enumerate(grid):
            fmts = [sweep.safe_format] * num_layers
            for layer in layers:
                fmts[layer] = (i_b, f_b)
            loss = probe(schedule_from_formats(fmts))
            probes += 1
            say(f"  group {g} {layers} ({i_b},{f_b}) -> {loss:.4f}")
            if loss <= baseline + sweep.target:
                pick, pick_loss, met = ci, loss, True
                break
            pick, pick_loss = ci, loss  # fall through to widest
        records.append(GroupChoice(
            group=g, layers=layers, i_bits=grid[pick][0],
            f_bits=grid[pick][1], probe_loss=pick_loss, met_target=met))
        chosen.append(pick)

    def assembled(idx: List[int]):
        fmts = [None] * num_layers
        for g, layers in enumerate(groups):
            for layer in layers:
                fmts[layer] = grid[idx[g]]
        return fmts

    final = probe(schedule_from_formats(assembled(chosen)))
    probes += 1
    say(f"assembled plan loss {final:.4f}")

    # Per-group probes can interact; escalate the narrowest group until
    # the assembled plan itself meets the target (or nothing can widen).
    for _ in range(sweep.max_escalations):
        if final <= baseline + sweep.target:
            break
        widenable = [g for g in range(len(groups))
                     if chosen[g] < len(grid) - 1]
        if not widenable:
            break
        g = min(widenable,
                key=lambda k: (sum(grid[chosen[k]]), -records[k].probe_loss))
        chosen[g] += 1
        say(f"  escalate group {g} -> {grid[chosen[g]]}")
        final = probe(schedule_from_formats(assembled(chosen)))
        probes += 1
        say(f"  plan loss {final:.4f}")

    groups_out = tuple(
        dataclasses.replace(records[g], i_bits=grid[chosen[g]][0],
                            f_bits=grid[chosen[g]][1])
        for g in range(len(groups)))
    return BitPlan(
        num_layers=num_layers, groups=groups_out, baseline_loss=baseline,
        final_loss=final, target=sweep.target, seed=sweep.seed, grid=grid,
        probe_steps=sweep.probe_steps, probes=probes)


def run_sweep(sweep: SweepConfig = SweepConfig(),
              log: Optional[Callable[[str], None]] = None) -> BitPlan:
    """Full sensitivity sweep on the LeNet-class config (the paper's
    workload; used by benchmarks/bitwidth.py and the conformance tests)."""
    probe, n_hidden = make_lenet_probe(sweep)
    return select_plan(probe, n_hidden, sweep, log=log)


# ---------------------------------------------------------------------------
# Sweep over a full transformer config (the --bit-search driver path)
# ---------------------------------------------------------------------------

def run_sweep_lm(cfg, ocfg: Optional[OptimizerConfig] = None,
                 sweep: SweepConfig = SweepConfig(), *, seq_len: int = 64,
                 grad_scale: float = 64.0,
                 log: Optional[Callable[[str], None]] = None) -> BitPlan:
    """Sensitivity sweep over the main block stack of a real model config.

    Probes run through ``make_train_step`` (the TaxoNN engine) with the
    candidate schedule installed on ``bits['blocks']``; any encoder stack
    keeps its default schedule.  Same single-compile property as the
    LeNet sweep: bitwidths are traced data.
    """
    from repro.models import lm

    ocfg = ocfg or OptimizerConfig(kind="sgd")
    policy = QuantPolicy(grad_scale=grad_scale)
    step = jax.jit(make_train_step(cfg, policy, ocfg, StepOptions()))
    n = num_scan_units(cfg)
    base_bits = default_bits(cfg, enabled=True)

    ds = SyntheticLMDataset(cfg.vocab_size, seq_len, sweep.batch,
                            seed=sweep.seed)
    batches = []
    for i in range(sweep.probe_steps):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        bsz = b["tokens"].shape[0]
        if cfg.family == "encdec":
            b["frames"] = jax.random.normal(
                jax.random.fold_in(jax.random.key(2), i),
                (bsz, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            b["patch_embeds"] = jax.random.normal(
                jax.random.fold_in(jax.random.key(3), i),
                (bsz, cfg.num_patches, cfg.d_model), jnp.float32)
        batches.append(b)

    params0 = lm.init_params(jax.random.key(sweep.seed), cfg)
    opt0 = init_train_state(params0, ocfg)

    def probe(schedule: BitSchedule) -> float:
        bits = dict(base_bits)
        bits["blocks"] = schedule
        params, opt = params0, opt0
        losses: List[float] = []
        for i, b in enumerate(batches):
            hyper = Hyper(lr=jnp.float32(sweep.lr), step=jnp.int32(i))
            params, opt, metrics = step(params, opt, b, hyper, bits)
            losses.append(float(metrics["loss"]))
        tail = max(1, len(losses) // 4)
        return float(sum(losses[-tail:]) / tail)

    return select_plan(probe, n, sweep, log=log)
