from repro.optim.sgd import (
    OptimizerConfig,
    init_opt_state,
    apply_update,
    Hyper,
)
from repro.optim.schedule import cosine_schedule, constant_schedule

__all__ = [
    "OptimizerConfig", "init_opt_state", "apply_update", "Hyper",
    "cosine_schedule", "constant_schedule",
]
