"""Optimizers built for per-layer fused updates.

The TaxoNN engine applies updates *inside* the backward scan, one layer at a
time (the paper's step-4 fused `W -= alpha * dW`).  The optimizer therefore
exposes a leafwise ``apply_update(params, grads, state, hyper)`` that works
on any sub-pytree (a single scanned layer slice or the whole boundary param
group) — no global gradient tree ever exists on the TaxoNN path.

Kinds:
  sgd        — stateless (paper's optimizer)
  momentum   — classic heavy-ball
  momentum8  — heavy-ball with int8-quantized momentum buffers (per-tensor
               scale): training-state analogue of the paper's low-bit storage
  adam       — for baseline comparisons
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "sgd"             # sgd | momentum | momentum8 | adam
    momentum: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0        # 0 = off; per-leaf clip by global-norm proxy


@dataclasses.dataclass(frozen=True)
class Hyper:
    """Traced hyperparameters (lr varies per step; passed into the jit)."""
    lr: Array
    step: Array


jax.tree_util.register_dataclass(Hyper, data_fields=["lr", "step"], meta_fields=[])


def init_opt_state(params, cfg: OptimizerConfig):
    if cfg.kind == "sgd":
        return {}
    if cfg.kind == "momentum":
        return {"m": jax.tree.map(jnp.zeros_like, params)}
    if cfg.kind == "momentum8":
        # rowwise scales (over the last axis): keeps a leading layer axis on
        # stacked params so the TaxoNN engine can scan optimizer state
        return {
            "m_q": jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.int8), params),
            "m_s": jax.tree.map(
                lambda w: jnp.ones(w.shape[:-1], jnp.float32), params),
        }
    if cfg.kind == "adam":
        return {
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
        }
    raise ValueError(cfg.kind)


def _clip(g: Array, limit: float) -> Array:
    if limit <= 0:
        return g
    norm = jnp.sqrt(jnp.sum(jnp.square(g.astype(jnp.float32))))
    scale = jnp.minimum(1.0, limit / (norm + 1e-12))
    return g * scale


def apply_update(params, grads, state, hyper: Hyper, cfg: OptimizerConfig):
    """Leafwise update over an arbitrary sub-pytree. Returns (params, state)."""
    lr = hyper.lr

    if cfg.kind == "sgd":
        def upd(w, g):
            g = _clip(g, cfg.grad_clip).astype(jnp.float32)
            if cfg.weight_decay:
                g = g + cfg.weight_decay * w
            return (w - lr * g).astype(w.dtype)
        return jax.tree.map(upd, params, grads), state

    if cfg.kind == "momentum":
        def upd(w, g, m):
            g = _clip(g, cfg.grad_clip).astype(jnp.float32)
            if cfg.weight_decay:
                g = g + cfg.weight_decay * w
            m_new = cfg.momentum * m + g
            return (w - lr * m_new).astype(w.dtype), m_new
        out = jax.tree.map(upd, params, grads, state["m"])
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m}

    if cfg.kind == "momentum8":
        def upd(w, g, mq, ms):
            g = _clip(g, cfg.grad_clip).astype(jnp.float32)
            if cfg.weight_decay:
                g = g + cfg.weight_decay * w
            m = mq.astype(jnp.float32) * ms[..., None]
            m_new = cfg.momentum * m + g
            absmax = jnp.max(jnp.abs(m_new), axis=-1)
            s_new = jnp.where(absmax > 0, absmax / 127.0, 1.0)
            mq_new = jnp.clip(jnp.round(m_new / s_new[..., None]),
                              -127, 127).astype(jnp.int8)
            return (w - lr * m_new).astype(w.dtype), mq_new, s_new
        out = jax.tree.map(upd, params, grads, state["m_q"], state["m_s"])
        def istuple(x):
            return isinstance(x, tuple)
        return (
            jax.tree.map(lambda o: o[0], out, is_leaf=istuple),
            {
                "m_q": jax.tree.map(lambda o: o[1], out, is_leaf=istuple),
                "m_s": jax.tree.map(lambda o: o[2], out, is_leaf=istuple),
            },
        )

    if cfg.kind == "adam":
        t = hyper.step.astype(jnp.float32) + 1.0

        def upd(w, g, m, v):
            g = _clip(g, cfg.grad_clip).astype(jnp.float32)
            if cfg.weight_decay:
                g = g + cfg.weight_decay * w
            m_new = cfg.momentum * m + (1 - cfg.momentum) * g
            v_new = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
            mh = m_new / (1 - cfg.momentum ** t)
            vh = v_new / (1 - cfg.beta2 ** t)
            return (w - lr * mh / (jnp.sqrt(vh) + cfg.eps)).astype(w.dtype), m_new, v_new
        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        def istuple(x):
            return isinstance(x, tuple)
        return (
            jax.tree.map(lambda o: o[0], out, is_leaf=istuple),
            {
                "m": jax.tree.map(lambda o: o[1], out, is_leaf=istuple),
                "v": jax.tree.map(lambda o: o[2], out, is_leaf=istuple),
            },
        )

    raise ValueError(cfg.kind)
