"""Learning-rate schedules (host-side: produce the lr scalar fed to Hyper)."""
from __future__ import annotations

import math


def constant_schedule(lr: float):
    def f(step: int) -> float:
        return lr
    return f


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    floor = peak_lr * floor_frac

    def f(step: int) -> float:
        if step < warmup:
            return peak_lr * (step + 1) / max(warmup, 1)
        frac = min(1.0, (step - warmup) / max(total - warmup, 1))
        return floor + 0.5 * (peak_lr - floor) * (1 + math.cos(math.pi * frac))
    return f
