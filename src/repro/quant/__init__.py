"""Fixed-point (I,F) quantization for TaxoNN-style low-bitwidth training.

The paper trains in per-layer fixed-point arithmetic: a number format
``(I, F)`` has ``I`` integer bits, ``F`` fractional bits and one sign bit
(total bitwidth ``I + F + 1``).  We emulate that arithmetic in float with
quantize-dequantize + straight-through estimators, so the same compiled
program serves any bitwidth schedule (bitwidths are runtime data).
"""
from repro.quant.fixed_point import (
    QFormat,
    quantize,
    quantize_ste,
    quantize_stochastic,
    stochastic_round_batched,
    fxp_resolution,
    fxp_max,
    BitSchedule,
    make_bit_schedule,
    paper_schedule,
    schedule_from_formats,
)
from repro.quant.compression import (
    compress_int8,
    decompress_int8,
    quantized_allreduce_bytes,
)
from repro.quant.int8 import (
    BlockScaledInt8,
    Int8Spec,
    absmax_scale,
    dequantize_int8,
    fxp_int8_bounds,
    fxp_int8_scale,
    int8_spec,
    quantize_int8,
    quantize_int8_absmax,
    quantize_int8_auto,
    quantize_int8_fxp,
    quantize_int8_tiles,
    transport_bits,
)

__all__ = [
    "QFormat",
    "quantize",
    "quantize_ste",
    "quantize_stochastic",
    "stochastic_round_batched",
    "fxp_resolution",
    "fxp_max",
    "BitSchedule",
    "make_bit_schedule",
    "paper_schedule",
    "schedule_from_formats",
    "compress_int8",
    "decompress_int8",
    "quantized_allreduce_bytes",
    "BlockScaledInt8",
    "Int8Spec",
    "absmax_scale",
    "dequantize_int8",
    "fxp_int8_bounds",
    "fxp_int8_scale",
    "int8_spec",
    "quantize_int8",
    "quantize_int8_absmax",
    "quantize_int8_auto",
    "quantize_int8_fxp",
    "quantize_int8_tiles",
    "transport_bits",
]
