"""Block-scaled int8 storage/compute format for the MXU kernel datapath.

The paper's performance claim rests on executing training MACs on the same
low-bitwidth units that serve inference.  On TPU the analogous unit is the
int8 MXU path: ``dot(int8, int8, preferred_element_type=int32)`` runs at
2-4x the f32 MAC rate with exact 32-bit accumulation (the paper's wide
accumulator registers).  This module defines how a TaxoNN ``(I, F)``
fixed-point tensor maps onto that path:

  * A format with bitwidth ``I + F + 1 <= 8`` embeds **exactly**: the int8
    payload is the fixed-point integer ``k`` itself and the scale is the
    format's resolution ``2^-F``.
  * A wider format keeps its 8 most significant bits: the bottom
    ``I + F + 1 - 8`` fractional bits are dropped (shift = right-shift of
    the fixed-point integer), i.e. the effective format is
    ``(I, F - shift)`` — saturation behaviour is unchanged.

Scales may be *static* Python floats (kernel-constant formats, e.g. the
LeNet Table-I schedules) or *traced* f32 scalars (per-tensor absmax scaling
in the runtime-bit engine path) — the kernels accept either through a small
f32 meta operand.

The per-tile storage container (``BlockScaledInt8``) reuses the absmax
machinery of ``repro.quant.compression``: each tile stores int8 payload plus
one f32 scale, where the scale is the (I,F)-derived step widened only for
tiles whose absmax overflows the format's representable range (hardware
would saturate; widening keeps the MSBs at the same bit budget).  A 2D tile
of dW in this format is byte-compatible with the wire format that
``dist.collectives.compressed_psum`` moves over ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.fixed_point import _pow2_int

Array = jax.Array

INT8_BITS = 8
TILE = (128, 128)  # default storage tile: one MXU face


# ---------------------------------------------------------------------------
# Static (Python-int) format mapping — for kernel-constant (I,F) formats
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Int8Spec:
    """How a static (I,F) format embeds into int8: q = clip(round(x/scale))."""

    scale: float
    qmin: int
    qmax: int
    shift: int  # dropped low fractional bits (0 when bitwidth <= 8)

    @property
    def exact(self) -> bool:
        """True when the int8 grid equals the (I,F) grid (bitwidth <= 8)."""
        return self.shift == 0


def int8_spec(i_bits: int, f_bits: int) -> Int8Spec:
    shift = max(0, i_bits + f_bits + 1 - INT8_BITS)
    mag = 2 ** (i_bits + f_bits - shift)  # <= 2^7
    return Int8Spec(scale=2.0 ** (shift - f_bits), qmin=-mag, qmax=mag - 1,
                    shift=shift)


# ---------------------------------------------------------------------------
# Traced helpers — bits and scales as runtime data (no recompiles)
# ---------------------------------------------------------------------------

def fxp_int8_scale(i_bits, f_bits) -> Array:
    """The (I,F)-derived int8 scale 2^(shift-F), computed from traced bits."""
    total = jnp.asarray(i_bits, jnp.int32) + jnp.asarray(f_bits, jnp.int32)
    shift = jnp.maximum(total + 1 - INT8_BITS, 0)
    return _pow2_int(shift) / _pow2_int(jnp.asarray(f_bits, jnp.int32))


def fxp_int8_bounds(i_bits, f_bits) -> tuple[Array, Array]:
    """(qmin, qmax) of the int8 embedding, from traced bits (f32 scalars)."""
    total = jnp.asarray(i_bits, jnp.int32) + jnp.asarray(f_bits, jnp.int32)
    shift = jnp.maximum(total + 1 - INT8_BITS, 0)
    mag = _pow2_int(total - shift)
    return -mag, mag - 1.0


def absmax_scale(x: Array) -> Array:
    """Per-tensor dynamic scale absmax/127 (traced scalar, zero-safe)."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return jnp.where(m > 0, m / 127.0, jnp.float32(1.0))


def quantize_int8(x: Array, scale, qmin=-127.0, qmax=127.0) -> Array:
    """Round-to-nearest int8 payload on the grid ``scale * [qmin, qmax]``."""
    xf = x.astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / scale), qmin, qmax)
    return q.astype(jnp.int8)


def quantize_int8_fxp(x: Array, i_bits, f_bits) -> tuple[Array, Array]:
    """Quantize onto the (I,F)-derived int8 grid. Returns (payload, scale).

    Works with both static Python-int and traced int32 bits; the returned
    scale is a f32 scalar either way.
    """
    if isinstance(i_bits, int) and isinstance(f_bits, int):
        spec = int8_spec(i_bits, f_bits)
        return (quantize_int8(x, spec.scale, spec.qmin, spec.qmax),
                jnp.float32(spec.scale))
    scale = fxp_int8_scale(i_bits, f_bits)
    qmin, qmax = fxp_int8_bounds(i_bits, f_bits)
    return quantize_int8(x, scale, qmin, qmax), scale


def quantize_int8_absmax(x: Array) -> tuple[Array, Array]:
    """Quantize with a per-tensor dynamic absmax scale (payload, scale)."""
    scale = absmax_scale(x)
    return quantize_int8(x, scale), scale


def transport_bits(bits: Optional[tuple]) -> Optional[tuple]:
    """The int8 *transport* rule for a static (I,F) format: keep the format
    grid when it embeds exactly (bitwidth <= 8); wider formats travel with
    absmax block scaling instead (None) — dropping their low fractional
    bits on the wire would zero small gradients and stall SGD."""
    if bits is None:
        return None
    i_bits, f_bits = bits
    return bits if i_bits + f_bits + 1 <= INT8_BITS else None


def quantize_int8_auto(x: Array, bits: Optional[tuple]) -> tuple[Array, Array]:
    """Transport quantization: the (I,F) grid when it embeds exactly,
    per-tensor absmax scaling otherwise (see ``transport_bits``)."""
    bits = transport_bits(bits)
    if bits is None:
        return quantize_int8_absmax(x)
    return quantize_int8_fxp(x, *bits)


def dequantize_int8(q: Array, scale, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Per-tile storage container (the dW wire format, 2D-tiled)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockScaledInt8:
    """A 2D array stored as int8 tiles with one f32 scale per tile."""

    payload: Array      # int8, padded to a multiple of the tile
    scales: Array       # f32 [tiles_r, tiles_c]
    shape: tuple        # original (unpadded) shape
    tile: tuple         # (tr, tc)

    def dequantize(self, dtype=jnp.float32) -> Array:
        tr, tc = self.tile
        pr, pc = self.payload.shape
        s = jnp.repeat(jnp.repeat(self.scales, tr, axis=0), tc, axis=1)
        x = self.payload.astype(jnp.float32) * s
        return x[:self.shape[0], :self.shape[1]].astype(dtype)


jax.tree_util.register_dataclass(
    BlockScaledInt8, data_fields=["payload", "scales"],
    meta_fields=["shape", "tile"])


def quantize_int8_tiles(x: Array, i_bits: Optional[int] = None,
                        f_bits: Optional[int] = None,
                        tile: tuple = TILE) -> BlockScaledInt8:
    """Tile-quantize a 2D array.

    With ``(i_bits, f_bits)`` given, every tile starts from the format's
    int8 scale and widens (per tile) only where the tile's absmax overflows
    the format range; without bits the scale is pure per-tile absmax/127
    (the ``compression.compress_int8`` rule applied to 2D tiles).
    """
    assert x.ndim == 2, x.shape
    tr, tc = tile
    r, c = x.shape
    pr, pc = (-r) % tr, (-c) % tc
    xf = jnp.pad(x.astype(jnp.float32), ((0, pr), (0, pc)))
    nr, nc = xf.shape[0] // tr, xf.shape[1] // tc
    tiles = xf.reshape(nr, tr, nc, tc).transpose(0, 2, 1, 3)  # [nr,nc,tr,tc]
    absmax = jnp.max(jnp.abs(tiles), axis=(2, 3))
    dyn = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    if i_bits is not None and f_bits is not None:
        base = fxp_int8_scale(i_bits, f_bits)
        scales = jnp.maximum(dyn, base)  # widen only overflowing tiles
    else:
        scales = dyn
    q = jnp.clip(jnp.round(tiles / scales[:, :, None, None]), -127, 127)
    payload = q.transpose(0, 2, 1, 3).reshape(xf.shape).astype(jnp.int8)
    return BlockScaledInt8(payload=payload, scales=scales, shape=(r, c),
                           tile=(tr, tc))
