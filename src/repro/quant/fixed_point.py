"""Fixed-point (I,F) emulation with straight-through estimators.

A TaxoNN number format ``(I, F)`` is a signed fixed-point format with ``I``
integer bits and ``F`` fractional bits (bitwidth ``I + F + 1`` including
sign).  Representable values are ``k * 2^-F`` for integer
``k in [-2^(I+F), 2^(I+F) - 1]``.

All quantizers below take ``I`` and ``F`` as *traced values* (int32 scalars
or arrays), so per-layer bitwidth schedules are runtime data: one compiled
train step serves every schedule, exactly as one TaxoNN chip serves every
(I,F) configuration loaded into its registers.

On real TPU hardware, formats with bitwidth <= 8 map onto the int8 MXU path
and formats with bitwidth <= 16 map onto bf16/int16; this module emulates the
*values* those paths would produce (round-to-nearest-even or stochastic
rounding, saturating clip).
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

Array = jax.Array
IntLike = Union[int, Array]


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Static description of a fixed-point format (for configs / docs)."""

    i_bits: int
    f_bits: int

    @property
    def bitwidth(self) -> int:
        return self.i_bits + self.f_bits + 1

    @property
    def resolution(self) -> float:
        return 2.0 ** (-self.f_bits)

    @property
    def max_value(self) -> float:
        return (2.0 ** (self.i_bits + self.f_bits) - 1) * self.resolution

    def __repr__(self) -> str:  # matches the paper's "(I,F)" notation
        return f"({self.i_bits},{self.f_bits})"


def _pow2_int(bits: IntLike) -> Array:
    """Exact 2^bits as float32 via integer shift (jnp.exp2 on f32 is computed
    as exp(x*ln2) on CPU and is NOT exact for integer exponents).

    Valid for 0 <= bits <= 30 (int32 shift); TaxoNN formats are <= 21 bits.
    """
    b = jnp.asarray(bits, jnp.int32)
    return jnp.left_shift(jnp.int32(1), b).astype(jnp.float32)


def fxp_resolution(f_bits: IntLike) -> Array:
    """Quantization step 2^-F, computed exactly from traced F."""
    return 1.0 / _pow2_int(f_bits)


def fxp_max(i_bits: IntLike, f_bits: IntLike) -> Array:
    """Largest representable magnitude (positive side) of (I,F)."""
    total = jnp.asarray(i_bits, jnp.int32) + jnp.asarray(f_bits, jnp.int32)
    return (_pow2_int(total) - 1.0) * fxp_resolution(f_bits)


def _quantize_value(x: Array, i_bits: IntLike, f_bits: IntLike) -> Array:
    """Round-to-nearest-even fixed-point quantization (pure value, no STE)."""
    x = jnp.asarray(x)
    step = fxp_resolution(f_bits).astype(x.dtype)
    total = jnp.asarray(i_bits, jnp.int32) + jnp.asarray(f_bits, jnp.int32)
    qmax = _pow2_int(total) - 1.0  # integer grid bound, positive side
    qmin = -_pow2_int(total)
    k = jnp.clip(jnp.round(x / step), qmin.astype(x.dtype), qmax.astype(x.dtype))
    return k * step


def quantize(x: Array, i_bits: IntLike, f_bits: IntLike) -> Array:
    """Quantize ``x`` to the (I,F) grid. No gradient definition (use in fwd-only
    paths or where the surrounding code handles gradients explicitly)."""
    return _quantize_value(x, i_bits, f_bits)


@jax.custom_vjp
def quantize_ste(x: Array, i_bits: Array, f_bits: Array) -> Array:
    """Quantize with a straight-through estimator.

    Forward: round-to-nearest-even onto the (I,F) grid with saturation.
    Backward: identity inside the representable range, zero outside
    (saturated values carry no gradient — matches hardware clipping).
    """
    return _quantize_value(x, i_bits, f_bits)


def _ste_fwd(x, i_bits, f_bits):
    bound = fxp_max(i_bits, f_bits).astype(x.dtype)
    mask = (jnp.abs(x) <= bound).astype(x.dtype)
    return _quantize_value(x, i_bits, f_bits), mask


def _ste_bwd(mask, g):
    return (g * mask, None, None)


quantize_ste.defvjp(_ste_fwd, _ste_bwd)


@jax.custom_vjp
def quantize_stochastic(x: Array, i_bits: Array, f_bits: Array, key: Array) -> Array:
    """Stochastically-rounded quantization with STE backward.

    Stochastic rounding is unbiased: E[q(x)] = x for in-range x.  The paper's
    low-bit gradient path needs this to keep SGD convergent at F <= 10 —
    round-to-nearest silently zeroes small gradient mass.
    """
    return _stochastic_value(x, i_bits, f_bits, key)


def _stochastic_value(x, i_bits, f_bits, key):
    x = jnp.asarray(x)
    step = fxp_resolution(f_bits).astype(x.dtype)
    total = jnp.asarray(i_bits, jnp.int32) + jnp.asarray(f_bits, jnp.int32)
    qmax = (_pow2_int(total) - 1.0).astype(x.dtype)
    qmin = (-_pow2_int(total)).astype(x.dtype)
    scaled = x / step
    floor = jnp.floor(scaled)
    frac = scaled - floor
    u = jax.random.uniform(key, x.shape, dtype=x.dtype)
    k = floor + (u < frac).astype(x.dtype)
    k = jnp.clip(k, qmin, qmax)
    return k * step


def stochastic_round_batched(x: Array, i_bits: Array, f_bits: Array,
                             key: Array, offset) -> Array:
    """Stochastic rounding whose noise is drawn PER LEADING-AXIS ELEMENT:
    row ``b`` uses ``fold_in(key, offset + b)``.

    Because each batch row owns its fold of the key, slicing the leading
    (batch) axis and passing the slice's global offset reproduces the
    full-batch draws exactly.  This is what lets the stage-sharded pipeline
    (which sees one microbatch at a time) make the same draws as the
    single-device scan engine (which sees the whole batch): the keys are
    deterministic in (layer key, global batch row), not in tensor shape.

    Value-only — callers on the manual G-chain apply it to cotangents
    directly; the forward-graph wrapper with an STE transpose lives in
    ``core.taxonn.grad_tap_stochastic``.
    """
    off = jnp.asarray(offset, jnp.int32)
    keys = jax.vmap(lambda b: jax.random.fold_in(key, off + b))(
        jnp.arange(x.shape[0], dtype=jnp.int32))
    return jax.vmap(lambda k, xb: _stochastic_value(xb, i_bits, f_bits, k))(
        keys, x)


def _stoch_fwd(x, i_bits, f_bits, key):
    bound = fxp_max(i_bits, f_bits).astype(x.dtype)
    mask = (jnp.abs(x) <= bound).astype(x.dtype)
    return _stochastic_value(x, i_bits, f_bits, key), mask


def _stoch_bwd(mask, g):
    return (g * mask, None, None, None)


quantize_stochastic.defvjp(_stoch_fwd, _stoch_bwd)


# ---------------------------------------------------------------------------
# Per-layer bit schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BitSchedule:
    """Per-layer (I,F) bitwidths for the three tensor classes the paper
    quantizes: weights, activations (the cached X_i), and gradients (G, dW).

    Each field is an int32 array of shape [num_layers] so it can be scanned
    with the layer stack.  ``enabled`` turns quantization off entirely
    (fp32/bf16 baseline) without recompiling.
    """

    w_i: Array
    w_f: Array
    a_i: Array
    a_f: Array
    g_i: Array
    g_f: Array
    enabled: Array  # float32 scalar: 1.0 = quantize, 0.0 = passthrough

    @property
    def num_layers(self) -> int:
        return int(self.w_i.shape[0])

    def layer(self, idx):
        """Slice one layer's bitwidths (for use inside a scanned body)."""
        return BitSchedule(
            w_i=self.w_i[idx], w_f=self.w_f[idx],
            a_i=self.a_i[idx], a_f=self.a_f[idx],
            g_i=self.g_i[idx], g_f=self.g_f[idx],
            enabled=self.enabled,
        )


jax.tree_util.register_dataclass(
    BitSchedule,
    data_fields=["w_i", "w_f", "a_i", "a_f", "g_i", "g_f", "enabled"],
    meta_fields=[],
)


def make_bit_schedule(
    num_layers: int,
    weight: tuple = (2, 12),
    act: tuple = (4, 10),
    grad: tuple = (2, 12),
    *,
    ramp: bool = True,
    enabled: bool = True,
) -> BitSchedule:
    """Build a per-layer schedule.

    ``ramp=True`` applies the paper's observation that later layers need more
    fractional bits: F ramps by +2 over the final quarter of the stack, and
    the last layer gets +1 integer bit (mirrors the (3,10) / (4,12) tails in
    Table I).
    """
    import numpy as np

    def per_layer(base_i, base_f):
        i = np.full((num_layers,), base_i, np.int32)
        f = np.full((num_layers,), base_f, np.int32)
        if ramp and num_layers > 1:
            tail = max(1, num_layers // 4)
            f[-tail:] += 2
            i[-1] += 1
        return jnp.asarray(i), jnp.asarray(f)

    w_i, w_f = per_layer(*weight)
    a_i, a_f = per_layer(*act)
    g_i, g_f = per_layer(*grad)
    return BitSchedule(
        w_i=w_i, w_f=w_f, a_i=a_i, a_f=a_f, g_i=g_i, g_f=g_f,
        enabled=jnp.float32(1.0 if enabled else 0.0),
    )


def schedule_from_formats(formats, *, enabled: bool = True) -> BitSchedule:
    """Build a schedule from an explicit per-layer list of (I, F) tuples.

    All three tensor classes (weights, activations, gradients) share the
    layer's format — the same convention as ``paper_schedule`` / Table I.
    This is the loading path for searched ``BitPlan`` artifacts.
    """
    i = jnp.asarray([int(p[0]) for p in formats], jnp.int32)
    f = jnp.asarray([int(p[1]) for p in formats], jnp.int32)
    return BitSchedule(
        w_i=i, w_f=f, a_i=i, a_f=f, g_i=i, g_f=f,
        enabled=jnp.float32(1.0 if enabled else 0.0),
    )


def paper_schedule(dataset: str, num_layers: int = 5) -> BitSchedule:
    """The exact per-layer (I,F) design points from Table I of the paper,
    tiled/interpolated if num_layers != 5."""
    import numpy as np

    table = {
        "mnist": [(2, 12), (2, 12), (2, 12), (1, 12), (3, 10)],
        "cifar10": [(2, 10), (2, 11), (1, 10), (1, 13), (2, 13)],
        "svhn": [(1, 12), (2, 12), (2, 12), (2, 11), (4, 12)],
    }
    pts = table[dataset.lower()]
    idx = np.minimum(
        (np.arange(num_layers) * len(pts)) // max(num_layers, 1), len(pts) - 1
    )
    i = jnp.asarray([pts[j][0] for j in idx], jnp.int32)
    f = jnp.asarray([pts[j][1] for j in idx], jnp.int32)
    return BitSchedule(
        w_i=i, w_f=f, a_i=i, a_f=f, g_i=i, g_f=f, enabled=jnp.float32(1.0)
    )


def maybe_quantize(x: Array, i_bits, f_bits, enabled: Array) -> Array:
    """Blend between quantized and passthrough based on the runtime flag.

    ``enabled`` is a float scalar (0.0/1.0); the select keeps everything
    traceable with zero recompiles when toggling quantization.
    """
    q = quantize_ste(x, i_bits, f_bits)
    return enabled * q + (1.0 - enabled) * x
