"""Gradient compression codecs for cross-replica reduction.

TaxoNN's power/area win comes from moving fewer bits per MAC.  On a pod the
analogous scarce resource is ICI bytes: the per-layer gradient all-reduce.
We provide an int8 block-scaled codec (4x byte reduction vs f32, 2x vs bf16)
used by ``dist.collectives.compressed_psum``.

The codec is deterministic and shape-preserving:
  compress:   f32[N] -> (int8[N], f32[N/B] scales)
  decompress: exact inverse of the quantization grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

BLOCK = 256  # elements per scale block; 1 f32 scale per 256 int8 payloads


def _pad_to_block(x: Array) -> tuple[Array, int]:
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.ravel(x)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat, n


def compress_int8(x: Array) -> tuple[Array, Array]:
    """Block-scaled int8 quantization. Returns (payload int8, scales f32)."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale.reshape(-1)


def decompress_int8(payload: Array, scales: Array, shape, dtype=jnp.float32) -> Array:
    blocks = payload.reshape(-1, BLOCK).astype(jnp.float32)
    x = blocks * scales.reshape(-1, 1)
    n = 1
    for d in shape:
        n *= d
    return x.reshape(-1)[:n].reshape(shape).astype(dtype)


def quantized_allreduce_bytes(num_elements: int, dtype_bytes: int = 4) -> dict:
    """Napkin accounting of collective bytes: dense vs int8-compressed.

    Used by benchmarks/savings.py to report the Table-IV analogue.
    """
    dense = num_elements * dtype_bytes
    comp = num_elements * 1 + (num_elements // BLOCK + 1) * 4
    return {
        "dense_bytes": dense,
        "compressed_bytes": comp,
        "reduction": dense / comp,
    }
