"""Cross-replica gradient reduction: dense and int8-compressed tree psum.

``dense_psum_tree`` is the reference f32 all-reduce.  ``compressed_psum_tree``
is the ICI-bytes analogue of TaxoNN's low-bitwidth MACs: each replica
block-scales its gradient to int8 (repro.quant.compression), the *compressed*
payload+scales travel over the interconnect (all-gather), and every replica
decompresses and sums locally.  1 byte/element + 4/BLOCK scale overhead vs 4
bytes/element dense — the Table-IV byte reduction applied to the dW
all-reduce that the backward scan issues per layer.

Both functions treat the input tree as *per-replica* values laid out
replicated on the mesh and return the elementwise sum across the named axes
(identical on every replica).  The compressed variant's error is bounded by
one quantization step per replica: |err| <= n_replicas * absmax_block / 127
/ 2 per element.
"""
from __future__ import annotations

from typing import Iterable

from repro.util import jaxcompat as _jaxcompat  # noqa: F401  (installs shims)

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.quant.compression import compress_int8, decompress_int8


def _reduce_size(mesh, axes) -> int:
    shape = dict(mesh.shape)
    n = 1
    for a in axes:
        n *= shape[a]
    return n


def dense_psum_tree(grads, mesh, axes: Iterable[str]):
    """Elementwise sum of ``grads`` across the mesh axes ``axes``."""
    axes = tuple(axes)

    def f(tree):
        return jax.tree.map(lambda x: lax.psum(x, axes), tree)

    return jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_vma=False)(grads)


def compressed_psum(x, axes: Iterable[str] = (), num_replicas: int = None):
    """One-tensor int8 block-scaled all-reduce (the dW wire format).

    The public per-tensor entry point: the TaxoNN engine's backward scan
    calls it per layer (``QuantPolicy.compress_dw``) and
    ``compressed_psum_tree`` maps it over a gradient tree inside its own
    shard_map.  With ``axes`` naming mesh axes it must run where those
    axes are bound (a shard_map body) and moves the compressed
    payload+scales over the interconnect.  With empty axes it is the pure
    codec round-trip — the wire-format numerics with no collective — and
    honors ``num_replicas`` as the simulated reduction size: ``n``
    replicas of a replicated value sum to ``n * decompress(compress(x))``,
    matching what the mesh path returns for the same replicated input.
    """
    axes = tuple(axes)
    payload, scales = compress_int8(x)
    if not axes or num_replicas == 1:
        dec = decompress_int8(payload, scales, x.shape, x.dtype)
        if not axes and num_replicas is not None and num_replicas > 1:
            dec = (dec.astype(jnp.float32) * num_replicas).astype(x.dtype)
        return dec
    pg = lax.all_gather(payload, axes)   # [n, N] int8 on the wire
    sg = lax.all_gather(scales, axes)    # [n, N/BLOCK] f32
    dec = jax.vmap(
        lambda p, s: decompress_int8(p, s, x.shape, jnp.float32)
    )(pg, sg)
    return jnp.sum(dec, axis=0).astype(x.dtype)


def compressed_psum_tree(grads, mesh, axes: Iterable[str]):
    """int8 block-scaled all-reduce: compress locally, move compressed
    bytes, decompress + sum on every replica."""
    axes = tuple(axes)
    n = _reduce_size(mesh, axes)

    def f(tree):
        return jax.tree.map(
            lambda x: compressed_psum(x, axes, num_replicas=n), tree)

    return jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_vma=False)(grads)
