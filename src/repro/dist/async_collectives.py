"""Bucketed async ring all-reduce: chunked ``lax.ppermute`` + start/wait.

The blocking collectives in ``dist.collectives`` issue one monolithic op per
tensor; XLA is free to overlap it with independent compute, but the backward
scan gives it nothing independent to overlap WITH — the scan body consumes
the reduced dW immediately.  This module supplies the two pieces the
communication-overlapped backward scan (``core.taxonn.backward_stack`` with
``QuantPolicy.overlap="on"``) is built from:

  * a **ring all-reduce** decomposed into chunked ``lax.ppermute`` steps
    (CATERPILLAR's interleaved ring reduction, Li & Pedram 2017): the tensor
    is split into the ring's g segments and, optionally, ``num_buckets``
    independent bucket streams, so each hop moves a small chunk the
    scheduler can interleave with MXU work instead of one long transfer;

  * an **AsyncHandle start/wait API** that splits the ring at its natural
    seam so the two halves can live in *different* scan iterations:

        handle = all_reduce_start(dW_i, axes)     # scan step i
        ... next layer's G-step/VJP compute ...   # overlap window
        dW_i   = all_reduce_wait(handle)          # scan step i+1

    ``AsyncHandle`` is a registered pytree, so it rides in the scan carry;
    every array it holds has a static shape, making the carry scan-legal.

Dense split: ``start`` runs the reduce-scatter phase (g-1 chunked hops) and
the carry holds only the 1/g-sized reduced shard; ``wait`` runs the
all-gather phase.  Compressed split (the int8 wire format of
``quant.compression``): ``start`` runs a **decompress-add-recompress
reduce-scatter ring** — each hop moves one 1/g compressed segment, so
per-hop wire bytes drop by (g-1)/g vs circulating the full buffer — and
the carry holds only this device's fully-reduced compressed segment;
``wait`` all-gathers the compressed segments and decompresses.  The
per-element error vs ``collectives.compressed_psum`` is bounded by one
codec half-step per compression event: g initial compressions plus g-2
in-ring recompressions, i.e. ``|err| <= (2g - 2) * max_block_absmax / 254``
(see ``_compressed_reduce_scatter``).

**Transport autotuner** (the ``transport=`` knob): the chunked ppermute
ring is the right transport only when its hops genuinely overlap compute;
measured on emulated host-CPU device groups one fused ``lax.psum`` beats
it by ~4x.  ``decide_transport`` picks ``"ring"`` vs ``"psum"`` vs
``"scatter"`` per bucket size — from a MEASURED micro-benchmark of the
reduce + optimizer-update-tail composite on the live device group when
one can run (cached per (compressed, size-bucket, group) like
``kernels.ops.tune_blocks``; prime eagerly via ``prime_transport_cache``),
falling back to a platform latency model inside a trace.  The
``REPRO_TRANSPORT`` env var forces a decision for reproduction runs, and
``dump_transport_cache`` persists the decisions (CI uploads them as a
debugging artifact).  ``transport="psum"`` issues the blocking collective
at ``start`` (dense: one FUSED psum over the whole tree at the tree API —
one rendezvous per layer instead of one per leaf; compressed: the
all-gather wire format of ``compressed_psum``) and returns an
already-complete handle whose ``wait`` is free — the in-flight value still
rides the scan carry, so the scheduler keeps the cross-iteration window.
``transport="scatter"`` (dense only) is the native reduce-scatter /
all-gather split: ``start`` completes a ``lax.psum_scatter`` and the
handle carries this device's fully reduced 1/g chunk; ``wait`` is a
``lax.all_gather``.  Same wire bytes as the fused psum, but the chunk is
a real shard the caller can run the optimizer update on BEFORE gathering
(``shard_chunk`` / ``reduce_scatter_chunk`` / ``all_gather_chunks``) —
the measured ~1.7x win at dW-leaf sizes that makes ``overlap=on`` beat
the blocking scan on CPU device groups.

Axes semantics match ``collectives.compressed_psum``: ``axes`` must name
mesh axes of an enclosing ``shard_map`` body; empty axes (or a group of
one) degrade to the identity — ``wait(start(x)) == x`` bit-exactly, which
is what makes the overlapped scan a pure *schedule* change on one device.
The ring assumes a single-process device group; spanning a multi-process
axis raises ``NotImplementedError`` up front (use ``transport="psum"``
there until the hops are topology-aware).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Iterable, Optional, Tuple

from repro.util import jaxcompat as _jaxcompat  # noqa: F401  (installs shims)

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.collectives import compressed_psum
from repro.quant.compression import BLOCK, compress_int8, decompress_int8

Array = jax.Array

# Auto-bucketing: one bucket per this many payload bytes (capped) so large
# dW tensors become several independent ring streams whose chunks the
# scheduler can interleave, while small tensors stay single-stream.
BUCKET_BYTES = 1 << 20
MAX_BUCKETS = 4

TRANSPORTS = ("ring", "psum", "scatter")
# model fallback: below this payload a ring is latency-bound on real
# accelerators and the fused psum wins; host-CPU device groups share one
# memory system, so the model never picks the ring there
RING_MIN_BYTES = 1 << 20


def _transports_for(compressed: bool) -> Tuple[str, ...]:
    """The compressed wire format has no reduce-scatter split (the int8
    codec blocks straddle the 1/g segment boundary), so ``scatter`` is a
    dense-only transport."""
    return ("ring", "psum") if compressed else TRANSPORTS


def group_size(axes: Iterable[str], num_replicas: Optional[int] = None) -> int:
    """Resolve the reduction-group size for named mesh axes.

    ``num_replicas`` overrides (callers inside a ``shard_map`` body know
    their mesh); otherwise the ambient (abstract) mesh is consulted.
    """
    axes = tuple(axes)
    if num_replicas is not None:
        return int(num_replicas)
    if not axes:
        return 1
    mesh = jax.sharding.get_abstract_mesh()
    shape = dict(getattr(mesh, "shape", {}) or {})
    n = 1
    for a in axes:
        if a not in shape:
            raise ValueError(
                f"cannot resolve ring-group size: axis {a!r} not in the "
                f"ambient mesh {tuple(shape)}; pass num_replicas= explicitly")
        n *= shape[a]
    return n


def _num_buckets(nbytes: int, num_buckets: Optional[int]) -> int:
    if num_buckets is not None:
        return max(1, int(num_buckets))
    return max(1, min(MAX_BUCKETS, nbytes // BUCKET_BYTES))


# ---------------------------------------------------------------------------
# transport autotuner: ring vs psum, per payload-size bucket
# ---------------------------------------------------------------------------

# (compressed, size_bucket_bytes, g) -> {"transport", "source", "us"}
_TRANSPORT_CACHE: dict = {}


def _size_bucket(nbytes: int) -> int:
    """Round the payload up to a power of two so near-identical tensors
    share one measured decision (the tune_blocks per-shape cache idiom,
    coarsened: transport crossover moves in decades, not elements)."""
    b = 1 << 12
    while b < nbytes:
        b <<= 1
    return b


def _forced_transport() -> Optional[str]:
    forced = os.environ.get("REPRO_TRANSPORT", "").strip().lower()
    if forced in TRANSPORTS:
        return forced
    if forced and forced != "auto":
        raise ValueError(
            f"REPRO_TRANSPORT={forced!r} not in {TRANSPORTS + ('auto',)}")
    return None


def _model_transport(nbytes: int, g: int, compressed: bool = False) -> str:
    """Deterministic fallback when no measurement can run (inside a trace,
    or the process doesn't own g devices).  Host-CPU 'devices' share one
    memory system — the emulated ring has nothing to overlap into and
    loses at every size (measured ~4x at 4MB) — so the model only picks
    the ring on a real accelerator backend, and only once the payload is
    big enough to amortize the per-hop latency.  Dense payloads on the
    CPU backend get ``scatter``: the native reduce-scatter + all-gather
    moves the same bytes as one fused psum but hands the caller a 1/g
    shard to run the optimizer update on (measured ~1.7x faster than
    psum + full-tensor update at dW-leaf sizes; callers that cannot
    exploit the shard degrade it to psum)."""
    if jax.default_backend() == "cpu":
        return "psum" if compressed else "scatter"
    return "ring" if nbytes >= RING_MIN_BYTES else "psum"


def _trace_clean() -> bool:
    fn = getattr(jax.core, "trace_state_clean", None)
    try:
        return bool(fn()) if fn is not None else False
    except Exception:
        return False


def _measure_transport(nbytes: int, g: int, compressed: bool,
                       reps: int = 3) -> dict:
    """Time each transport's REDUCE + UPDATE-TAIL composite for one
    bucket-sized payload on a live g-device mesh (eager: never called
    inside a trace).

    What the backward scan actually instantiates per dW leaf is not the
    all-reduce alone but reduce -> optimizer saxpy -> updated params
    available on every device, and the transports differ in where the
    saxpy runs: ``psum``/``ring`` update the full tensor on every device,
    ``scatter`` updates only this device's 1/g shard and all-gathers the
    result (same wire bytes, 1/g the update traffic) — so that composite
    is what gets timed and ranked."""
    n = max(BLOCK * g, (nbytes // 4 // (BLOCK * g)) * BLOCK * g)
    x = jnp.arange(n, dtype=jnp.float32) / n
    mesh = jax.make_mesh((g,), ("_tt",), devices=jax.devices()[:g])
    from jax.sharding import PartitionSpec as P

    def build(transport):
        if transport == "scatter":
            def f(v):
                shard = reduce_scatter_chunk(v, "_tt", g)
                own = shard_chunk(v, "_tt", g)
                new = own - jnp.float32(0.01) * shard
                return all_gather_chunks(new, "_tt", g, v.shape, v.dtype)
        else:
            def f(v):
                dw = ring_all_reduce(v, ("_tt",), num_replicas=g,
                                     compressed=compressed,
                                     transport=transport)
                return v - jnp.float32(0.01) * dw
        return jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(),
                                     out_specs=P(), check_vma=False))

    out = {}
    for transport in _transports_for(compressed):
        fn = build(transport)
        jax.block_until_ready(fn(x))            # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            # block EVERY rep: concurrent in-flight executions of one
            # collective module interleave their participants across
            # rendezvous on the CPU backend and deadlock the device group
            jax.block_until_ready(fn(x))
        out[transport] = (time.perf_counter() - t0) / reps * 1e6
    return out


def decide_transport(nbytes: int, g: int, *, compressed: bool = False,
                     allow_measure: bool = True) -> str:
    """Pick the transport for one payload: forced (``REPRO_TRANSPORT``) >
    cached > measured (when a g-device micro-bench can run right now) >
    platform model.  Decisions are cached per (compressed, size-bucket, g)
    so every scan iteration — and every later step build — reuses one
    choice; ``prime_transport_cache`` measures eagerly up front."""
    forced = _forced_transport()
    if forced is not None:
        # the compressed wire format has no scatter split
        return "psum" if (compressed and forced == "scatter") else forced
    if g <= 1:
        return "psum"                     # nothing moves; skip ring setup
    key = (bool(compressed), _size_bucket(nbytes), int(g))
    hit = _TRANSPORT_CACHE.get(key)
    if hit is not None:
        return hit["transport"]
    if allow_measure and g <= len(jax.devices()) and _trace_clean():
        try:
            us = _measure_transport(key[1], g, compressed)
            pick = min(us, key=us.get)
            _TRANSPORT_CACHE[key] = {"transport": pick, "source": "measured",
                                     "us": us}
            return pick
        except Exception:
            pass                          # fall through to the model
    pick = _model_transport(nbytes, g, compressed)
    _TRANSPORT_CACHE[key] = {"transport": pick, "source": "model", "us": {}}
    return pick


def prime_transport_cache(sizes_bytes: Iterable[int], g: int, *,
                          compressed: bool = False) -> dict:
    """Eagerly measure + cache the transport decisions a run will need
    (call BEFORE tracing the step: inside a trace the autotuner can only
    consult the cache or the model).  Returns {bucket_bytes: transport}."""
    out = {}
    for nbytes in sorted({_size_bucket(int(b)) for b in sizes_bytes}):
        out[nbytes] = decide_transport(nbytes, g, compressed=compressed)
    return out


def transport_cache_snapshot() -> dict:
    """Copy of the decision cache, JSON-friendly keys."""
    return {f"compressed={k[0]},bytes={k[1]},g={k[2]}": dict(v)
            for k, v in sorted(_TRANSPORT_CACHE.items())}


def dump_transport_cache(path: str) -> None:
    """Persist the decision cache (the CI bench uploads it for debugging)."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(transport_cache_snapshot(), f, indent=2, sort_keys=True)


def load_transport_cache(snapshot: dict, *, overwrite: bool = False) -> int:
    """Inverse of ``transport_cache_snapshot``: install persisted decisions
    (e.g. the ones a checkpoint carried in its ``extra``) so a RESUMED run
    reuses the original run's measured transports instead of re-measuring —
    which keeps the restarted backward scan's collective schedule, and
    therefore its numerics, identical to the killed run's.  Returns the
    number of entries installed; malformed entries are skipped."""
    n = 0
    for key, entry in (snapshot or {}).items():
        try:
            parts = dict(p.split("=", 1) for p in key.split(","))
            k = (parts["compressed"] == "True", int(parts["bytes"]),
                 int(parts["g"]))
            transport = entry["transport"]
        except (KeyError, ValueError, AttributeError, TypeError):
            continue
        if transport not in TRANSPORTS:
            continue
        if not overwrite and k in _TRANSPORT_CACHE:
            continue
        _TRANSPORT_CACHE[k] = {"transport": transport,
                               "source": f"restored:{entry.get('source', '?')}",
                               "us": dict(entry.get("us") or {})}
        n += 1
    return n


def clear_transport_cache() -> None:
    _TRANSPORT_CACHE.clear()


def _ring_perm(g: int) -> Tuple[Tuple[int, int], ...]:
    return tuple((i, (i + 1) % g) for i in range(g))


def _seg(chunks: Array, i) -> Array:
    """chunks[i % g] with a traced index."""
    g = chunks.shape[0]
    return lax.dynamic_index_in_dim(chunks, jnp.mod(i, g), 0, keepdims=False)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AsyncHandle:
    """An in-flight all-reduce.  Pytree (scan-carry safe): ``arrays`` are
    the in-flight chunks, everything else is static metadata."""

    arrays: Tuple[Array, ...]
    kind: str                      # "identity" | "dense" | "compressed"
    axis: Optional[str]
    g: int
    shape: Tuple[int, ...]
    dtype: object
    n_buckets: int

    def tree_flatten(self):
        return (tuple(self.arrays),
                (self.kind, self.axis, self.g, self.shape, self.dtype,
                 self.n_buckets))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tuple(children), *aux)


def _to_chunks(x: Array, g: int, n_buckets: int) -> Array:
    """[...] -> [n_buckets, g, c] zero-padded chunk view (f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    c = -(-flat.size // (g * n_buckets))
    flat = jnp.pad(flat, (0, g * n_buckets * c - flat.size))
    # bucket-major so each bucket holds a contiguous [g, c] ring layout
    return flat.reshape(n_buckets, g, c)


def _from_chunks(chunks: Array, shape, dtype) -> Array:
    n = 1
    for d in shape:
        n *= d
    return chunks.reshape(-1)[:n].reshape(shape).astype(dtype)


def _require_single_process() -> None:
    if jax.process_count() > 1:
        raise NotImplementedError(
            "the ppermute ring assumes a single-process device group, but "
            "this runtime spans multiple processes; force the fused "
            "collective instead (transport='psum' or REPRO_TRANSPORT=psum) "
            "until the ring hops are topology-aware")


def _resolve_transport(transport: str, nbytes: int, g: int,
                       compressed: bool) -> str:
    """'auto' consults the decision cache/model (and the REPRO_TRANSPORT
    override); an explicit transport= argument wins over everything.
    ``scatter`` degrades to ``psum`` on the compressed path (the codec
    blocks have no 1/g segment split)."""
    if transport == "auto":
        return decide_transport(int(nbytes), g, compressed=compressed,
                                allow_measure=False)
    if transport not in TRANSPORTS:
        raise ValueError(f"transport={transport!r} not in "
                         f"{TRANSPORTS + ('auto',)}")
    return "psum" if (compressed and transport == "scatter") else transport


def _identity_handle(x: Array) -> AsyncHandle:
    return AsyncHandle((x,), "identity", None, 1, tuple(x.shape), x.dtype, 1)


# ---------------------------------------------------------------------------
# scatter transport: native reduce-scatter / all-gather over 1/g chunks
#
# The payload is viewed flat, zero-padded to g equal chunks; device d owns
# chunk d (``lax.psum_scatter`` row order == ``lax.all_gather`` row order ==
# axis index).  The point of the split is that the chunk is a real 1/g
# SHARD the caller can run the optimizer update on before gathering — the
# ZeRO-style sharded update ``core.taxonn`` uses for elementwise
# optimizers — so the per-device update traffic drops by (g-1)/g while the
# wire bytes match one fused psum.
# ---------------------------------------------------------------------------

def _chunk_len(shape, g: int) -> int:
    n = 1
    for d in shape:
        n *= d
    return -(-n // g)


def _flat_padded(x: Array, g: int) -> Array:
    """[...] -> [g, c] zero-padded flat f32 view (pad skipped when the
    size divides evenly — the common dW-leaf case — so XLA sees a pure
    reshape it can fuse instead of a materialized pad copy)."""
    flat = x.astype(jnp.float32).reshape(-1)
    c = _chunk_len(x.shape, g)
    if g * c != flat.size:
        flat = jnp.pad(flat, (0, g * c - flat.size))
    return flat.reshape(g, c)


def shard_chunk(x: Array, axis, g: int) -> Array:
    """This device's [c] chunk of the padded flat view of ``x`` (no
    collective) — the params/opt-state side of a sharded update."""
    return _seg(_flat_padded(x, g), lax.axis_index(axis))


def reduce_scatter_chunk(x: Array, axis, g: int) -> Array:
    """Native reduce-scatter: the fully reduced [c] chunk this device owns
    (f32).  Chunk order matches ``shard_chunk``/``all_gather_chunks``."""
    return lax.psum_scatter(_flat_padded(x, g), axis,
                            scatter_dimension=0, tiled=False)


def all_gather_chunks(chunk: Array, axis, g: int, shape, dtype) -> Array:
    """Inverse of the chunk split: gather every device's [c] chunk and
    restore the original shape/dtype (padding dropped)."""
    full = lax.all_gather(chunk, axis, tiled=True)
    n = 1
    for d in shape:
        n *= d
    return full[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# dense ring: start = reduce-scatter phase, wait = all-gather phase
# ---------------------------------------------------------------------------

def _reduce_scatter(bucket: Array, axis: str, g: int, hop) -> Array:
    """One bucket [g, c] -> this device's reduced shard [c] after g-1 hops."""
    idx = lax.axis_index(axis)
    acc = _seg(bucket, idx)
    for s in range(1, g):
        acc = hop(acc)
        acc = acc + _seg(bucket, idx - s)
    return acc                     # device d owns reduced segment (d+1) % g


def _all_gather_ring(shard: Array, axis: str, g: int) -> Array:
    """Reduced shard [c] (segment (d+1)%g on device d) -> full [g, c]."""
    perm = _ring_perm(g)
    idx = lax.axis_index(axis)
    c = shard.shape[0]
    out = jnp.zeros((g, c), shard.dtype)
    out = lax.dynamic_update_index_in_dim(out, shard, jnp.mod(idx + 1, g), 0)
    cur = shard
    for s in range(1, g):
        cur = lax.ppermute(cur, axis, perm)
        # arrived from device d-s, which owned segment (d-s+1) % g
        out = lax.dynamic_update_index_in_dim(out, cur,
                                              jnp.mod(idx - s + 1, g), 0)
    return out


# ---------------------------------------------------------------------------
# compressed ring: decompress-add-recompress reduce-scatter + all-gather
# ---------------------------------------------------------------------------

def _compressed_reduce_scatter(x: Array, axis, g: int,
                               hop) -> Tuple[Array, Array]:
    """Reduce-scatter ``x`` over the ring in the int8 wire format.

    Each hop moves ONE compressed 1/g segment (payload + block scales) —
    (g-1)/g fewer wire bytes per hop than circulating the whole compressed
    buffer — at the price of a decompress-add-recompress at every hop
    (NeuroTrainer's in-transit reduce).  Error accounting vs
    ``collectives.compressed_psum`` (which compresses each contribution
    exactly once): every compression event adds at most one codec
    half-step ``block_absmax / 254``; a segment's reduction chain here has
    g-1 in-ring compressions plus the final shard compression, and the
    reference path has g of its own, so the divergence is bounded by
    ``(2g - 2) * max_block_absmax / 254`` per element (absmax of the
    largest partial sum).  Returns this device's fully reduced compressed
    segment ``(payload int8[c], scales f32[c/BLOCK])`` — segment
    ``(d+1) % g`` on device d, the dense-ring convention.
    """
    flat = x.astype(jnp.float32).reshape(-1)
    c = -(-flat.size // g)
    c = -(-c // BLOCK) * BLOCK     # whole scale blocks per segment
    flat = jnp.pad(flat, (0, g * c - flat.size))
    chunks = flat.reshape(g, c)
    idx = lax.axis_index(axis)
    acc = _seg(chunks, idx)
    for s in range(1, g):
        payload, scales = compress_int8(acc)
        payload, scales = hop(payload), hop(scales)
        acc = decompress_int8(payload, scales, (c,), jnp.float32)
        acc = acc + _seg(chunks, idx - s)
    return compress_int8(acc)


def _compressed_all_gather(payload: Array, scales: Array, axis, g: int,
                           shape, dtype) -> Array:
    """All-gather the reduced compressed segments and decompress."""
    perm = _ring_perm(g)
    idx = lax.axis_index(axis)
    c = payload.shape[0]
    full_p = jnp.zeros((g, c), payload.dtype)
    full_s = jnp.zeros((g, c // BLOCK), scales.dtype)

    def place(fp, fs, p, sc, seg):
        fp = lax.dynamic_update_index_in_dim(fp, p, seg, 0)
        fs = lax.dynamic_update_index_in_dim(fs, sc, seg, 0)
        return fp, fs

    full_p, full_s = place(full_p, full_s, payload, scales,
                           jnp.mod(idx + 1, g))
    cur_p, cur_s = payload, scales
    for s in range(1, g):
        cur_p = lax.ppermute(cur_p, axis, perm)
        cur_s = lax.ppermute(cur_s, axis, perm)
        # arrived from device d-s, which owned segment (d-s+1) % g
        full_p, full_s = place(full_p, full_s, cur_p, cur_s,
                               jnp.mod(idx - s + 1, g))
    out = decompress_int8(full_p.reshape(-1), full_s.reshape(-1),
                          (g * c,), jnp.float32)
    n = 1
    for d in shape:
        n *= d
    return out[:n].reshape(shape).astype(dtype)


def all_reduce_start(x: Array, axes: Iterable[str] = (), *,
                     compressed: bool = False,
                     num_replicas: Optional[int] = None,
                     num_buckets: Optional[int] = None,
                     dummy: bool = False,
                     transport: str = "auto") -> AsyncHandle:
    """Begin an all-reduce of ``x`` over the named mesh axes.

    Multi-axis groups ring over the combined axes (``lax.ppermute`` accepts
    the axis tuple and flattens it to one logical ring).  Returns a handle
    whose in-flight arrays are what must travel the scan carry.

    With no axes (or a group of one) there is nothing to move: the start
    short-circuits to a no-op identity handle whose ``wait`` returns ``x``
    bit-exactly (the compressed form carries the codec round-trip of ``x``,
    times ``num_replicas`` when an explicit no-mesh override simulates a
    replicated sum, matching ``collectives.compressed_psum``), so the
    overlapped scan stays bit-identical to the blocking one on one device.

    ``transport`` is ``"auto"`` (per-bucket autotuner decision, see
    ``decide_transport``), ``"ring"``, or ``"psum"``; ``"psum"`` issues the
    blocking fused collective at start and returns an already-complete
    handle.  A ring spanning a multi-process runtime raises
    ``NotImplementedError`` up front.

    ``dummy=True`` skips the start-phase hops/collective and returns a
    handle with the array shapes/dtypes a real start would produce — the
    overlapped scan's warm-up carry, built without burning g-1 hops per
    bucket on garbage.  The wait side needs no flag: it runs uniformly
    inside the scan.
    """
    axes = tuple(axes)
    g = group_size(axes, num_replicas)
    if not axes or g == 1:
        if compressed:
            # the blocking wire-format numerics, kept in ONE place
            x = compressed_psum(x, (), num_replicas=num_replicas)
        return _identity_handle(x)
    transport = _resolve_transport(
        transport, x.size * jnp.dtype(x.dtype).itemsize, g, compressed)
    axis = axes if len(axes) > 1 else axes[0]
    if transport == "psum":
        if dummy:
            return _identity_handle(x)
        out = (compressed_psum(x, axes, num_replicas=num_replicas)
               if compressed else lax.psum(x, axes))
        return _identity_handle(out)
    if transport == "scatter":
        # native reduce-scatter at start; the carry holds the 1/g reduced
        # chunk and wait all-gathers it (dummy: slice this device's chunk
        # locally so the warm-up carry has the right shape, no collective)
        chunk = (shard_chunk(x, axis, g) if dummy
                 else reduce_scatter_chunk(x, axis, g))
        return AsyncHandle((chunk,), "scatter", axis, g, tuple(x.shape),
                           x.dtype, 1)
    _require_single_process()
    hop_perm = _ring_perm(g)

    def hop(v):
        return v if dummy else lax.ppermute(v, axis, hop_perm)

    if compressed:
        payload, scales = _compressed_reduce_scatter(x, axis, g, hop)
        return AsyncHandle((payload, scales), "compressed", axis, g,
                           tuple(x.shape), x.dtype, 1)
    n_buckets = _num_buckets(x.size * 4, num_buckets)
    chunks = _to_chunks(x, g, n_buckets)
    shards = tuple(_reduce_scatter(chunks[b], axis, g, hop)
                   for b in range(n_buckets))
    return AsyncHandle(shards, "dense", axis, g, tuple(x.shape), x.dtype,
                       n_buckets)


def all_reduce_wait(handle: AsyncHandle) -> Array:
    """Complete an in-flight all-reduce and return the elementwise sum
    (identical on every ring member)."""
    if handle.kind == "identity":
        return handle.arrays[0]
    if handle.kind == "scatter":
        return all_gather_chunks(handle.arrays[0], handle.axis, handle.g,
                                 handle.shape, handle.dtype)
    if handle.kind == "compressed":
        payload, scales = handle.arrays
        return _compressed_all_gather(payload, scales, handle.axis,
                                      handle.g, handle.shape, handle.dtype)
    assert handle.kind == "dense", handle.kind
    gathered = jnp.stack([_all_gather_ring(s, handle.axis, handle.g)
                          for s in handle.arrays])
    return _from_chunks(gathered, handle.shape, handle.dtype)


def ring_all_reduce(x: Array, axes: Iterable[str] = (), *,
                    compressed: bool = False,
                    num_replicas: Optional[int] = None,
                    num_buckets: Optional[int] = None,
                    transport: str = "ring") -> Array:
    """Blocking convenience wrapper: ``wait(start(x))`` in one call.

    Defaults to ``transport="ring"`` (the wrapper exists to exercise the
    ring; pass ``"auto"`` to go through the autotuner)."""
    return all_reduce_wait(all_reduce_start(
        x, axes, compressed=compressed, num_replicas=num_replicas,
        num_buckets=num_buckets, transport=transport))


# ---------------------------------------------------------------------------
# tree-level API (the backward scan reduces one layer's dW tree per step)
# ---------------------------------------------------------------------------

def _is_handle(x) -> bool:
    return isinstance(x, AsyncHandle)


def resolve_leaf_transports(tree, axes: Iterable[str] = (), *,
                            compressed: bool = False,
                            num_replicas: Optional[int] = None,
                            transport: str = "auto") -> list:
    """The STATIC per-leaf transport decisions ``tree_all_reduce_start``
    would make for ``tree`` (flatten order), resolved from leaf byte sizes
    alone.  Decisions are plain Python strings, so callers can shape their
    program around them at trace time — ``core.taxonn`` uses this to give
    blocking-transport leaves a same-iteration update (and scatter leaves
    a sharded one) while only ring leaves ride the depth pipeline."""
    axes = tuple(axes)
    g = group_size(axes, num_replicas)
    if not axes or g == 1:
        return ["psum" for _ in jax.tree.leaves(tree)]

    def nbytes(x):        # works for arrays and ShapeDtypeStructs alike
        n = 1
        for d in x.shape:
            n *= int(d)
        return n * jnp.dtype(x.dtype).itemsize
    return [_resolve_transport(transport, nbytes(x), g, compressed)
            for x in jax.tree.leaves(tree)]


def tree_all_reduce_start(tree, axes: Iterable[str] = (), *,
                          compressed: bool = False,
                          num_replicas: Optional[int] = None,
                          num_buckets: Optional[int] = None,
                          dummy: bool = False,
                          transport: str = "auto"):
    """Start one all-reduce per leaf; returns a tree of AsyncHandles.

    Dense leaves whose resolved transport is ``"psum"`` are FUSED into one
    variadic ``lax.psum`` over all of them — a single rendezvous per call
    (per layer, in the backward scan) instead of one per leaf; XLA binds a
    pytree psum as one all-reduce op with variadic operands.  Ring leaves
    (and the compressed path, whose wire format is already one buffer per
    leaf) start individually.
    """
    axes = tuple(axes)
    g = group_size(axes, num_replicas)
    if not axes or g == 1 or compressed:
        return jax.tree.map(
            lambda x: all_reduce_start(x, axes, compressed=compressed,
                                       num_replicas=num_replicas,
                                       num_buckets=num_buckets, dummy=dummy,
                                       transport=transport),
            tree)
    leaves, treedef = jax.tree.flatten(tree)
    decisions = [_resolve_transport(
        transport, x.size * jnp.dtype(x.dtype).itemsize, g, False)
        for x in leaves]
    handles: list = [None] * len(leaves)
    fuse = [i for i, d in enumerate(decisions) if d == "psum"]
    if fuse:
        if dummy:
            reduced = tuple(leaves[i] for i in fuse)
        else:
            reduced = lax.psum(tuple(leaves[i] for i in fuse), axes)
        for i, r in zip(fuse, reduced):
            handles[i] = _identity_handle(r)
    for i, d in enumerate(decisions):
        if d in ("ring", "scatter"):
            handles[i] = all_reduce_start(
                leaves[i], axes, compressed=False, num_replicas=num_replicas,
                num_buckets=num_buckets, dummy=dummy, transport=d)
    return jax.tree.unflatten(treedef, handles)


def tree_all_reduce_wait(handles):
    """Wait on a tree of AsyncHandles (as produced by tree_all_reduce_start)."""
    return jax.tree.map(all_reduce_wait, handles, is_leaf=_is_handle)
