"""Bucketed async ring all-reduce: chunked ``lax.ppermute`` + start/wait.

The blocking collectives in ``dist.collectives`` issue one monolithic op per
tensor; XLA is free to overlap it with independent compute, but the backward
scan gives it nothing independent to overlap WITH — the scan body consumes
the reduced dW immediately.  This module supplies the two pieces the
communication-overlapped backward scan (``core.taxonn.backward_stack`` with
``QuantPolicy.overlap="on"``) is built from:

  * a **ring all-reduce** decomposed into chunked ``lax.ppermute`` steps
    (CATERPILLAR's interleaved ring reduction, Li & Pedram 2017): the tensor
    is split into the ring's g segments and, optionally, ``num_buckets``
    independent bucket streams, so each hop moves a small chunk the
    scheduler can interleave with MXU work instead of one long transfer;

  * an **AsyncHandle start/wait API** that splits the ring at its natural
    seam so the two halves can live in *different* scan iterations:

        handle = all_reduce_start(dW_i, axes)     # scan step i
        ... next layer's G-step/VJP compute ...   # overlap window
        dW_i   = all_reduce_wait(handle)          # scan step i+1

    ``AsyncHandle`` is a registered pytree, so it rides in the scan carry;
    every array it holds has a static shape, making the carry scan-legal.

Dense split: ``start`` runs the reduce-scatter phase (g-1 chunked hops) and
the carry holds only the 1/g-sized reduced shard; ``wait`` runs the
all-gather phase.  Compressed split (the int8 wire format of
``quant.compression``): ``start`` compresses and issues the first
circulate hop; ``wait`` finishes the remaining hops, decompressing and
accumulating as payloads arrive — the same per-replica
compress-once/decompress-g-times numerics as ``collectives.compressed_psum``
(addend set identical; only the summation order differs with ring position).

Axes semantics match ``collectives.compressed_psum``: ``axes`` must name
mesh axes of an enclosing ``shard_map`` body; empty axes (or a group of
one) degrade to the identity — ``wait(start(x)) == x`` bit-exactly, which
is what makes the overlapped scan a pure *schedule* change on one device.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

from repro.util import jaxcompat as _jaxcompat  # noqa: F401  (installs shims)

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.collectives import compressed_psum
from repro.quant.compression import compress_int8, decompress_int8

Array = jax.Array

# Auto-bucketing: one bucket per this many payload bytes (capped) so large
# dW tensors become several independent ring streams whose chunks the
# scheduler can interleave, while small tensors stay single-stream.
BUCKET_BYTES = 1 << 20
MAX_BUCKETS = 4


def group_size(axes: Iterable[str], num_replicas: Optional[int] = None) -> int:
    """Resolve the reduction-group size for named mesh axes.

    ``num_replicas`` overrides (callers inside a ``shard_map`` body know
    their mesh); otherwise the ambient (abstract) mesh is consulted.
    """
    axes = tuple(axes)
    if num_replicas is not None:
        return int(num_replicas)
    if not axes:
        return 1
    mesh = jax.sharding.get_abstract_mesh()
    shape = dict(getattr(mesh, "shape", {}) or {})
    n = 1
    for a in axes:
        if a not in shape:
            raise ValueError(
                f"cannot resolve ring-group size: axis {a!r} not in the "
                f"ambient mesh {tuple(shape)}; pass num_replicas= explicitly")
        n *= shape[a]
    return n


def _num_buckets(nbytes: int, num_buckets: Optional[int]) -> int:
    if num_buckets is not None:
        return max(1, int(num_buckets))
    return max(1, min(MAX_BUCKETS, nbytes // BUCKET_BYTES))


def _ring_perm(g: int) -> Tuple[Tuple[int, int], ...]:
    return tuple((i, (i + 1) % g) for i in range(g))


def _seg(chunks: Array, i) -> Array:
    """chunks[i % g] with a traced index."""
    g = chunks.shape[0]
    return lax.dynamic_index_in_dim(chunks, jnp.mod(i, g), 0, keepdims=False)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AsyncHandle:
    """An in-flight all-reduce.  Pytree (scan-carry safe): ``arrays`` are
    the in-flight chunks, everything else is static metadata."""

    arrays: Tuple[Array, ...]
    kind: str                      # "identity" | "dense" | "compressed"
    axis: Optional[str]
    g: int
    shape: Tuple[int, ...]
    dtype: object
    n_buckets: int

    def tree_flatten(self):
        return (tuple(self.arrays),
                (self.kind, self.axis, self.g, self.shape, self.dtype,
                 self.n_buckets))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tuple(children), *aux)


def _to_chunks(x: Array, g: int, n_buckets: int) -> Array:
    """[...] -> [n_buckets, g, c] zero-padded chunk view (f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    c = -(-flat.size // (g * n_buckets))
    flat = jnp.pad(flat, (0, g * n_buckets * c - flat.size))
    # bucket-major so each bucket holds a contiguous [g, c] ring layout
    return flat.reshape(n_buckets, g, c)


def _from_chunks(chunks: Array, shape, dtype) -> Array:
    n = 1
    for d in shape:
        n *= d
    return chunks.reshape(-1)[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# dense ring: start = reduce-scatter phase, wait = all-gather phase
# ---------------------------------------------------------------------------

def _reduce_scatter(bucket: Array, axis: str, g: int, hop) -> Array:
    """One bucket [g, c] -> this device's reduced shard [c] after g-1 hops."""
    idx = lax.axis_index(axis)
    acc = _seg(bucket, idx)
    for s in range(1, g):
        acc = hop(acc)
        acc = acc + _seg(bucket, idx - s)
    return acc                     # device d owns reduced segment (d+1) % g


def _all_gather_ring(shard: Array, axis: str, g: int) -> Array:
    """Reduced shard [c] (segment (d+1)%g on device d) -> full [g, c]."""
    perm = _ring_perm(g)
    idx = lax.axis_index(axis)
    c = shard.shape[0]
    out = jnp.zeros((g, c), shard.dtype)
    out = lax.dynamic_update_index_in_dim(out, shard, jnp.mod(idx + 1, g), 0)
    cur = shard
    for s in range(1, g):
        cur = lax.ppermute(cur, axis, perm)
        # arrived from device d-s, which owned segment (d-s+1) % g
        out = lax.dynamic_update_index_in_dim(out, cur,
                                              jnp.mod(idx - s + 1, g), 0)
    return out


def all_reduce_start(x: Array, axes: Iterable[str] = (), *,
                     compressed: bool = False,
                     num_replicas: Optional[int] = None,
                     num_buckets: Optional[int] = None,
                     dummy: bool = False) -> AsyncHandle:
    """Begin an all-reduce of ``x`` over the named mesh axes.

    Multi-axis groups ring over the combined axes (``lax.ppermute`` accepts
    the axis tuple and flattens it to one logical ring).  Returns a handle
    whose in-flight arrays are what must travel the scan carry.

    With no axes (or a group of one) there is nothing to move, but the
    handle still reproduces the matching ``collectives.compressed_psum``
    numerics: the compressed form carries the codec round-trip of ``x``
    (times ``num_replicas`` when an explicit no-mesh override simulates a
    replicated sum), so the overlapped scan stays bit-identical to the
    blocking one on a single device.

    ``dummy=True`` skips the start-phase hops and returns the handle a
    start on an ALL-ZERO ``x`` would produce (every partial sum is zero),
    with identical array shapes/dtypes — the overlapped scan's warm-up
    carry, built without burning g-1 hops per bucket on garbage.  The wait
    side needs no flag: it runs uniformly inside the scan.
    """
    axes = tuple(axes)
    g = group_size(axes, num_replicas)
    hop_perm = _ring_perm(g)

    def hop(v):
        return v if dummy else lax.ppermute(v, axis, hop_perm)

    if not axes or g == 1:
        if compressed:
            # the blocking wire-format numerics, kept in ONE place
            x = compressed_psum(x, (), num_replicas=num_replicas)
        return AsyncHandle((x,), "identity", None, 1, tuple(x.shape),
                           x.dtype, 1)
    axis = axes if len(axes) > 1 else axes[0]
    if compressed:
        payload, scales = compress_int8(x)
        acc = decompress_int8(payload, scales, x.shape, jnp.float32)
        payload = hop(payload)                           # first hop in flight
        scales = hop(scales)
        return AsyncHandle((acc, payload, scales), "compressed", axis, g,
                           tuple(x.shape), x.dtype, 1)
    n_buckets = _num_buckets(x.size * 4, num_buckets)
    chunks = _to_chunks(x, g, n_buckets)
    shards = tuple(_reduce_scatter(chunks[b], axis, g, hop)
                   for b in range(n_buckets))
    return AsyncHandle(shards, "dense", axis, g, tuple(x.shape), x.dtype,
                       n_buckets)


def all_reduce_wait(handle: AsyncHandle) -> Array:
    """Complete an in-flight all-reduce and return the elementwise sum
    (identical on every ring member)."""
    if handle.kind == "identity":
        return handle.arrays[0]
    if handle.kind == "compressed":
        acc, payload, scales = handle.arrays
        perm = _ring_perm(handle.g)
        for s in range(1, handle.g):
            acc = acc + decompress_int8(payload, scales, handle.shape,
                                        jnp.float32)
            if s < handle.g - 1:
                payload = lax.ppermute(payload, handle.axis, perm)
                scales = lax.ppermute(scales, handle.axis, perm)
        return acc.astype(handle.dtype)
    assert handle.kind == "dense", handle.kind
    gathered = jnp.stack([_all_gather_ring(s, handle.axis, handle.g)
                          for s in handle.arrays])
    return _from_chunks(gathered, handle.shape, handle.dtype)


def ring_all_reduce(x: Array, axes: Iterable[str] = (), *,
                    compressed: bool = False,
                    num_replicas: Optional[int] = None,
                    num_buckets: Optional[int] = None) -> Array:
    """Blocking convenience wrapper: ``wait(start(x))`` in one call."""
    return all_reduce_wait(all_reduce_start(
        x, axes, compressed=compressed, num_replicas=num_replicas,
        num_buckets=num_buckets))


# ---------------------------------------------------------------------------
# tree-level API (the backward scan reduces one layer's dW tree per step)
# ---------------------------------------------------------------------------

def _is_handle(x) -> bool:
    return isinstance(x, AsyncHandle)


def tree_all_reduce_start(tree, axes: Iterable[str] = (), *,
                          compressed: bool = False,
                          num_replicas: Optional[int] = None,
                          num_buckets: Optional[int] = None,
                          dummy: bool = False):
    """Start one all-reduce per leaf; returns a tree of AsyncHandles."""
    return jax.tree.map(
        lambda x: all_reduce_start(x, axes, compressed=compressed,
                                   num_replicas=num_replicas,
                                   num_buckets=num_buckets, dummy=dummy),
        tree)


def tree_all_reduce_wait(handles):
    """Wait on a tree of AsyncHandles (as produced by tree_all_reduce_start)."""
    return jax.tree.map(all_reduce_wait, handles, is_leaf=_is_handle)
