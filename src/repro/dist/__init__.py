"""repro.dist — sharding, collectives, pipelining, and HLO accounting.

The distributed-execution layer the models / engine / launchers program
against:

  api          activation-sharding rules, perf options, ``constrain``
  sharding     parameter / optimizer / batch / decode-state PartitionSpecs
  collectives  dense + int8-compressed tree all-reduce (gradient psum)
  async_collectives  bucketed ppermute ring all-reduce with an AsyncHandle
               start/wait API — the overlapped backward scan's transport
  pipeline     pipeline-schedule subsystem: GPipe / 1F1B / interleaved-1F1B
               tick tables + the exact differentiable microbatch pipeline
               (and the engine's stage-sharded execution path)
  hlo_analysis compiled-artifact FLOPs/bytes/collective extraction (async
               pair-aware, replica-group byte attribution), overlap_fraction
               + roofline
"""
from repro.util import jaxcompat as _jaxcompat  # noqa: F401  (installs shims)
