"""PartitionSpec assignment for parameters, optimizer state, batches, and
decode state.

Policy (megatron-style 2D: data axes x "model"):

  * embedding [V, D]          -> vocab-sharded over "model" (the CE head is
                                 vocab-parallel; the embed lookup psums)
  * attention q/k/v [D, H, h] -> head-sharded over "model"
  * attention out  [H, h, D]  -> head-sharded (row-parallel: one psum/block)
  * MLP up/gate [D, F]        -> column-parallel; down [F, D] row-parallel
  * MoE expert stacks [E,D,F] -> expert-parallel when E divides the model
                                 axis, else F-sharded (TP inside the expert)
  * vectors / norms / biases  -> replicated
  * anything unrecognized     -> replicated (always correct, never wrong)

Every rule is divisibility-guarded: a dim that doesn't divide the axis size
falls back to replicated instead of uneven sharding, so the same code
serves the 2-device test meshes and the 512-chip production mesh.

Stacked (scanned) parameters carry a leading layer axis; rules address
dims from the END so they apply to both stacked and unstacked leaves.
"""
from __future__ import annotations


from repro.util import jaxcompat as _jaxcompat  # noqa: F401  (installs shims)

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _is_pspec(x) -> bool:
    return isinstance(x, P)


def to_named(pspecs, mesh):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=_is_pspec)


def replicated(specs, mesh):
    """Fully-replicated NamedSharding tree matching ``specs``' structure."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), specs)


def _model_size(mesh) -> int:
    return dict(mesh.shape).get("model", 1)


def _batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axes_size(mesh, axes) -> int:
    shape = dict(mesh.shape)
    n = 1
    for a in axes:
        n *= shape[a]
    return n


def _spec(ndim: int, dim_from_end: int, axis: str) -> P:
    """P with ``axis`` at position ndim-dim_from_end, None elsewhere."""
    entries = [None] * ndim
    entries[ndim - dim_from_end] = axis
    return P(*entries)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _param_spec(path_names, leaf_name: str, shape, m: int) -> P:
    nd = len(shape)

    def ok(dim_from_end: int) -> bool:
        return nd >= dim_from_end and shape[nd - dim_from_end] % m == 0

    if m <= 1 or nd == 0:
        return P()

    in_moe = "moe" in path_names and "shared" not in path_names

    if leaf_name == "embed" and nd == 2:
        return _spec(nd, 2, "model") if ok(2) else P()
    if leaf_name == "lm_head" and nd == 2:
        return _spec(nd, 1, "model") if ok(1) else P()

    if leaf_name in ("wq", "wk", "wv") and nd >= 3:
        return _spec(nd, 2, "model") if ok(2) else P()     # [.., D, H, hd]
    if leaf_name in ("bq", "bk", "bv") and nd >= 2:
        return _spec(nd, 2, "model") if ok(2) else P()     # [.., H, hd]
    if leaf_name == "wo" and nd >= 3:
        return _spec(nd, 3, "model") if ok(3) else P()     # [.., H, hd, D]

    # MLA projections
    if leaf_name in ("w_uk", "w_uv") and nd >= 3:
        return _spec(nd, 2, "model") if ok(2) else P()     # [.., r, H, hd]

    if in_moe:
        if leaf_name in ("w_gate", "w_up") and nd >= 3:    # [.., E, D, F]
            if ok(3):
                return _spec(nd, 3, "model")
            return _spec(nd, 1, "model") if ok(1) else P()
        if leaf_name == "w_down" and nd >= 3:              # [.., E, F, D]
            if ok(3):
                return _spec(nd, 3, "model")
            return _spec(nd, 2, "model") if ok(2) else P()
        if leaf_name == "router":
            return P()
    else:
        if leaf_name in ("w_gate", "w_up") and nd >= 2:    # [.., D, F]
            return _spec(nd, 1, "model") if ok(1) else P()
        if leaf_name == "w_down" and nd >= 2:              # [.., F, D]
            return _spec(nd, 2, "model") if ok(2) else P()

    # Mamba projections: shard the d_inner columns (see ssm.init_mamba)
    if leaf_name in ("w_z", "w_x") and nd >= 2:
        return _spec(nd, 1, "model") if ok(1) else P()
    if leaf_name == "out_proj" and nd >= 2:
        return _spec(nd, 2, "model") if ok(2) else P()

    return P()


def param_pspecs(cfg: ModelConfig, params, mesh):
    """PartitionSpec tree mirroring ``params`` (arrays or ShapeDtypeStructs)."""
    m = _model_size(mesh)

    def spec(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "name", k)))
                 for k in path]
        return _param_spec(names, names[-1] if names else "", leaf.shape, m)

    return jax.tree_util.tree_map_with_path(spec, params)


# ---------------------------------------------------------------------------
# Optimizer state
# ---------------------------------------------------------------------------

def opt_pspecs(cfg: ModelConfig, opt_specs, p_pspecs, mesh):
    """Specs for the train state: moment buffers inherit their parameter's
    spec; ``m_s`` (rowwise int8-momentum scales) drops the last dim."""
    def drop_last(s: P) -> P:
        return P(*tuple(s)[:-1]) if len(tuple(s)) else P()

    out = {}
    for key, state in opt_specs.items():
        pspec = p_pspecs[key]
        fields = {}
        for fname, sub in state.items():
            if fname == "m_s":
                fields[fname] = jax.tree.map(drop_last, pspec,
                                             is_leaf=_is_pspec)
            else:
                fields[fname] = pspec
        out[key] = fields
    return out


# ---------------------------------------------------------------------------
# Batches
# ---------------------------------------------------------------------------

def batch_pspecs(specs, mesh):
    """Shard dim 0 of every batch leaf over the data axes (divisibility-
    guarded); scalars and non-divisible leaves replicate."""
    baxes = _batch_axes(mesh)
    n = _axes_size(mesh, baxes)

    def spec(leaf):
        shape = leaf.shape
        if not baxes or not shape or shape[0] % n != 0:
            return P()
        entry = baxes[0] if len(baxes) == 1 else baxes
        return P(entry, *([None] * (len(shape) - 1)))

    return jax.tree.map(spec, specs)


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------

def decode_state_pspecs(cfg: ModelConfig, state_specs, mesh):
    """Serving-state specs: caches shard their batch dim over the data axes.

    Cache layouts (see serving/engine.py): plain families stack per-layer
    caches as [L, B, ...]; hybrid attention caches are [G, B, ...] and
    hybrid mamba caches [G, K, B, ...].  ``pos`` is a replicated scalar.
    """
    baxes = _batch_axes(mesh)
    n = _axes_size(mesh, baxes)
    entry = None if not baxes else (baxes[0] if len(baxes) == 1 else baxes)

    def spec(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "name", k)))
                 for k in path]
        shape = leaf.shape
        if entry is None or "pos" in names or len(shape) < 2:
            return P()
        bdim = 2 if "mamba" in names else 1
        if len(shape) <= bdim or shape[bdim] % n != 0:
            return P()
        entries = [None] * len(shape)
        entries[bdim] = entry
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec, state_specs)
