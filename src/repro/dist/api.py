"""Activation-sharding rules, perf options, and the ``constrain`` primitive.

The model code never names mesh axes directly.  It tags intermediate
activations with a *logical layout string* — one lowercase letter per array
dimension:

    b  batch                  (sharded over the data axes: ("pod",) "data")
    t  sequence / tokens      (sharded over "model" only under seq_parallel)
    d  d_model / feature      (replicated: the residual stream is TP-replicated)
    v  vocab                  (sharded over "model": vocab-parallel CE head)
    e  experts                (left to the partitioner; propagates from weights)
    c  expert capacity        (left to the partitioner)

``make_default_rules(batch_axes, seq_parallel=...)`` builds the table
mapping letters to mesh-axis assignments; ``activation_sharding_ctx(rules)``
installs it; ``constrain(x, "btd")`` applies the corresponding sharding
constraint — and is a guaranteed no-op outside a mesh/rules context, so
every pure-CPU unit test runs the exact same model code.

Perf options (``perf_options_ctx`` / ``perf_opt``) are trace-time feature
flags (seq_parallel, moe_rowcombine, ce_bf16, flash_attn, pad_heads) that
change layout/scheduling but never math — see tests/test_perf_options.py.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Iterable, Optional

from repro.util import jaxcompat as _jaxcompat  # noqa: F401  (installs shims)

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Array = jax.Array

# Sentinel for "leave this dimension to the partitioner".
UNCONSTRAINED = P.UNCONSTRAINED

_RULES: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "activation_sharding_rules", default=None)
_PERF: contextvars.ContextVar[frozenset] = contextvars.ContextVar(
    "perf_options", default=frozenset())


# ---------------------------------------------------------------------------
# Perf options
# ---------------------------------------------------------------------------

KNOWN_PERF_OPTS = frozenset({
    "seq_parallel", "pad_heads", "moe_rowcombine", "ce_bf16", "flash_attn",
})


@contextlib.contextmanager
def perf_options_ctx(opts: Iterable[str]):
    """Enable a set of §Perf options for the enclosed trace/compile."""
    opts = frozenset(opts)
    unknown = opts - KNOWN_PERF_OPTS
    if unknown:
        raise ValueError(f"unknown perf options: {sorted(unknown)}")
    token = _PERF.set(_PERF.get() | opts)
    try:
        yield
    finally:
        _PERF.reset(token)


def perf_opt(name: str) -> bool:
    """Is the named perf option active? (checked at trace time)"""
    return name in _PERF.get()


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def make_default_rules(batch_axes: Iterable[str],
                       seq_parallel: bool = False) -> dict:
    """Letter -> mesh-axis assignment table (see module docstring).

    ``batch_axes`` are the data-parallel mesh axes, e.g. ``("data",)`` or
    ``("pod", "data")``; the batch dimension shards over all of them.
    ``seq_parallel`` additionally shards the sequence dimension of the
    residual stream over "model" (Megatron sequence parallelism: the
    norm/residual work is 1/TP per device; the surrounding matmuls
    all-gather it back, which XLA overlaps with compute).
    """
    batch_axes = tuple(batch_axes)
    return {
        "b": batch_axes,
        "t": "model" if seq_parallel else None,
        "d": None,
        "v": "model",
        "e": UNCONSTRAINED,
        "c": UNCONSTRAINED,
        # paged-KV serving pool [L, N_blocks, block, kv_heads, head_dim]
        # tagged "lnshd": the block axis shards over the data axes (each
        # data shard owns a slice of the pool) and KV heads over "model"
        # (classic TP serving); layer / in-block slot / head_dim replicate
        "l": None,
        "n": batch_axes,
        "s": None,
        "h": "model",
    }


@contextlib.contextmanager
def activation_sharding_ctx(rules: Optional[dict]):
    """Install a rules table for ``constrain`` inside the block."""
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def current_rules() -> Optional[dict]:
    return _RULES.get()


# ---------------------------------------------------------------------------
# Mesh context
# ---------------------------------------------------------------------------

def current_mesh():
    """The ambient mesh (entered via ``jax.set_mesh``), or None."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return None
    if not getattr(mesh, "axis_names", ()):
        return None
    return mesh


def model_axis_size_ctx() -> int:
    """Size of the tensor-parallel "model" axis in the ambient mesh (1 if
    no mesh is set or the mesh has no model axis)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    return dict(mesh.shape).get("model", 1)


# ---------------------------------------------------------------------------
# constrain
# ---------------------------------------------------------------------------

def _axis_size(mesh_shape: dict, entry) -> int:
    if isinstance(entry, str):
        return mesh_shape[entry]
    n = 1
    for a in entry:
        n *= mesh_shape[a]
    return n


# When two letters in one tag claim the same mesh axis (e.g. "btv" under
# seq_parallel: 't' and 'v' both want "model"), the lower number wins and
# the loser replicates.  Vocab beats sequence: the CE head's masked-target
# reduction is collective-free only with V sharded (see lm.ce_from_weight).
_AXIS_PRIORITY = {"b": 0, "n": 0, "v": 1, "h": 1, "e": 2, "c": 2, "d": 3,
                  "t": 4, "l": 5, "s": 5}


def _spec_for(logical: str, ndim: int, rules: dict, mesh,
              shape) -> Optional[P]:
    """Build a PartitionSpec for ``logical`` against the ambient mesh.

    Rank adaptation: when the array has fewer dims than the tag (e.g. a
    [B, V] last-token logits tensor tagged "btv"), the first letter maps to
    dim 0 and the trailing letters to the trailing dims — squeezed middle
    dims drop out.  Axes missing from the mesh, already-used axes, and
    non-divisible dims degrade to None (replicated) rather than erroring,
    so one model codebase runs on any mesh topology.
    """
    if ndim < len(logical):
        logical = logical[0] + logical[len(logical) - (ndim - 1):] \
            if ndim >= 2 else logical[-1]
    elif ndim > len(logical):
        return None  # tag can't describe this array; skip the constraint

    mesh_axes = set(mesh.axis_names)
    mesh_shape = dict(mesh.shape)
    used: set = set()
    entries = [None] * len(logical)
    order = sorted(range(len(logical)),
                   key=lambda i: _AXIS_PRIORITY.get(logical[i], 5))
    for dim in order:
        entry = rules.get(logical[dim], UNCONSTRAINED)
        if entry is UNCONSTRAINED:
            entries[dim] = UNCONSTRAINED
            continue
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        axes = tuple(a for a in axes if a in mesh_axes and a not in used)
        if not axes:
            continue
        if shape[dim] % _axis_size(mesh_shape, axes) != 0:
            continue  # uneven shard: leave replicated
        used.update(axes)
        entries[dim] = axes[0] if len(axes) == 1 else axes
    return P(*entries)


def constrain(x: Array, logical: str) -> Array:
    """Constrain ``x`` to the sharding the active rules assign to the
    logical layout ``logical``.  No-op outside a mesh + rules context."""
    rules = _RULES.get()
    if rules is None:
        return x
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _spec_for(logical, x.ndim, rules, mesh, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
