"""Pipeline-schedule subsystem: GPipe, 1F1B, and interleaved-1F1B.

Two layers live here:

**Execution** — ``pipeline_apply(stage_params, x, body, mesh, schedule)``
runs M microbatches through S stages as pure differentiable JAX: one
``lax.scan`` over the forward diagonal (T = M + S - 1 ticks) with
predicated writes, so forward values AND gradients (via the scan's
transpose) equal the sequential reference exactly.  The pipeline value
``x`` is a pytree ([M, ...] leaves): side values ride the rotating buffer
with the activation — per-microbatch reduce-class accumulators (aux-loss
statistics a stage adds to) and the microbatch index itself, which stages
use to slice broadcast-class operands (an encoder-output fan-out) down to
their current microbatch.  Warm-up/drain ticks
compute on zero-filled garbage that is never written to the output.  The
schedule selects the *stage placement*: GPipe/1F1B pin stage s to pipe
device s; interleaved-1F1B assigns ``num_virtual`` non-contiguous virtual
stages per device (Megatron-style round-robin, stage s -> device s mod D)
by permuting the rotating buffer's storage order, which changes the
collective-permute pattern the "pipe" mesh axis sees.

**Cost model** — each ``Schedule`` builds a tick table (which (stage,
microbatch, fwd/bwd) unit runs on which device at which tick) under the
TaxoNN TDM frame model: one device-tick can co-issue one forward and one
backward unit, because the paper's time-division-multiplexed datapath
(``kernels.bp_fused_unit``) runs FP + BP + WU of one frame back-to-back on
the same PEs.  GPipe cannot co-issue — its loss barrier means no backward
work exists until every forward has drained — so its table is the forward
diagonal followed by the backward diagonal.  1F1B interleaves the two
diagonals in steady state and interleaved-1F1B additionally shrinks the
warm-up by splitting each device into virtual stages.  From the table each
schedule derives ``bubble_fraction(S, M)`` (idle device-ticks / total) and
``peak_activation_microbatches(S, M)`` (max in-flight forward activations
resident on one device) — the bubble/memory tradeoff GPipe vs 1F1B is
about.  ``(S-1)/(M+S-1)`` is GPipe's closed form (CATERPILLAR, Li &
Pedram 2017); 1F1B's fused frames land strictly below it for S >= 2.

See tests/test_pipeline_parallel.py for exactness and the bubble ordering,
and dist/hlo_analysis.py::per_tick_attribution for attributing compiled
collective-permute bytes to schedule ticks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple, Union

from repro.util import jaxcompat as _jaxcompat  # noqa: F401  (installs shims)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """Idle fraction of the GPipe schedule: (S-1) / (M + S - 1)."""
    s, m = num_stages, num_microbatches
    return (s - 1) / (m + s - 1)


# ---------------------------------------------------------------------------
# Tick tables (the cost model)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """One schedule instantiated at (S stages, M microbatches).

    ``fwd_tick[s, m]`` / ``bwd_tick[s, m]`` give the tick at which the
    forward / backward unit of microbatch m runs on stage s.  Everything
    else (bubble, peak memory) is derived from these two arrays.
    """
    num_stages: int
    num_microbatches: int
    num_devices: int
    num_virtual: int
    num_ticks: int
    fwd_tick: np.ndarray          # [S, M] int
    bwd_tick: np.ndarray          # [S, M] int
    busy_slots: int               # device-ticks with >= 1 unit issued
    bubble: float                 # 1 - busy / (num_ticks * num_devices)
    peak_activation_microbatches: int

    def stage_device(self, s: int) -> int:
        return s % self.num_devices


def _finish_plan(S: int, M: int, D: int, v: int, fwd: np.ndarray,
                 bwd: np.ndarray) -> SchedulePlan:
    """Derive span/bubble/peak-memory from the (fwd, bwd) tick arrays."""
    ticks = int(max(fwd.max(), bwd.max())) + 1
    # busy device-ticks: a fused (F, B) pair on one device is ONE busy slot
    busy = set()
    for s in range(S):
        for m in range(M):
            busy.add((s % D, int(fwd[s, m])))
            busy.add((s % D, int(bwd[s, m])))
    # peak in-flight activations per device: an activation is live from the
    # tick its forward issues until the tick its backward (the consumer)
    # issues
    peak = 0
    for d in range(D):
        stages = range(d, S, D)
        events = []                 # (+1 at fwd tick, -1 at bwd tick)
        for s in stages:
            for m in range(M):
                events.append((int(fwd[s, m]), 1))
                events.append((int(bwd[s, m]), -1))
        live = 0
        for _, delta in sorted(events):   # -1 sorts before +1 at equal ticks
            live += delta
            peak = max(peak, live)
    return SchedulePlan(
        num_stages=S, num_microbatches=M, num_devices=D, num_virtual=v,
        num_ticks=ticks, fwd_tick=fwd, bwd_tick=bwd, busy_slots=len(busy),
        bubble=1.0 - len(busy) / (ticks * D),
        peak_activation_microbatches=peak)


def _gpipe_plan(S: int, M: int) -> SchedulePlan:
    """All forwards, loss barrier, all backwards (two diagonals)."""
    fwd = np.zeros((S, M), np.int64)
    bwd = np.zeros((S, M), np.int64)
    t_flush = M + S - 1
    for s in range(S):
        for m in range(M):
            fwd[s, m] = m + s
            bwd[s, m] = t_flush + (S - 1 - s) + m
    return _finish_plan(S, M, S, 1, fwd, bwd)


def _one_f_one_b_plan(S: int, M: int) -> SchedulePlan:
    """Closed-form 1F1B on TDM fused frames: two interleaved diagonals.

    F(s, m) at tick s + m and B(s, m) at tick (2S-1-s) + m satisfy every
    dependency (F feeds forward one tick apart, B feeds backward one tick
    apart, and F(s, m) < B(s, m) since 2s < 2S-1), and in steady state a
    device co-issues one F and one B per tick — the paper's TDM frame.
    Span = M + 2S - 2 ticks after tick 0, so bubble = (S-1)/(M+2S-1) —
    strictly below GPipe's (S-1)/(M+S-1) for every S >= 2 — and in-flight
    activations at stage s cap at min(M, 2(S-s)-1) instead of GPipe's M.
    """
    s_idx = np.arange(S)[:, None]
    m_idx = np.arange(M)[None, :]
    fwd = np.broadcast_to(s_idx + m_idx, (S, M)).astype(np.int64)
    bwd = np.broadcast_to((2 * S - 1 - s_idx) + m_idx, (S, M)).astype(np.int64)
    return _finish_plan(S, M, S, 1, fwd, bwd)


def _interleaved_plan(S: int, M: int, v: int) -> SchedulePlan:
    """Greedy work-conserving simulation of interleaved-1F1B under the
    TDM fused-frame model: per tick a device issues at most one backward
    (lowest microbatch, deepest stage first) and one forward (subject to
    the per-stage in-flight cap that gives 1F1B its memory bound)."""
    D = S // v
    NOT_DONE = -1
    fwd = np.full((S, M), NOT_DONE, np.int64)
    bwd = np.full((S, M), NOT_DONE, np.int64)
    next_fwd = [0] * S                  # microbatches enter a stage in order
    next_bwd = [0] * S

    def fwd_ready(s: int, t: int) -> Optional[int]:
        m = next_fwd[s]
        if m >= M:
            return None
        if s > 0 and not (0 <= fwd[s - 1, m] < t):
            return None
        return m

    def bwd_ready(s: int, t: int) -> Optional[int]:
        m = next_bwd[s]
        if m >= M or not (0 <= fwd[s, m] < t):
            return None
        if s < S - 1 and not (0 <= bwd[s + 1, m] < t):
            return None
        return m

    def inflight(s: int) -> int:
        return next_fwd[s] - next_bwd[s]

    remaining = 2 * S * M
    t = 0
    while remaining:
        issued_any = False
        for relax_caps in (False, True):
            for d in range(D):
                stages = list(range(d, S, D))
                # one backward: lowest microbatch, deepest stage breaks ties
                cand = [(m, -s, s) for s in stages
                        for m in (bwd_ready(s, t),) if m is not None]
                b_issue = min(cand) if cand else None
                if b_issue is not None:
                    s = b_issue[2]
                    bwd[s, next_bwd[s]] = t
                    next_bwd[s] += 1
                    remaining -= 1
                    issued_any = True
                # one forward: earliest microbatch first, capped in-flight
                cand = [(m, s) for s in stages
                        for m in (fwd_ready(s, t),) if m is not None
                        and (relax_caps or inflight(s) < 2 * (S - s) - 1)]
                if cand:
                    s = min(cand)[1]
                    fwd[s, next_fwd[s]] = t
                    next_fwd[s] += 1
                    remaining -= 1
                    issued_any = True
            if issued_any:
                break
        assert issued_any, "1F1B simulation stalled (dependency bug)"
        t += 1
    return _finish_plan(S, M, D, v, fwd, bwd)


@functools.lru_cache(maxsize=None)
def _plan_cached(kind: str, S: int, M: int, v: int) -> SchedulePlan:
    if kind == "gpipe":
        return _gpipe_plan(S, M)
    if v == 1:
        return _one_f_one_b_plan(S, M)
    return _interleaved_plan(S, M, v)


# ---------------------------------------------------------------------------
# Schedule abstraction
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Schedule:
    """A pipeline schedule: stage placement + tick-table cost model."""
    name: str = "gpipe"
    num_virtual: int = 1          # virtual stages per device (interleaved)

    _kind = "gpipe"

    # -- validation / placement -------------------------------------------
    def validate(self, num_stages: int, num_microbatches: int = 1) -> None:
        if num_stages < 1 or num_microbatches < 1:
            raise ValueError(
                f"{self.name}: need num_stages >= 1 and num_microbatches >= "
                f"1, got S={num_stages}, M={num_microbatches}")
        if self.num_virtual < 1:
            raise ValueError(f"{self.name}: num_virtual must be >= 1, got "
                             f"{self.num_virtual}")
        if num_stages % self.num_virtual != 0:
            raise ValueError(
                f"{self.name}: num_stages={num_stages} does not divide into "
                f"num_virtual={self.num_virtual} virtual stages per device; "
                f"use a stage count divisible by the virtual-stage count")

    def num_devices(self, num_stages: int) -> int:
        return num_stages // self.num_virtual

    def stage_of_slot(self, num_stages: int) -> np.ndarray:
        """Storage order of the rotating buffer: slot j holds which stage.

        Device-major: with D devices and v virtual stages, slot (d*v + k)
        holds stage (k*D + d), so pinning the slot axis to the "pipe" mesh
        axis gives each device its round-robin virtual stages.
        """
        self.validate(num_stages)
        D = self.num_devices(num_stages)
        return np.add.outer(np.arange(D),
                            np.arange(self.num_virtual) * D).reshape(-1)

    # -- cost model --------------------------------------------------------
    def plan(self, num_stages: int, num_microbatches: int) -> SchedulePlan:
        self.validate(num_stages, num_microbatches)
        return _plan_cached(self._kind, num_stages, num_microbatches,
                            self.num_virtual)

    def bubble_fraction(self, num_stages: int, num_microbatches: int) -> float:
        """Idle fraction of device-ticks in this schedule's tick table."""
        return self.plan(num_stages, num_microbatches).bubble

    def peak_activation_microbatches(self, num_stages: int,
                                     num_microbatches: int) -> int:
        """Max forward activations simultaneously resident on one device."""
        return self.plan(num_stages,
                         num_microbatches).peak_activation_microbatches

    def peak_activation_bytes(self, num_stages: int, num_microbatches: int,
                              microbatch_bytes: int) -> int:
        """Peak per-device activation memory, given one stage's activation
        footprint for one microbatch."""
        return (self.peak_activation_microbatches(num_stages,
                                                  num_microbatches)
                * int(microbatch_bytes))

    def summary(self, num_stages: int, num_microbatches: int) -> Dict:
        p = self.plan(num_stages, num_microbatches)
        return {
            "schedule": self.name,
            "num_stages": p.num_stages,
            "num_microbatches": p.num_microbatches,
            "num_devices": p.num_devices,
            "num_virtual": p.num_virtual,
            "ticks": p.num_ticks,
            "bubble_fraction": p.bubble,
            "peak_activation_microbatches": p.peak_activation_microbatches,
        }


@dataclasses.dataclass(frozen=True)
class GPipeSchedule(Schedule):
    """All-forward / flush / all-backward; peak memory grows with M."""
    name: str = "gpipe"
    _kind = "gpipe"

    def validate(self, num_stages: int, num_microbatches: int = 1) -> None:
        if self.num_virtual != 1:
            raise ValueError("gpipe has no virtual stages; use the "
                             "interleaved schedule for num_virtual > 1")
        super().validate(num_stages, num_microbatches)

    def bubble_fraction(self, num_stages: int, num_microbatches: int) -> float:
        self.validate(num_stages, num_microbatches)
        return bubble_fraction(num_stages, num_microbatches)  # closed form


@dataclasses.dataclass(frozen=True)
class OneFOneBSchedule(Schedule):
    """PipeDream-flush 1F1B on TaxoNN TDM frames: steady-state ticks fuse
    one forward with one backward, bounding in-flight activations by ~S
    instead of M and shrinking the bubble below GPipe's."""
    name: str = "1f1b"
    _kind = "1f1b"

    def validate(self, num_stages: int, num_microbatches: int = 1) -> None:
        if self.num_virtual != 1:
            raise ValueError("1f1b runs one stage per device; use the "
                             "interleaved schedule for num_virtual > 1")
        super().validate(num_stages, num_microbatches)


@dataclasses.dataclass(frozen=True)
class Interleaved1F1BSchedule(Schedule):
    """1F1B with ``num_virtual`` round-robin virtual stages per device
    (Megatron-style): the warm-up diagonal spans D = S / v devices instead
    of S, trading bubble for more collective-permute hops per tick."""
    name: str = "interleaved"
    num_virtual: int = 2
    _kind = "1f1b"


SCHEDULES: Dict[str, Callable[..., Schedule]] = {
    "gpipe": GPipeSchedule,
    "1f1b": OneFOneBSchedule,
    "interleaved": Interleaved1F1BSchedule,
}


def get_schedule(spec: Union[str, Schedule, None] = "gpipe",
                 num_virtual: Optional[int] = None) -> Schedule:
    """Resolve a schedule name ("gpipe" | "1f1b" | "interleaved") or pass
    a ``Schedule`` instance through.  ``num_virtual`` overrides the
    virtual-stage count for the interleaved schedule."""
    if spec is None:
        spec = "gpipe"
    if isinstance(spec, Schedule):
        if num_virtual is not None and num_virtual != spec.num_virtual:
            return dataclasses.replace(spec, num_virtual=num_virtual)
        return spec
    if spec not in SCHEDULES:
        raise ValueError(f"unknown pipeline schedule {spec!r}; expected one "
                         f"of {tuple(SCHEDULES)}")
    kwargs = {}
    if num_virtual is not None:
        if spec != "interleaved" and num_virtual != 1:
            raise ValueError(f"schedule {spec!r} does not take virtual "
                             f"stages (num_virtual={num_virtual})")
        if spec == "interleaved":
            kwargs["num_virtual"] = num_virtual
    return SCHEDULES[spec](**kwargs)


# ---------------------------------------------------------------------------
# Execution (pure differentiable JAX)
# ---------------------------------------------------------------------------

def _stage_constrain(buf, mesh):
    """Pin the rotating buffer's slot axis to the "pipe" mesh axis."""
    if mesh is None or "pipe" not in getattr(mesh, "axis_names", ()):
        return buf
    if buf.shape[0] % dict(mesh.shape)["pipe"] != 0:
        return buf
    spec = P("pipe", *([None] * (buf.ndim - 1)))
    try:
        return jax.lax.with_sharding_constraint(
            buf, NamedSharding(mesh, spec))
    except Exception:  # eager call outside a partitionable context
        return buf


def _slot_maps(sched: Schedule, S: int) -> Tuple[np.ndarray, np.ndarray,
                                                 np.ndarray, bool]:
    stage_of_slot = sched.stage_of_slot(S)
    slot_of_stage = np.argsort(stage_of_slot)
    route = slot_of_stage[(stage_of_slot - 1) % S]   # dst slot <- src slot
    identity = bool((stage_of_slot == np.arange(S)).all())
    return stage_of_slot, slot_of_stage, route, identity


def pipeline_apply(stage_params, x, body: Callable,
                   mesh=None,
                   schedule: Union[str, Schedule, None] = "gpipe"):
    """Apply an S-stage pipeline to M microbatches under a schedule.

    stage_params : pytree whose leaves carry a leading stage axis [S, ...]
    x            : pytree whose leaves carry a leading microbatch axis
                   [M, microbatch...].  A bare array is the common case; a
                   pytree lets side values ride the rotating buffer with
                   the activation — e.g. a per-microbatch aux-loss
                   accumulator each stage adds to (reduce-class operand,
                   summed by the caller after the drain) or the microbatch
                   index itself, which stages use to slice broadcast-class
                   operands (an encoder output fan-out) down to their
                   current microbatch
    body         : body(stage_params_s, v) -> v', one stage on one
                   microbatch value; must preserve the value's structure
                   and leaf shapes so the result can recirculate
    mesh         : optional mesh with a "pipe" axis to pin stages to devices
    schedule     : "gpipe" | "1f1b" | "interleaved" or a Schedule; selects
                   the stage->device placement (interleaved permutes the
                   buffer storage so each device holds its round-robin
                   virtual stages) and the cost model reported by
                   ``Schedule.summary``.  All schedules compute the same
                   function: the result is bit-identical to running the
                   stages sequentially over each microbatch, and gradients
                   (the scan's transpose) match the sequential reference.

    Returns a pytree shaped like ``x`` ([M, microbatch...] leaves).
    """
    sched = get_schedule(schedule)
    S = jax.tree.leaves(stage_params)[0].shape[0]
    M = jax.tree.leaves(x)[0].shape[0]
    sched.validate(S, M)
    stage_of_slot, slot_of_stage, route, identity = _slot_maps(sched, S)
    in_slot = int(slot_of_stage[0])
    out_slot = int(slot_of_stage[S - 1])
    T = M + S - 1

    if identity:
        params_slots = stage_params
    else:                       # device-major storage for virtual stages
        gather = jnp.asarray(stage_of_slot)
        params_slots = jax.tree.map(lambda a: a[gather], stage_params)
        route_idx = jnp.asarray(route)

    def tick(carry, t):
        buf, outs = carry                    # buf [S, mb...]: slot inputs
        # feed microbatch t into stage 0's slot (garbage recirculates after
        # drain; its outputs fall past tick T and are never collected)
        t_in = jnp.clip(t, 0, M - 1)
        buf = jax.tree.map(
            lambda b, a: b.at[in_slot].set(jnp.where(
                t < M,
                lax.dynamic_index_in_dim(a, t_in, 0, keepdims=False),
                b[in_slot])),
            buf, x)
        buf = jax.tree.map(lambda b: _stage_constrain(b, mesh), buf)
        new = jax.vmap(body)(params_slots, buf)  # all slots, one tick
        # stage S-1's slot finished microbatch t-(S-1): write it out
        # (predicated — warm-up ticks produce garbage that must not touch
        # outs or grads)
        idx = t - (S - 1)
        idx_c = jnp.maximum(idx, 0)

        def write(o, n):
            cur = lax.dynamic_index_in_dim(o, idx_c, 0, keepdims=False)
            return lax.dynamic_update_index_in_dim(
                o, jnp.where(idx >= 0, n[out_slot], cur), idx_c, 0)

        outs = jax.tree.map(write, outs, new)
        # route: the slot holding stage s feeds the slot holding stage s+1
        # (identity placement lowers to the classic rotate-by-one)
        nxt = jax.tree.map(
            lambda n: jnp.roll(n, 1, axis=0) if identity else n[route_idx],
            new)
        return (nxt, outs), None

    buf0 = jax.tree.map(
        lambda a: jnp.zeros((S,) + a.shape[1:], a.dtype), x)
    outs0 = jax.tree.map(jnp.zeros_like, x)
    (_, outs), _ = lax.scan(tick, (buf0, outs0), jnp.arange(T))
    return outs
