"""GPipe-style microbatch pipeline parallelism (exact and differentiable).

``pipeline_apply(stage_params, x, body, mesh)`` runs M microbatches through
S stages using the rotating-buffer schedule: one ``lax.scan`` over
T = M + S - 1 ticks, where tick t runs stage s on microbatch t - s for all
stages at once (a single ``vmap`` over the stage axis) and then rotates the
activation buffer by one stage.  With the buffer constrained to the "pipe"
mesh axis the vmap'd stage work is device-parallel and the rotation lowers
to a collective-permute — the classic GPipe dataflow, expressed as pure JAX
so it differentiates exactly (CATERPILLAR's pipelined multi-unit training
schedule, Li & Pedram 2017).

Warm-up/drain ticks compute on zero-filled garbage that is never written to
the output (the write is predicated), so forward values AND gradients equal
the sequential reference exactly — see tests/test_pipeline_parallel.py.

``bubble_fraction(S, M) = (S-1)/(M+S-1)`` is the idle fraction of the
schedule (the reason microbatch counts are chosen >> stage counts).
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.util import jaxcompat as _jaxcompat  # noqa: F401  (installs shims)

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """Idle fraction of the GPipe schedule: (S-1) / (M + S - 1)."""
    s, m = num_stages, num_microbatches
    return (s - 1) / (m + s - 1)


def _stage_constrain(buf, mesh):
    """Pin the rotating buffer's stage axis to the "pipe" mesh axis."""
    if mesh is None or "pipe" not in getattr(mesh, "axis_names", ()):
        return buf
    if buf.shape[0] % dict(mesh.shape)["pipe"] != 0:
        return buf
    spec = P("pipe", *([None] * (buf.ndim - 1)))
    try:
        return jax.lax.with_sharding_constraint(
            buf, NamedSharding(mesh, spec))
    except Exception:  # eager call outside a partitionable context
        return buf


def pipeline_apply(stage_params, x: jax.Array, body: Callable,
                   mesh=None) -> jax.Array:
    """Apply an S-stage pipeline to M microbatches.

    stage_params : pytree whose leaves carry a leading stage axis [S, ...]
    x            : [M, microbatch...] input microbatches
    body         : body(stage_params_s, h) -> h, one stage on one microbatch
    mesh         : optional mesh with a "pipe" axis to pin stages to devices

    Returns [M, microbatch...] — identical to running the stages
    sequentially over each microbatch.
    """
    S = jax.tree.leaves(stage_params)[0].shape[0]
    M = x.shape[0]
    T = M + S - 1

    def tick(carry, t):
        buf, outs = carry                       # buf [S, mb...]: stage inputs
        # feed microbatch t into stage 0 (garbage recirculates after drain;
        # its outputs fall past tick T and are never collected)
        inp = lax.dynamic_index_in_dim(x, jnp.clip(t, 0, M - 1), 0,
                                       keepdims=False)
        buf = buf.at[0].set(jnp.where(t < M, inp, buf[0]))
        buf = _stage_constrain(buf, mesh)
        new = jax.vmap(body)(stage_params, buf)  # all stages, one tick
        # stage S-1 finished microbatch t-(S-1): write it out (predicated —
        # warm-up ticks produce garbage that must not touch outs or grads)
        idx = t - (S - 1)
        idx_c = jnp.maximum(idx, 0)
        cur = lax.dynamic_index_in_dim(outs, idx_c, 0, keepdims=False)
        outs = lax.dynamic_update_index_in_dim(
            outs, jnp.where(idx >= 0, new[S - 1], cur), idx_c, 0)
        # rotate: stage s+1's next input is stage s's output
        return (jnp.roll(new, 1, axis=0), outs), None

    buf0 = jnp.zeros((S,) + x.shape[1:], x.dtype)
    (_, outs), _ = lax.scan(tick, (buf0, jnp.zeros_like(x)), jnp.arange(T))
    return outs
