"""Compiled-artifact accounting: FLOPs, HBM bytes, collective traffic,
and the three-term roofline.

``analyze_compiled`` reads XLA's per-device cost/memory analyses off a
``jax.stages.Compiled`` and parses the optimized HLO for collective ops
(all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute)
— the data-movement accounting NeuroTrainer (Kim et al., 2017) argues
dominates training energy.  Async collectives are handled as start/done
PAIRS (the ``-done`` op contributes nothing; each pair is one collective)
and per-op bytes are attributed by REPLICA-GROUP SIZE, not result shape:
a ring all-reduce over a group of g devices moves 2(g-1)/g payload bytes
per device, an all-gather / reduce-scatter / all-to-all (g-1)/g, and a
collective-permute one payload per hop.

``per_tick_attribution`` divides a module's collective bytes across the
tick count of a pipeline schedule's plan (``dist.pipeline``), so the
bubble/traffic tradeoff of GPipe vs 1F1B vs interleaved is a measured
quantity: fewer ticks under the same permute traffic means more bytes in
flight per tick of schedule time.  It is STRICT about pairing: a module
with unmatched ``-start``/``-done`` ops raises instead of silently
attributing bytes to a window the compiler never closed.

``overlap_fraction`` measures whether the compiler actually scheduled
compute into each collective's latency window: for async pairs the window
is start..done; for synchronous collectives it is issue..first-REAL-
consumer (pure data-movement consumers — the carry stores a rolled scan
wraps around an in-flight result — are chased through), and a result that
reaches a loop-body ROOT through movement only is LOOP-CARRIED: its
consumer is the next iteration's wait, so it counts as overlapped by
construction.  A collective with at least one real compute op
(dot/fusion/while/elementwise — not parameters, tuples, data-movement
fusions or other collectives) inside its window — or a loop-carried one —
counts as overlapped; the fraction is overlapped / total.  A chained ring
(hop permutes joined by accumulate adds) is ONE logical collective: the
chain-head's chase absorbs the downstream hops, so a g-device bucketed
ring and a lone fused psum are comparable units instead of the hop count
swamping the denominator.  This is the
measured counterpart of the overlapped backward scan
(``core.taxonn.backward_stack(overlap="on")``): the ring hops it issues at
layer i are only worth their bytes if layer i-1's VJP work lands between
them and their consumer.

``roofline_terms`` converts (flops, hbm bytes, collective bytes) into
per-step seconds under a fixed accelerator model and names the dominant
term.  Extrapolation across scan depth happens in launch/dryrun.py; this
module only measures one artifact.
"""
from __future__ import annotations

import bisect
import re
from typing import Dict

# Accelerator model for the roofline (TPU-class chip; order-of-magnitude
# honest, single source of truth for reports and benchmarks).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per device
HBM_BANDWIDTH = 819e9        # bytes/s per device
ICI_BANDWIDTH = 90e9         # bytes/s per device (all links combined)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# one HLO array type, e.g. f32[4,8]{1,0} or pred[] — captures dtype + dims
_ARRAY_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)"
                       r"\[([0-9,]*)\]")
_COLLECTIVE_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?:\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all"
    r"|collective-permute)"
    r"(?P<suffix>-start|-done)?\((?P<args>[^\n]*)", re.M)
_OPERAND_REF_RE = re.compile(r"%([\w.\-]+)")
# explicit groups: replica_groups={{0,1},{2,3}} -> first group's size
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
# iota (v2) groups: replica_groups=[4,2]<=[8] -> [num_groups, group_size]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(typestr):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _payload_bytes(line: str) -> int:
    """Largest single array on the op line.

    The payload of a collective is ONE logical array — the gathered result
    for all-gather, the (larger) operand for reduce-scatter, either side
    for all-reduce / collective-permute — so the max over every array
    type printed on the line (operands, tuple results, layouts) picks it
    without double-counting the aliased halves of a ``-start`` tuple.
    """
    best = 0
    for dtype, dims in _ARRAY_RE.findall(line):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        best = max(best, n * _DTYPE_BYTES[dtype])
    return best


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def _wire_factor(kind: str, g: int) -> float:
    """Per-device bytes moved as a multiple of the payload, for a ring
    collective over a replica group of g devices."""
    if kind == "collective-permute":
        return 1.0          # one hop: each device sends its payload once
    if g <= 1:
        return 0.0          # a group of one moves nothing
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g      # reduce-scatter + all-gather phases
    return (g - 1) / g                # all-gather / reduce-scatter / a2a


def collective_stats(hlo_text: str, default_group_size: int = 2) -> Dict:
    """Collective census of one optimized HLO module.

    Async ``-start``/``-done`` ops are paired by SSA name (the done op
    references the start's result) and counted once; bytes are attributed
    per replica-group size via ``_wire_factor``.  Ops whose replica groups
    are not printed (or are empty) fall back to ``default_group_size`` —
    the g=2 default reproduces the old result-shape estimate for
    all-reduce (factor 1.0) while staying finite for the others.  A
    ``-done`` whose operand names no recorded ``-start`` is counted in
    ``unmatched_dones`` (its bytes were never attributed — malformed or
    truncated HLO; ``per_tick_attribution`` refuses such modules).
    """
    counts: Dict[str, int] = {}
    by_kind_bytes: Dict[str, float] = {}
    moved = 0.0
    starts: Dict[str, str] = {}        # ssa name -> kind, awaiting a done
    async_pairs = 0
    unmatched_dones = 0
    for m in _COLLECTIVE_OP_RE.finditer(hlo_text):
        kind, suffix = m.group("kind"), m.group("suffix")
        line = m.group(0)
        if suffix == "-done":
            ref = _OPERAND_REF_RE.search(m.group("args"))
            if ref and starts.pop(ref.group(1), None) is not None:
                async_pairs += 1
            else:
                unmatched_dones += 1
            continue                   # bytes were counted at the start op
        if suffix == "-start":
            starts[m.group("name")] = kind
        counts[kind] = counts.get(kind, 0) + 1
        g = _group_size(line, default_group_size)
        op_bytes = _wire_factor(kind, g) * _payload_bytes(line)
        by_kind_bytes[kind] = by_kind_bytes.get(kind, 0.0) + op_bytes
        moved += op_bytes
    return {
        "counts": counts,
        "moved_bytes_per_device": float(moved),
        "by_kind_bytes": by_kind_bytes,
        "async_pairs": async_pairs,
        "unmatched_starts": len(starts),
        "unmatched_dones": unmatched_dones,
    }


# any op line: "%name = <type-or-tuple> opcode(" — used by overlap_fraction
_ANY_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?:\([^)]*\)|\S+)\s+"
    r"(?P<opcode>[\w\-]+)\(")
# ops that occupy no functional-unit time: bookkeeping, not overlap evidence
_FREE_OPCODES = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "domain", "opt-barrier",
})
# fusion-name tokens that are pure data movement; a fusion whose name is
# built ONLY from these (e.g. "bitcast_dynamic-update-slice_fusion", the
# loop-carry store a scan wraps around a collective result) is transparent:
# it neither counts as overlap evidence nor terminates a latency window
_MOVE_TOKENS = frozenset({
    "bitcast", "copy", "dynamic-update-slice", "dynamic-slice", "slice",
    "transpose", "reshape", "convert", "concatenate", "pad", "fusion",
})


def _is_data_movement(opcode: str, name: str) -> bool:
    if opcode in _FREE_OPCODES:
        return True
    if opcode != "fusion":
        return False
    base = name.split(".")[0]          # strip the ".N" uniquing suffix
    return all(tok in _MOVE_TOKENS for tok in base.split("_") if tok)


# a ring reduction's own in-chain ops: the hop permutes and the accumulate
# adds between them.  Chasing through these (in addition to data movement)
# lets the loop-carried test see a chained ring — permute -> add -> permute
# -> ... -> carry store -> ROOT — as one logical collective whose real
# consumer is the next scan iteration.
_CARRY_CHAIN_TOKENS = _MOVE_TOKENS | {"add", "collective-permute"}


def _is_carry_chain(opcode: str, name: str) -> bool:
    if _is_data_movement(opcode, name):
        return True
    if opcode in ("add", "collective-permute", "collective-permute-start",
                  "collective-permute-done"):
        return True
    if opcode != "fusion":
        return False
    base = name.split(".")[0]
    return all(tok in _CARRY_CHAIN_TOKENS for tok in base.split("_") if tok)


def _base_opcode(opcode: str) -> str:
    for suffix in ("-start", "-done"):
        if opcode.endswith(suffix):
            return opcode[: -len(suffix)]
    return opcode


# a permute hop's ring signature: its source_target_pairs plus payload size.
# Every hop of one bucketed ring shares both (the perm is fixed and the
# chunk shape constant across phases), while unrelated permutes in the same
# module — pipeline stage boundaries, halo exchanges — differ in at least
# one, so the signature is what lets the backward chase absorb hops even
# after XLA fuses the accumulate adds with real compute.
_PAIRS_RE = re.compile(r"source_target_pairs=(\S+?\}\})")


def _permute_sig(line: str):
    m = _PAIRS_RE.search(line)
    return (m.group(1) if m else "", _payload_bytes(line))


def _is_compute_opcode(opcode: str, name: str = "") -> bool:
    if _is_data_movement(opcode, name):
        return False
    base = opcode
    for suffix in ("-start", "-done"):
        if base.endswith(suffix):
            base = base[: -len(suffix)]
    return base not in COLLECTIVE_KINDS


def overlap_fraction(hlo_text: str) -> Dict:
    """Fraction of collectives with compute scheduled in their latency
    window (start..done for async pairs; issue..first-real-consumer for
    sync ops, loop-carried results counting as overlapped — see the module
    docstring), plus the total compute ops found inside those windows.

    Returns ``{"collectives", "overlapped", "overlap_fraction",
    "compute_ops_in_windows"}``; a module with no collectives reports a
    fraction of 0.0.
    """
    lines = hlo_text.splitlines()
    ops = []                      # (line_idx, name, opcode)
    uses: Dict[str, list] = {}    # operand name -> ascending use-line idxs
    defs_by_line: Dict[int, tuple] = {}
    defs_by_name: Dict[str, list] = {}   # name -> ascending def-line idxs
    operands_by_line: Dict[int, list] = {}
    for idx, line in enumerate(lines):
        m = _ANY_OP_RE.match(line)
        if m:
            ops.append((idx, m.group("name"), m.group("opcode")))
            defs_by_line[idx] = (m.group("name"), m.group("opcode"))
            defs_by_name.setdefault(m.group("name"), []).append(idx)
            operands_by_line[idx] = _OPERAND_REF_RE.findall(line[m.end():])
        # operand references (past the "%name =" definition when present);
        # names recur across computations, so keep every use line and pick
        # the first one AFTER the issuing op below
        for ref in _OPERAND_REF_RE.findall(line[m.end():] if m else line):
            uses.setdefault(ref, []).append(idx)

    def _is_chained_hop(idx: int) -> bool:
        """True when the permute at ``idx`` is a later hop of a ring whose
        head already issued: an upstream collective-permute with the SAME
        ring signature is reachable through the operand dataflow within a
        few steps.  The bound is small on purpose — a ring hop's input is
        at most store-fusion -> previous hop away, while an unrelated
        permute that merely post-dates another is separated by the real
        compute between them.  This is the backward complement of the
        forward carry-chain chase: XLA fuses the ring's accumulate adds
        with neighbouring real compute (update fusions, sqrt fusions), so
        the forward chase alone stops early and would re-count every
        surviving hop as its own collective."""
        sig = _permute_sig(lines[idx])
        frontier = operands_by_line.get(idx, [])
        for _ in range(4):
            nxt = []
            for nm in frontier:
                dls = defs_by_name.get(nm)
                if not dls:
                    continue
                j = bisect.bisect_left(dls, idx)
                if j == 0:
                    continue
                didx = dls[j - 1]        # nearest upstream def of this name
                dop = defs_by_line[didx][1]
                if _base_opcode(dop) == "collective-permute":
                    if _permute_sig(lines[didx]) == sig:
                        return True
                    continue             # a DIFFERENT ring: not this chain
                nxt.extend(operands_by_line.get(didx, ()))
            frontier = nxt[:64]          # bound the fan-in walk
            if not frontier:
                return False
        return False
    compute_lines = sorted(i for i, nm, opc in ops
                           if _is_compute_opcode(opc, nm))

    def compute_in(lo: int, hi: int) -> int:
        """Compute-op lines strictly between lines lo and hi."""
        return max(0, bisect.bisect_left(compute_lines, hi)
                   - bisect.bisect_right(compute_lines, lo))

    def first_real_consumer(idx: int, name: str):
        """(window_end, loop_carried, absorbed) for the value at ``idx``.

        Chases through pure data-movement consumers (the carry stores a
        scan wraps around an in-flight collective result).  A value that
        reaches a ROOT tuple through movement only is LOOP-CARRIED: its
        real consumer is the next iteration's wait, so the whole remainder
        of the body is its latency window — exactly the overlapped
        backward scan's start/wait structure.  The chase also passes
        through the ring's own chain (hop permutes + accumulate adds), so
        a chained reduce-scatter reads as one logical collective;
        ``absorbed`` returns the (line, name) of every collective op the
        chase passed through — the chain's later hops, which are phases of
        THIS logical collective and must not be re-counted as independent
        collectives (counting each hop made a 24-hop ring and a lone psum
        land on the same fraction).  Only a FIRST consumer that is the
        ROOT (or a chain op leading to it) counts as carried — a value
        whose first consumer is real compute is NOT carried even if its
        raw value also lands in the ROOT tuple, and a dead collective (no
        consumers) is not overlap evidence."""
        hi = len(lines)
        absorbed = []
        for _ in range(256):              # bounded chase
            use_lines = uses.get(name, ())
            j = bisect.bisect_right(use_lines, idx)
            if j >= len(use_lines):
                return len(lines), False, absorbed  # dead value: no consumer
            hi = use_lines[j]
            if lines[hi].lstrip().startswith("ROOT"):
                return hi, True, absorbed  # feeds the carry directly
            d = defs_by_line.get(hi)
            if d is None or not _is_carry_chain(d[1], d[0]):
                return hi, False, absorbed
            if _base_opcode(d[1]) in COLLECTIVE_KINDS:
                absorbed.append((hi, d[0]))
            idx, name = hi, d[0]
        return hi, False, absorbed

    total = overlapped = in_windows = 0
    starts: Dict[str, int] = {}
    absorbed_lines: set = set()
    absorbed_names: set = set()
    for idx, name, opcode in ops:
        base = _base_opcode(opcode)
        is_start = opcode.endswith("-start")
        is_done = opcode.endswith("-done")
        if base not in COLLECTIVE_KINDS:
            continue
        if idx in absorbed_lines or name in absorbed_names:
            continue   # a chained hop of an already-counted collective
        if base == "collective-permute" and _is_chained_hop(idx):
            # a later hop of a ring already counted at its head; for a
            # -start, skipping the record makes its -done a no-op below
            continue
        if is_start:
            starts[name] = idx
            continue
        if is_done:
            m = _ANY_OP_RE.match(lines[idx])
            ref = _OPERAND_REF_RE.search(lines[idx][m.end():] if m
                                         else lines[idx])
            lo = starts.pop(ref.group(1), None) if ref else None
            if lo is None:
                continue   # absorbed (chained hop) or unmatched start
            hi = idx
        else:
            # sync collective: window runs to its first REAL consumer after
            # the issue line (same-name values in other computations
            # excluded; carry stores chased through).  Loop-carried results
            # are consumed one iteration later, so they count as overlapped
            # even when the body's tail holds no further compute.
            lo = idx
            hi, carried, absorbed = first_real_consumer(idx, name)
            for aidx, aname in absorbed:
                absorbed_lines.add(aidx)
                absorbed_names.add(aname)
            if carried:
                total += 1
                n = compute_in(lo, hi)
                in_windows += n
                overlapped += 1
                continue
        total += 1
        n = compute_in(lo, hi)
        in_windows += n
        overlapped += n > 0
    return {
        "collectives": total,
        "overlapped": overlapped,
        "overlap_fraction": (overlapped / total) if total else 0.0,
        "compute_ops_in_windows": in_windows,
    }


def per_tick_attribution(hlo_text: str, num_ticks: int,
                         default_group_size: int = 2) -> Dict:
    """Attribute a module's collective bytes to pipeline-schedule ticks.

    ``num_ticks`` comes from a ``dist.pipeline`` SchedulePlan (the
    schedule's modeled span); the result says how many collective — and
    specifically collective-permute, the stage-boundary traffic — bytes
    each tick of schedule time must carry.

    Raises ``ValueError`` on malformed HLO (unpaired ``-start``/``-done``
    ops): an orphaned start's bytes have no closing window and an orphaned
    done's were never counted, so any per-tick split would mis-attribute.
    """
    if num_ticks < 1:
        raise ValueError(f"num_ticks must be >= 1, got {num_ticks}")
    stats = collective_stats(hlo_text, default_group_size)
    if stats["unmatched_starts"] or stats["unmatched_dones"]:
        raise ValueError(
            f"malformed HLO: {stats['unmatched_starts']} async start op(s) "
            f"without a done and {stats['unmatched_dones']} done op(s) "
            f"without a start; refusing to attribute collective bytes "
            f"across ticks")
    per_kind = {k: v / num_ticks for k, v in stats["by_kind_bytes"].items()}
    return {
        "num_ticks": int(num_ticks),
        "moved_bytes_per_tick": stats["moved_bytes_per_device"] / num_ticks,
        "bytes_per_tick_by_kind": per_kind,
        "permute_bytes_per_tick": per_kind.get("collective-permute", 0.0),
        "collectives": stats,
    }


def _cost_dict(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _memory_dict(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        val = getattr(ma, field, None)
        if val is not None:
            out[field] = int(val)
    return out


def analyze_compiled(compiled, n_devices: int = 1) -> Dict:
    """Per-device cost record for one compiled (SPMD) artifact.

    The compiled module is already the per-device program, so XLA's cost
    analysis is per-device as-is; ``n_devices`` is recorded for context.
    """
    cost = _cost_dict(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    return {
        "n_devices": int(n_devices),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "hbm_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": collective_stats(hlo),
        "overlap": overlap_fraction(hlo),
        "memory_analysis": _memory_dict(compiled),
    }


def roofline_terms(flops: float, hbm_bytes: float,
                   collective_bytes: float) -> Dict:
    """Three-term roofline: seconds spent if each resource were the only
    bottleneck, plus which term dominates."""
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BANDWIDTH,
        "collective_s": collective_bytes / ICI_BANDWIDTH,
    }
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "step_s_lower_bound": max(terms.values()),
        "dominant": dominant.replace("_s", ""),
    }
