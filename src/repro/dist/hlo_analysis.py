"""Compiled-artifact accounting: FLOPs, HBM bytes, collective traffic,
and the three-term roofline.

``analyze_compiled`` reads XLA's per-device cost/memory analyses off a
``jax.stages.Compiled`` and parses the optimized HLO for collective ops
(all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute),
summing each op's result bytes as the per-device moved-byte estimate —
the data-movement accounting NeuroTrainer (Kim et al., 2017) argues
dominates training energy.

``roofline_terms`` converts (flops, hbm bytes, collective bytes) into
per-step seconds under a fixed accelerator model and names the dominant
term.  Extrapolation across scan depth happens in launch/dryrun.py; this
module only measures one artifact.
"""
from __future__ import annotations

import re
from typing import Dict

# Accelerator model for the roofline (TPU-class chip; order-of-magnitude
# honest, single source of truth for reports and benchmarks).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per device
HBM_BANDWIDTH = 819e9        # bytes/s per device
ICI_BANDWIDTH = 90e9         # bytes/s per device (all links combined)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# one HLO array type, e.g. f32[4,8]{1,0} or pred[] — captures dtype + dims
_ARRAY_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)"
                       r"\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(typestr):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict:
    """Count collectives and sum their result bytes in optimized HLO."""
    counts: Dict[str, int] = {}
    moved = 0
    for typestr, kind in _COLLECTIVE_RE.findall(hlo_text):
        counts[kind] = counts.get(kind, 0) + 1
        moved += _shape_bytes(typestr)
    return {"counts": counts, "moved_bytes_per_device": float(moved)}


def _cost_dict(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _memory_dict(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        val = getattr(ma, field, None)
        if val is not None:
            out[field] = int(val)
    return out


def analyze_compiled(compiled, n_devices: int = 1) -> Dict:
    """Per-device cost record for one compiled (SPMD) artifact.

    The compiled module is already the per-device program, so XLA's cost
    analysis is per-device as-is; ``n_devices`` is recorded for context.
    """
    cost = _cost_dict(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    return {
        "n_devices": int(n_devices),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "hbm_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": collective_stats(hlo),
        "memory_analysis": _memory_dict(compiled),
    }


def roofline_terms(flops: float, hbm_bytes: float,
                   collective_bytes: float) -> Dict:
    """Three-term roofline: seconds spent if each resource were the only
    bottleneck, plus which term dominates."""
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BANDWIDTH,
        "collective_s": collective_bytes / ICI_BANDWIDTH,
    }
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "step_s_lower_bound": max(terms.values()),
        "dominant": dominant.replace("_s", ""),
    }
