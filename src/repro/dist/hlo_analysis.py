"""Compiled-artifact accounting: FLOPs, HBM bytes, collective traffic,
and the three-term roofline.

``analyze_compiled`` reads XLA's per-device cost/memory analyses off a
``jax.stages.Compiled`` and parses the optimized HLO for collective ops
(all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute)
— the data-movement accounting NeuroTrainer (Kim et al., 2017) argues
dominates training energy.  Async collectives are handled as start/done
PAIRS (the ``-done`` op contributes nothing; each pair is one collective)
and per-op bytes are attributed by REPLICA-GROUP SIZE, not result shape:
a ring all-reduce over a group of g devices moves 2(g-1)/g payload bytes
per device, an all-gather / reduce-scatter / all-to-all (g-1)/g, and a
collective-permute one payload per hop.

``per_tick_attribution`` divides a module's collective bytes across the
tick count of a pipeline schedule's plan (``dist.pipeline``), so the
bubble/traffic tradeoff of GPipe vs 1F1B vs interleaved is a measured
quantity: fewer ticks under the same permute traffic means more bytes in
flight per tick of schedule time.

``roofline_terms`` converts (flops, hbm bytes, collective bytes) into
per-step seconds under a fixed accelerator model and names the dominant
term.  Extrapolation across scan depth happens in launch/dryrun.py; this
module only measures one artifact.
"""
from __future__ import annotations

import re
from typing import Dict

# Accelerator model for the roofline (TPU-class chip; order-of-magnitude
# honest, single source of truth for reports and benchmarks).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per device
HBM_BANDWIDTH = 819e9        # bytes/s per device
ICI_BANDWIDTH = 90e9         # bytes/s per device (all links combined)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# one HLO array type, e.g. f32[4,8]{1,0} or pred[] — captures dtype + dims
_ARRAY_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)"
                       r"\[([0-9,]*)\]")
_COLLECTIVE_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?:\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all"
    r"|collective-permute)"
    r"(?P<suffix>-start|-done)?\((?P<args>[^\n]*)", re.M)
_OPERAND_REF_RE = re.compile(r"%([\w.\-]+)")
# explicit groups: replica_groups={{0,1},{2,3}} -> first group's size
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
# iota (v2) groups: replica_groups=[4,2]<=[8] -> [num_groups, group_size]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(typestr):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _payload_bytes(line: str) -> int:
    """Largest single array on the op line.

    The payload of a collective is ONE logical array — the gathered result
    for all-gather, the (larger) operand for reduce-scatter, either side
    for all-reduce / collective-permute — so the max over every array
    type printed on the line (operands, tuple results, layouts) picks it
    without double-counting the aliased halves of a ``-start`` tuple.
    """
    best = 0
    for dtype, dims in _ARRAY_RE.findall(line):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        best = max(best, n * _DTYPE_BYTES[dtype])
    return best


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def _wire_factor(kind: str, g: int) -> float:
    """Per-device bytes moved as a multiple of the payload, for a ring
    collective over a replica group of g devices."""
    if kind == "collective-permute":
        return 1.0          # one hop: each device sends its payload once
    if g <= 1:
        return 0.0          # a group of one moves nothing
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g      # reduce-scatter + all-gather phases
    return (g - 1) / g                # all-gather / reduce-scatter / a2a


def collective_stats(hlo_text: str, default_group_size: int = 2) -> Dict:
    """Collective census of one optimized HLO module.

    Async ``-start``/``-done`` ops are paired by SSA name (the done op
    references the start's result) and counted once; bytes are attributed
    per replica-group size via ``_wire_factor``.  Ops whose replica groups
    are not printed (or are empty) fall back to ``default_group_size`` —
    the g=2 default reproduces the old result-shape estimate for
    all-reduce (factor 1.0) while staying finite for the others.
    """
    counts: Dict[str, int] = {}
    by_kind_bytes: Dict[str, float] = {}
    moved = 0.0
    starts: Dict[str, str] = {}        # ssa name -> kind, awaiting a done
    async_pairs = 0
    for m in _COLLECTIVE_OP_RE.finditer(hlo_text):
        kind, suffix = m.group("kind"), m.group("suffix")
        line = m.group(0)
        if suffix == "-done":
            ref = _OPERAND_REF_RE.search(m.group("args"))
            if ref and starts.pop(ref.group(1), None) is not None:
                async_pairs += 1
            continue                   # bytes were counted at the start op
        if suffix == "-start":
            starts[m.group("name")] = kind
        counts[kind] = counts.get(kind, 0) + 1
        g = _group_size(line, default_group_size)
        op_bytes = _wire_factor(kind, g) * _payload_bytes(line)
        by_kind_bytes[kind] = by_kind_bytes.get(kind, 0.0) + op_bytes
        moved += op_bytes
    return {
        "counts": counts,
        "moved_bytes_per_device": float(moved),
        "by_kind_bytes": by_kind_bytes,
        "async_pairs": async_pairs,
        "unmatched_starts": len(starts),
    }


def per_tick_attribution(hlo_text: str, num_ticks: int,
                         default_group_size: int = 2) -> Dict:
    """Attribute a module's collective bytes to pipeline-schedule ticks.

    ``num_ticks`` comes from a ``dist.pipeline`` SchedulePlan (the
    schedule's modeled span); the result says how many collective — and
    specifically collective-permute, the stage-boundary traffic — bytes
    each tick of schedule time must carry.
    """
    if num_ticks < 1:
        raise ValueError(f"num_ticks must be >= 1, got {num_ticks}")
    stats = collective_stats(hlo_text, default_group_size)
    per_kind = {k: v / num_ticks for k, v in stats["by_kind_bytes"].items()}
    return {
        "num_ticks": int(num_ticks),
        "moved_bytes_per_tick": stats["moved_bytes_per_device"] / num_ticks,
        "bytes_per_tick_by_kind": per_kind,
        "permute_bytes_per_tick": per_kind.get("collective-permute", 0.0),
        "collectives": stats,
    }


def _cost_dict(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _memory_dict(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        val = getattr(ma, field, None)
        if val is not None:
            out[field] = int(val)
    return out


def analyze_compiled(compiled, n_devices: int = 1) -> Dict:
    """Per-device cost record for one compiled (SPMD) artifact.

    The compiled module is already the per-device program, so XLA's cost
    analysis is per-device as-is; ``n_devices`` is recorded for context.
    """
    cost = _cost_dict(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    return {
        "n_devices": int(n_devices),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "hbm_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": collective_stats(hlo),
        "memory_analysis": _memory_dict(compiled),
    }


def roofline_terms(flops: float, hbm_bytes: float,
                   collective_bytes: float) -> Dict:
    """Three-term roofline: seconds spent if each resource were the only
    bottleneck, plus which term dominates."""
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BANDWIDTH,
        "collective_s": collective_bytes / ICI_BANDWIDTH,
    }
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "step_s_lower_bound": max(terms.values()),
        "dominant": dominant.replace("_s", ""),
    }
