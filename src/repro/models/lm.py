"""Model assembly: embedding, scanned layer stacks, loss head, decode paths.

Homogeneous layer stacks are scanned (`lax.scan` over parameters stacked on a
leading L axis) — keeps HLO size O(1) in depth, which keeps 512-device AOT
compiles fast and lets the TaxoNN engine express its per-layer fused update
as a scan carry.

Families:
  dense/moe : embed -> L x transformer_block -> norm -> CE head
  vlm       : [patch_embeds ; text embeds] -> dense stack (loss on text)
  ssm       : embed -> L x mamba_block -> norm -> CE head
  hybrid    : embed -> G x (shared_attn_block ; K x mamba_block) -> ...
  encdec    : frames -> enc stack ; tokens -> dec stack(cross=enc) -> CE head
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.api import constrain
from repro.models import blocks as B
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.util.scan import xscan

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _stacked_init(key, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab_size
    params: dict = {
        "embed": jax.random.normal(keys[0], (V, D), jnp.float32) * D ** -0.5,
        "final_norm": L.init_norm(D, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[1], (D, V), jnp.float32) * D ** -0.5

    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = _stacked_init(
            keys[2], cfg.num_layers, lambda k: B.init_transformer_block(k, cfg))
        if cfg.family == "vlm":
            params["mm_proj"] = jax.random.normal(keys[3], (D, D), jnp.float32) * D ** -0.5
    elif cfg.family == "ssm":
        params["blocks"] = _stacked_init(
            keys[2], cfg.num_layers, lambda k: B.init_mamba_block(k, cfg))
    elif cfg.family == "hybrid":
        G, K = hybrid_groups(cfg)
        flat = _stacked_init(keys[2], G * K, lambda k: B.init_mamba_block(k, cfg))
        params["blocks"] = jax.tree.map(
            lambda x: x.reshape((G, K) + x.shape[1:]), flat)
        params["shared_attn"] = B.init_transformer_block(keys[3], cfg)
    elif cfg.family == "encdec":
        params["enc_blocks"] = _stacked_init(
            keys[2], cfg.num_encoder_layers,
            lambda k: B.init_transformer_block(k, cfg))
        params["enc_norm"] = L.init_norm(D, cfg)
        params["blocks"] = _stacked_init(
            keys[3], cfg.num_layers, lambda k: B.init_decoder_block(k, cfg))
    else:
        raise ValueError(cfg.family)
    return params


# How each family's main stack consumes operands that are not the layer's
# own parameters or the flowing activation — the contract the stage-sharded
# pipeline path (core/steps.py) uses to replicate or slice them:
#   "none"       self-contained per-layer bodies (dense/moe/vlm/ssm)
#   "weights"    a weight-tied block applied by every unit (hybrid's shared
#                attn): broadcast-class — replicated to every stage, layer-
#                quantized in place, gradient summed across stages by the
#                vjp of the broadcast
#   "activation" a full-batch activation fanned out to every layer (encdec's
#                encoder output): broadcast-class, but batch-indexed — each
#                stage slices the microbatch it is processing
# (moe's load-balance aux loss is the reduce-class counterpart: a per-layer
# side OUTPUT accumulated across stages and summed after the drain.)
SHARED_OPERAND_KIND = {
    "dense": "none", "moe": "none", "vlm": "none", "ssm": "none",
    "hybrid": "weights", "encdec": "activation",
}


def hybrid_groups(cfg: ModelConfig) -> tuple[int, int]:
    """Zamba2-style grouping: shared attn block applied every `attn_every`
    mamba layers -> G groups of K layers."""
    K = cfg.attn_every
    assert cfg.num_layers % K == 0, (cfg.num_layers, K)
    return cfg.num_layers // K, K


# ---------------------------------------------------------------------------
# Embedding & positions
# ---------------------------------------------------------------------------

def _sinusoid(t: int, d: int, offset=0) -> Array:
    pos = (jnp.arange(t, dtype=jnp.float32) + offset)[:, None]  # offset may be traced
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((t, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def embed_input(params, cfg: ModelConfig, batch: dict):
    """Returns (x0 [B,T,D], positions [B,T])."""
    dt = compute_dtype(cfg)
    tokens = batch["tokens"]
    # cast BEFORE the gather: with a vocab-sharded table the lookup psum
    # then runs at compute precision (half the collective bytes of f32)
    x = params["embed"].astype(dt)[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(dt) @ params["mm_proj"].astype(dt)
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.family == "encdec":
        x = x + _sinusoid(x.shape[1], cfg.d_model).astype(dt)
    b, t = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    return constrain(x, "btd"), positions


# ---------------------------------------------------------------------------
# Layer stacks (full sequence)
# ---------------------------------------------------------------------------

def apply_stack(params, cfg: ModelConfig, x: Array, positions: Array,
                enc_out: Optional[Array] = None):
    """Run the main stack. Returns (x_final, aux_loss)."""
    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, p):
            h2, aux = B.transformer_block(p, h, cfg, positions)
            return h2, aux
        x, auxs = xscan(body, x, params["blocks"])
        return x, jnp.sum(auxs)

    if cfg.family == "ssm":
        def body(h, p):
            h2, aux = B.mamba_block(p, h, cfg, positions)
            return h2, aux
        x, auxs = xscan(body, x, params["blocks"])
        return x, jnp.sum(auxs)

    if cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(h, gp):
            h, _ = B.transformer_block(shared, h, cfg, positions)

            def inner(hh, p):
                h2, aux = B.mamba_block(p, hh, cfg, positions)
                return h2, aux
            h, _ = xscan(inner, h, gp)
            return h, jnp.float32(0.0)
        x, _ = xscan(group, x, params["blocks"])
        return x, jnp.float32(0.0)

    if cfg.family == "encdec":
        assert enc_out is not None

        def body(h, p):
            h2, aux = B.decoder_block(p, h, cfg, positions, enc_out)
            return h2, aux
        x, auxs = xscan(body, x, params["blocks"])
        return x, jnp.sum(auxs)

    raise ValueError(cfg.family)


def encode(params, cfg: ModelConfig, frames: Array) -> Array:
    """Whisper encoder over precomputed (stub) frame embeddings [B,S,D]."""
    dt = compute_dtype(cfg)
    x = frames.astype(dt) + _sinusoid(frames.shape[1], cfg.d_model).astype(dt)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(h, p):
        h2, aux = B.transformer_block(p, h, cfg, positions, causal=False)
        return h2, aux
    x, _ = xscan(body, x, params["enc_blocks"])
    return L.apply_norm(params["enc_norm"], x, cfg)


# ---------------------------------------------------------------------------
# Loss head (chunked cross-entropy: [B,T,V] never materialised)
# ---------------------------------------------------------------------------

def head_weight(params, cfg: ModelConfig) -> Array:
    if cfg.tie_embeddings:
        return params["embed"].T  # [D, V]
    return params["lm_head"]


def ce_loss_head(params, cfg: ModelConfig, x: Array, labels: Array):
    """Chunked CE over the sequence axis.  labels: [B,T], -1 = ignore.
    Logits for each chunk are (re)computed inside a remat'd scan body, so the
    full [B,T,V] tensor never exists — fwd or bwd.  Returns (loss, metrics)."""
    return ce_from_weight(head_weight(params, cfg), cfg, x, labels)


def ce_from_weight(w: Array, cfg: ModelConfig, x: Array, labels: Array):
    """CE head given an explicit [D, V] output weight (used by the TaxoNN
    engine, which differentiates the head separately)."""
    bsz, t, d = x.shape
    c = min(cfg.logit_chunk, t)
    n = (t + c - 1) // c
    pad = n * c - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = x.reshape(bsz, n, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(bsz, n, c).transpose(1, 0, 2)

    from repro.dist.api import perf_opt  # local import: avoid cycle
    ce_bf16 = perf_opt("ce_bf16")

    @jax.checkpoint
    def body(carry, xs):
        tot, cnt = carry
        xch, lch = xs
        raw = xch @ w.astype(xch.dtype)
        # §Perf "ce_bf16": keep the [B,C,V] logits in bf16 (halves the CE
        # head's HBM bytes); max in bf16, exp in bf16, SUM accumulated f32.
        logits = constrain(raw if ce_bf16 else raw.astype(jnp.float32), "btv")
        m = jnp.max(logits, axis=-1, keepdims=True)
        sumexp = jnp.sum(jnp.exp(logits - m), axis=-1, dtype=jnp.float32)
        lse = jnp.log(sumexp) + m[..., 0].astype(jnp.float32)
        # vocab-parallel target pick: masked reduction instead of gather —
        # with V sharded on "model" this is collective-free (the gather
        # form all-gathers the full [B,C,V] logits across TP shards)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        mask = iota == jnp.maximum(lch, 0)[..., None]
        tgt = jnp.sum(jnp.where(mask, logits, 0).astype(jnp.float32), axis=-1)
        valid = (lch >= 0).astype(jnp.float32)
        return (tot + jnp.sum((lse - tgt) * valid), cnt + jnp.sum(valid)), None

    (tot, cnt), _ = xscan(body, (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss, {"loss": loss, "tokens": cnt}


# ---------------------------------------------------------------------------
# Full forward passes
# ---------------------------------------------------------------------------

AUX_COEF = 0.01  # MoE load-balance coefficient


def loss_fn(params, cfg: ModelConfig, batch: dict):
    """Autodiff-path training loss (the jax.grad baseline the TaxoNN engine
    is validated against)."""
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["frames"])
    x, positions = embed_input(params, cfg, batch)
    x, aux = apply_stack(params, cfg, x, positions, enc_out)
    x = L.apply_norm(params["final_norm"], x, cfg)
    labels = batch["labels"]
    if cfg.family == "vlm":  # loss over text positions only
        x = x[:, batch["patch_embeds"].shape[1]:, :]
    loss, metrics = ce_loss_head(params, cfg, x, labels)
    total = loss + AUX_COEF * aux
    metrics["aux"] = aux
    return total, metrics


def forward_hidden(params, cfg: ModelConfig, batch: dict) -> Array:
    """Forward to final hidden states (prefill / inference)."""
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["frames"])
    x, positions = embed_input(params, cfg, batch)
    x, _ = apply_stack(params, cfg, x, positions, enc_out)
    return L.apply_norm(params["final_norm"], x, cfg)


def last_token_logits(params, cfg: ModelConfig, batch: dict) -> Array:
    x = forward_hidden(params, cfg, batch)
    w = head_weight(params, cfg)
    return (x[:, -1, :] @ w.astype(x.dtype)).astype(jnp.float32)
