"""Functional layer library: norms, RoPE, attention variants, MLP, MoE.

Pure functions over explicit parameter pytrees (no flax).  Every ``apply``
comes with a matching ``init``.  Layers support three execution modes:

  * full-sequence (training / prefill, causal or bidirectional mask)
  * chunked online-softmax attention for long sequences (flash-style, pure
    JAX ``lax.scan`` over KV blocks — bounded memory at 32k+)
  * single-token decode against a KV cache (GQA ring-buffer for SWA, MLA
    absorbed-matmul over the compressed c_kv cache)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist.api import constrain, model_axis_size_ctx, perf_opt
from repro.kernels import ops as kops
from repro.kernels.common import act_deriv as _act_deriv, act_fn as _act_fn
from repro.models.config import ModelConfig
from repro.util.scan import xscan

Array = jax.Array

ATTN_CHUNK_THRESHOLD = 8192   # use online-softmax scan above this seq len
ATTN_KV_BLOCK = 1024

NEG_INF = -1e30  # additive mask value (finite: avoids NaN in masked softmax rows)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dtype)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


def apply_norm(params, x: Array, cfg: ModelConfig) -> Array:
    if cfg.norm_kind == "layernorm":
        return layernorm(params, x, cfg.norm_eps)
    return rmsnorm(params, x, cfg.norm_eps)


def init_norm(d: int, cfg: ModelConfig):
    return init_layernorm(d) if cfg.norm_kind == "layernorm" else init_rmsnorm(d)


# ---------------------------------------------------------------------------
# RoPE (half-rotation convention)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., T, H, hd]; positions: broadcastable to [..., T]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, hd/2]
    cos = jnp.cos(angles)[..., None, :]                 # [..., T, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# The kernel-datapath dense unit (TaxoNN PE array as a custom_vjp op)
# ---------------------------------------------------------------------------
#
# ``dense_unit(x, w, act)`` computes act(x @ w) through the Pallas kernel
# datapath selected by the ambient KernelBackend (see repro.kernels.ops):
# forward is ``fxp_matmul``; backward emits ``bp_gstep`` (dx, Eq. 8's matmul
# leg) and the dW-only form of ``sgd_dw_update`` (Eq. 9).  On the "int8"
# backend the operands move as int8 payloads with traced absmax scales and
# the MACs run int8 x int8 -> int32 — the paper's reuse of the inference
# low-bit PEs for the training passes.  The engine's STE wrappers own the
# (I,F) grid *around* this op, so the unit itself stays format-agnostic and
# one compiled step still serves every bit schedule.
#
# With the backend "off" (the CPU default) callers skip this path entirely
# and keep the original jnp einsums — bit-identical to the pre-kernel code.

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _dense_unit(x, w, act, backend):
    y, _ = _dense_unit_fwd(x, w, act, backend)
    return y


def _dense_unit_fwd(x, w, act, backend):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    z = kops.dense_fwd(x2, w, backend)              # f32 [M, N]
    y = _act_fn(z, act).astype(x.dtype).reshape(shape[:-1] + (w.shape[1],))
    # z is a per-layer residual: under the engine's remat-per-layer backward
    # it lives only for one scan step (the paper's derivation-unit register)
    return y, (x2, w, z if act != "identity" else None, shape)


def _dense_unit_bwd(act, backend, res, dy):
    x2, w, z, shape = res
    dy2 = dy.reshape(-1, dy.shape[-1]).astype(jnp.float32)
    dz = dy2 if z is None else dy2 * _act_deriv(z, act)
    dx = kops.dense_bwd_dx(dz, w, backend)               # Eq. 8 matmul leg
    dw = kops.dense_bwd_dw(x2, dz, backend)              # Eq. 9 outer product
    return dx.reshape(shape).astype(x2.dtype), dw.astype(w.dtype)


_dense_unit.defvjp(_dense_unit_fwd, _dense_unit_bwd)


def dense_unit(x, w, act: str = "identity",
               backend: Optional[str] = None) -> Array:
    """act(x @ w) on the active kernel datapath. x: [..., K]; w: [K, N]."""
    backend = backend or kops.current_backend()
    if backend == "off":
        return _act_fn((x @ w.astype(x.dtype)).astype(jnp.float32),
                       act).astype(x.dtype)
    return _dense_unit(x, w, act, backend)


def _proj3(x: Array, w3: Array, backend: str) -> Array:
    """Projection einsum "btd,dhk->bthk" through the dense unit."""
    d, h, hd = w3.shape
    y = _dense_unit(x, w3.reshape(d, h * hd), "identity", backend)
    return y.reshape(x.shape[:-1] + (h, hd))


# ---------------------------------------------------------------------------
# Dense attention (GQA / MQA / SWA)
# ---------------------------------------------------------------------------

def alloc_heads(cfg: ModelConfig) -> int:
    return cfg.padded_heads or cfg.num_heads


def _live_head_mask(cfg: ModelConfig, dtype) -> Optional[Array]:
    """[H_alloc] mask, 1 for real heads.  Heads are grouped per KV head
    (layout [Hkv, group]); padding extends each group, so the original
    query->KV mapping is preserved.  Masking wo rows keeps dead heads at
    exactly zero output AND zero gradient -> function == unpadded model."""
    hp, h, hkv = alloc_heads(cfg), cfg.num_heads, cfg.num_kv_heads
    if hp == h:
        return None
    g, gp = h // hkv, hp // hkv
    mask = (jnp.arange(gp) < g).astype(dtype)
    return jnp.broadcast_to(mask, (hkv, gp)).reshape(hp)


def init_attention(key, cfg: ModelConfig):
    D, Hkv, hd = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    H = alloc_heads(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = D ** -0.5
    p = {
        "wq": jax.random.normal(k1, (D, H, hd), jnp.float32) * s,
        "wk": jax.random.normal(k2, (D, Hkv, hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (D, Hkv, hd), jnp.float32) * s,
        "wo": jax.random.normal(k4, (H, hd, D), jnp.float32) * (H * hd) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((Hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((Hkv, hd), jnp.float32)
    return p


def _project_qkv(params, x, cfg: ModelConfig, positions):
    dt = x.dtype
    backend = kops.current_backend()
    if backend != "off":
        # §Kernels: QKV projections on the TaxoNN kernel datapath
        q = _proj3(x, params["wq"], backend)
        k = _proj3(x, params["wk"], backend)
        v = _proj3(x, params["wv"], backend)
    else:
        q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
        k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: Array, groups: int) -> Array:
    """[B, T, Hkv, hd] -> [B, T, Hkv*groups, hd] by repeat (GQA)."""
    if groups == 1:
        return k
    b, t, hkv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, hkv, groups, hd))
    return k.reshape(b, t, hkv * groups, hd)


def _attn_mask(t_q: int, t_kv: int, causal: bool, window: Optional[int],
               q_offset: int = 0) -> Array:
    """Additive mask [t_q, t_kv]; query i maps to absolute position i+q_offset."""
    qpos = jnp.arange(t_q)[:, None] + q_offset
    kpos = jnp.arange(t_kv)[None, :]
    ok = jnp.ones((t_q, t_kv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_full(q, k, v, mask, scale) -> Array:
    """Standard softmax attention, scores materialised. q,k,v: [B,T,H,hd]."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = scores + mask[None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _sdpa_chunked(q, k, v, causal, window, scale) -> Array:
    """Online-softmax attention, scanning KV blocks (flash-style, pure JAX).

    Memory is O(T * KV_BLOCK) instead of O(T^2).  Used for 32k+ sequences.
    K and V head dims may differ (MLA: qk 192 vs v 128).
    """
    b, t, h, hd = q.shape
    dv = v.shape[-1]
    blk = min(ATTN_KV_BLOCK, t)
    nblk = (t + blk - 1) // blk
    pad = nblk * blk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, blk, h, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, blk, h, dv).transpose(1, 0, 2, 3, 4)

    qpos = jnp.arange(t)[:, None]

    def body(carry, xs):
        acc, m, lse = carry  # acc [b,t,h,hd] f32, m/lse [b,h,t] f32
        kblk, vblk, blk_idx = xs
        kpos = blk_idx * blk + jnp.arange(blk)[None, :]
        ok = jnp.ones((t, blk), bool)
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        ok &= (kpos < t)  # padding
        mask = jnp.where(ok, 0.0, NEG_INF)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        s = s + mask[None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = lse * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, t, h, dv), jnp.float32)
    m0 = jnp.full((b, h, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, t), jnp.float32)
    (acc, m, lse), _ = xscan(
        body, (acc0, m0, l0), (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(lse, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def _masked_wo(params, cfg: ModelConfig, dt):
    wo = params["wo"].astype(dt)
    mask = _live_head_mask(cfg, dt)
    if mask is not None:
        wo = wo * mask[:, None, None]
    return wo


def attention(params, x: Array, cfg: ModelConfig, positions: Array,
              causal: bool = True, return_kv: bool = False):
    """Full-sequence attention (training / prefill). x: [B, T, D].

    ``return_kv=True`` additionally returns the (pre-GQA-expansion) rotated
    K/V so prefill can seed the decode cache without recomputation.
    """
    dt = x.dtype
    b, t, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions)
    groups = q.shape[2] // cfg.num_kv_heads
    kx = _expand_kv(k, groups)
    vx = _expand_kv(v, groups)
    scale = cfg.head_dim ** -0.5
    # §Perf "flash_attn": online-softmax at every length (never materialise
    # the [B,H,T,T] score tensor); default only above the chunk threshold
    if t > ATTN_CHUNK_THRESHOLD or (perf_opt("flash_attn") and t > 1024):
        out = _sdpa_chunked(q, kx, vx, causal, cfg.swa_window, scale)
    else:
        mask = _attn_mask(t, t, causal, cfg.swa_window)
        out = _sdpa_full(q, kx, vx, mask, scale)
    wo = _masked_wo(params, cfg, dt)
    backend = kops.current_backend()
    if backend != "off":
        # §Kernels: output projection on the TaxoNN kernel datapath
        h_, hd_, d_ = wo.shape
        y = _dense_unit(out.reshape(b, t, h_ * hd_),
                        wo.reshape(h_ * hd_, d_), "identity", backend)
    else:
        y = jnp.einsum("bthk,hkd->btd", out, wo)
    if return_kv:
        return y, (k, v)
    return y


def fill_ring(k: Array, length: int) -> Array:
    """Place a [B,T,...] sequence into a ring buffer of ``length`` slots so
    that token at absolute position p sits at slot p % length (matching
    ``attention_decode``'s indexing).  Keeps the last ``length`` tokens."""
    t = k.shape[1]
    if t <= length:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, length - t)
        return jnp.pad(k, pad)
    tail = k[:, t - length:]
    idx = (jnp.arange(length) - t) % length
    return jnp.take(tail, idx, axis=1)


# --- decode with KV cache -------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Ring-buffer KV cache.  For SWA archs the buffer is min(window, max_len)
    long (a serving memory win the sliding window makes free)."""
    length = max_len if cfg.swa_window is None else min(cfg.swa_window, max_len)
    shape = (batch, length, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def attention_decode(params, x: Array, cfg: ModelConfig, cache: dict,
                     pos: Array) -> tuple[Array, dict]:
    """One-token decode. x: [B, 1, D]; pos: scalar int32 (current position)."""
    b = x.shape[0]
    q, k, v = _project_qkv(params, x, cfg, jnp.full((b, 1), pos))
    return attention_decode_tail(params, q, k, v, x.dtype, cfg, cache, pos)


def attention_decode_tail(params, q: Array, k: Array, v: Array, dt,
                          cfg: ModelConfig, cache: dict, pos: Array
                          ) -> tuple[Array, dict]:
    """Cache write + ring-masked softmax + output projection — everything
    after the prologue, shared by the unfused path above and the fused
    decode-prologue kernel (kernels.decode_prologue) so both prologues feed
    bit-identical attention math."""
    length = cache["k"].shape[1]
    slot = jnp.mod(pos, length)  # ring buffer when SWA; plain index otherwise
    ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                  (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                  (0, slot, 0, 0))
    groups = q.shape[2] // cfg.num_kv_heads
    kk = _expand_kv(ck.astype(dt), groups)
    vv = _expand_kv(cv.astype(dt), groups)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                   preferred_element_type=jnp.float32) * scale
    # valid slots: absolute kpos <= pos and kpos > pos - length (ring validity)
    idx = jnp.arange(length)
    # absolute position stored in slot i (ring): the latest write to slot i
    # occurred at abs = pos - ((slot - i) mod length)
    abs_pos = pos - jnp.mod(slot - idx, length)
    ok = (abs_pos >= 0) & (abs_pos <= pos)
    if cfg.swa_window is not None:
        ok &= abs_pos > pos - cfg.swa_window
    s = s + jnp.where(ok, 0.0, NEG_INF)[None, None, None, :]
    p = jax.nn.softmax(s, axis=-1).astype(dt)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    y = jnp.einsum("bthk,hkd->btd", out, _masked_wo(params, cfg, dt))
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    s = D ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (D, H, dn + dr), jnp.float32) * s,
        "w_dkv": jax.random.normal(ks[1], (D, r), jnp.float32) * s,
        "w_kpe": jax.random.normal(ks[2], (D, dr), jnp.float32) * s,
        "w_uk": jax.random.normal(ks[3], (r, H, dn), jnp.float32) * r ** -0.5,
        "w_uv": jax.random.normal(ks[4], (r, H, dv), jnp.float32) * r ** -0.5,
        "wo": jax.random.normal(ks[5], (H, dv, D), jnp.float32) * (H * dv) ** -0.5,
        "ckv_norm": init_rmsnorm(r),
    }


def mla_attention(params, x: Array, cfg: ModelConfig, positions: Array,
                  return_cache: bool = False):
    """Full-sequence MLA (training / prefill): materialise per-head K/V."""
    dt = x.dtype
    b, t, _ = x.shape
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    c_kv = rmsnorm(params["ckv_norm"],
                   jnp.einsum("btd,dr->btr", x, params["w_dkv"].astype(dt)),
                   cfg.norm_eps)
    k_pe = apply_rope(
        jnp.einsum("btd,dr->btr", x, params["w_kpe"].astype(dt))[:, :, None, :],
        positions, cfg.rope_theta)                         # [B,T,1,dr]
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, params["w_uk"].astype(dt))
    v = jnp.einsum("btr,rhk->bthk", c_kv, params["w_uv"].astype(dt))

    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_pe, (b, t, cfg.num_heads, dr))], axis=-1)
    qq = jnp.concatenate([q_nope, q_pe], axis=-1)
    scale = (dn + dr) ** -0.5
    if t > ATTN_CHUNK_THRESHOLD:
        out = _sdpa_chunked(qq, k, v, True, None, scale)
    else:
        mask = _attn_mask(t, t, True, None)
        out = _sdpa_full(qq, k, v, mask, scale)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(dt))
    if return_cache:
        return y, (c_kv, k_pe[:, :, 0, :])
    return y


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Compressed cache: c_kv rank-r latents + shared rope key (the MLA win)."""
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode(params, x: Array, cfg: ModelConfig, cache: dict,
               pos: Array) -> tuple[Array, dict]:
    """Absorbed-matmul MLA decode: attention runs in the rank-r latent space;
    per-head K/V are never materialised for the cache."""
    dt = x.dtype
    b = x.shape[0]
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    posb = jnp.full((b, 1), pos)

    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, posb, cfg.rope_theta)          # [B,1,H,dr]

    c_new = rmsnorm(params["ckv_norm"],
                    jnp.einsum("btd,dr->btr", x, params["w_dkv"].astype(dt)),
                    cfg.norm_eps)
    kpe_new = apply_rope(
        jnp.einsum("btd,dr->btr", x, params["w_kpe"].astype(dt))[:, :, None, :],
        posb, cfg.rope_theta)[:, :, 0, :]                  # [B,1,dr]

    ckv = lax.dynamic_update_slice(cache["ckv"], c_new.astype(cache["ckv"].dtype),
                                   (0, pos, 0))
    kpe = lax.dynamic_update_slice(cache["kpe"], kpe_new.astype(cache["kpe"].dtype),
                                   (0, pos, 0))

    # absorb w_uk into q: q_lat [B,H,r]
    q_lat = jnp.einsum("bthk,rhk->bhr", q_nope, params["w_uk"].astype(dt))
    s_nope = jnp.einsum("bhr,bsr->bhs", q_lat, ckv.astype(dt))
    s_pe = jnp.einsum("bthk,bsk->bhs", q_pe, kpe.astype(dt))
    scale = (dn + dr) ** -0.5
    s = (s_nope + s_pe).astype(jnp.float32) * scale
    valid = jnp.arange(cache["ckv"].shape[1]) <= pos
    s = s + jnp.where(valid, 0.0, NEG_INF)[None, None, :]
    p = jax.nn.softmax(s, axis=-1).astype(dt)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, ckv.astype(dt))  # latent-space output
    out = jnp.einsum("bhr,rhk->bhk", o_lat, params["w_uv"].astype(dt))
    y = jnp.einsum("bhk,hkd->bd", out, params["wo"].astype(dt))[:, None, :]
    return y, {"ckv": ckv, "kpe": kpe}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = D ** -0.5, F ** -0.5
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": jax.random.normal(k1, (D, F), jnp.float32) * s_in,
            "w_up": jax.random.normal(k2, (D, F), jnp.float32) * s_in,
            "w_down": jax.random.normal(k3, (F, D), jnp.float32) * s_out,
        }
    return {
        "w_up": jax.random.normal(k1, (D, F), jnp.float32) * s_in,
        "w_down": jax.random.normal(k2, (F, D), jnp.float32) * s_out,
    }


def mlp(params, x: Array, cfg: ModelConfig) -> Array:
    dt = x.dtype
    backend = kops.current_backend()
    if backend != "off":
        # §Kernels: the MLP matmuls on the TaxoNN kernel datapath
        if cfg.mlp_kind in ("swiglu", "geglu"):
            actk = "silu" if cfg.mlp_kind == "swiglu" else "gelu"
            g = _dense_unit(x, params["w_gate"], actk, backend)
            u = _dense_unit(x, params["w_up"], "identity", backend)
            return _dense_unit(g * u, params["w_down"], "identity", backend)
        h = _dense_unit(x, params["w_up"], "gelu", backend)
        return _dense_unit(h, params["w_down"], "identity", backend)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else functools.partial(
            jax.nn.gelu, approximate=True)
        g = act(x @ params["w_gate"].astype(dt))
        u = x @ params["w_up"].astype(dt)
        return (g * u) @ params["w_down"].astype(dt)
    h = jax.nn.gelu(x @ params["w_up"].astype(dt), approximate=True)
    return h @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# MoE (top-k routing, sort-based capacity dispatch, shared experts)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in, s_out = D ** -0.5, F ** -0.5
    p = {
        "router": jax.random.normal(k1, (D, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(k2, (E, D, F), jnp.float32) * s_in,
        "w_up": jax.random.normal(k3, (E, D, F), jnp.float32) * s_in,
        "w_down": jax.random.normal(k4, (E, F, D), jnp.float32) * s_out,
    }
    if cfg.num_shared_experts:
        Fs = cfg.moe_d_ff * cfg.num_shared_experts
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "w_gate": jax.random.normal(ks[0], (D, Fs), jnp.float32) * s_in,
            "w_up": jax.random.normal(ks[1], (D, Fs), jnp.float32) * s_in,
            "w_down": jax.random.normal(ks[2], (Fs, D), jnp.float32) * Fs ** -0.5,
        }
    return p


def _moe_experts_shardmap(x: Array, wg: Array, wu: Array, wd: Array,
                          slot: Array, keep: Array, sw: Array, stok: Array,
                          C: int, E: int, cfg: ModelConfig) -> Array:
    """§Perf "moe_rowcombine": the whole routed-expert path (dispatch scatter
    -> expert matmuls -> token-space combine) inside one shard_map.

    Collective profile per layer: ONE token-space psum [b,t,D] forward and
    ONE for d_tokens backward.  The pjit baseline instead reduces in
    dispatch-buffer space ([b,E,C,D], C*E ~ 1.25*K*t rows) — and its
    backward psums the buffer cotangents for w_gate AND w_up separately.

    EP (E %% model == 0): each shard scatters/computes only its experts.
    TP-inside-expert (F sharded): dispatch replicated, matmuls F-local,
    partial outputs combined then psum'd.  Routing tensors (slot/keep/sw/
    stok) are cheap and computed outside (replicated over model).
    """
    dt = x.dtype
    mesh = jax.sharding.get_abstract_mesh()
    axes = dict(mesh.shape)
    m = axes.get("model", 1)
    baxes = tuple(a for a in ("pod", "data") if a in axes)
    b_entry = baxes if len(baxes) > 1 else baxes[0]
    ep = E % m == 0 and E >= m
    if ep:
        w_in_spec = P("model", None, None)    # [E, D, F]
        wd_spec = P("model", None, None)      # [E, F, D]
    else:
        w_in_spec = P(None, None, "model")    # F sharded
        wd_spec = P(None, "model", None)
    vec = P(b_entry, None)
    x_spec = P(b_entry, None, None)

    act = jax.nn.silu if cfg.mlp_kind == "swiglu" else functools.partial(
        jax.nn.gelu, approximate=True)

    def f(x_l, wg_l, wu_l, wd_l, slot_l, keep_l, sw_l, stok_l):
        bl, t, d = x_l.shape
        e_l = wg_l.shape[0]
        if ep:
            e0 = lax.axis_index("model") * e_l
            se_l = slot_l // C                # global expert id (trash -> E)
            pos_l = slot_l - se_l * C
            keep2 = keep_l & (se_l >= e0) & (se_l < e0 + e_l)
            lslot = jnp.where(keep2, (se_l - e0) * C + pos_l, e_l * C)
        else:
            keep2 = keep_l
            lslot = jnp.where(keep_l, slot_l, e_l * C)
        rows_l = jnp.arange(bl)[:, None]
        src = jnp.take_along_axis(x_l, stok_l[..., None], axis=1)
        buf = jnp.zeros((bl, e_l * C + 1, d), dt).at[rows_l, lslot].set(src)
        buf = buf[:, :-1].reshape(bl, e_l, C, d)

        g = act(jnp.einsum("becd,edf->becf", buf, wg_l))
        u = jnp.einsum("becd,edf->becf", buf, wu_l)
        eo = jnp.einsum("becf,efd->becd", g * u, wd_l)

        gathered = eo.reshape(bl, e_l * C, -1)
        lslot_g = jnp.where(keep2, lslot, 0)
        picked = jnp.take_along_axis(gathered, lslot_g[..., None], axis=1)
        contrib = jnp.where(keep2[..., None], picked * sw_l[..., None], 0.0)
        out = jnp.zeros((bl, t, gathered.shape[-1]), dt) \
            .at[rows_l, stok_l].add(contrib.astype(dt))
        return lax.psum(out, "model")

    return jax.shard_map(
        f, mesh=mesh,
        in_specs=(x_spec, w_in_spec, w_in_spec, wd_spec, vec, vec, vec, vec),
        out_specs=x_spec, check_vma=False,
    )(x, wg.astype(dt), wu.astype(dt), wd.astype(dt),
      slot, keep, sw.astype(dt), stok)


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_aux_from_stats(frac: Array, probs_mean: Array) -> Array:
    """Load-balance aux loss from its two batch-mean statistics.

    ``aux = E * sum_e frac[e] * probs_mean[e]`` is BILINEAR in two batch
    means, so it does not decompose over microbatches (the mean of
    per-microbatch aux values is NOT the full-batch aux).  Callers that
    split the batch — the stage-sharded pipeline — accumulate ``frac`` and
    ``probs_mean`` separately (``moe_verbose``), average them across
    microbatches, and recombine here to reproduce full-batch semantics.
    """
    return jnp.sum(frac * probs_mean) * frac.shape[-1]


def moe_verbose(params, x: Array, cfg: ModelConfig
                ) -> tuple[Array, Array, Array]:
    """Top-k routed MoE with PER-SEQUENCE sort-based capacity dispatch.

    Dispatch (sort, rank, scatter) happens independently per batch row along
    the last axis, so under data parallelism it is entirely local — no
    distributed sorts, no giant global dispatch buffers (a global-token sort
    at 1M tokens costs hundreds of GiB of temps and a distributed sort).
    Capacity is per sequence: C = ceil(T*K/E * capacity_factor).

    Returns (output, frac [E], probs_mean [E]) — the aux-loss statistics
    exposed separately so microbatched callers can accumulate them (see
    ``moe_aux_from_stats``); ``moe`` below contracts them to the scalar.
    """
    dt = x.dtype
    b, t, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = moe_capacity(cfg, t)
    nk = t * K

    logits = jnp.einsum("btd,de->bte", x, params["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = lax.top_k(probs, K)                    # [b,t,K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balance aux statistics (expert pick fraction, mean router prob)
    frac = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1, 2))
    probs_mean = jnp.mean(probs, axis=(0, 1))

    # ---- per-row sort-based dispatch (all ops batched over b) -----------
    flat_e = top_e.reshape(b, nk)
    flat_w = top_p.reshape(b, nk).astype(dt)

    order = jnp.argsort(flat_e, axis=-1, stable=True)      # per-row sort
    se = jnp.take_along_axis(flat_e, order, axis=-1)       # [b,nk]
    sw = jnp.take_along_axis(flat_w, order, axis=-1)
    stok = order // K                                      # source token in row

    counts = jnp.sum(flat_e[:, :, None] == jnp.arange(E)[None, None, :],
                     axis=1)                               # [b,E]
    offsets = jnp.cumsum(counts, axis=-1) - counts
    pos_in_e = jnp.arange(nk)[None, :] - jnp.take_along_axis(offsets, se, -1)
    keep = pos_in_e < C

    slot = jnp.where(keep, se * C + pos_in_e, E * C)       # E*C = trash slot
    rows = jnp.arange(b)[:, None]
    act = jax.nn.silu if cfg.mlp_kind == "swiglu" else functools.partial(
        jax.nn.gelu, approximate=True)

    if perf_opt("moe_rowcombine") and model_axis_size_ctx() > 1:
        # §Perf option: dispatch + expert matmuls + combine inside one
        # shard_map -> exactly one token-space psum fwd and one bwd
        # (see _moe_experts_shardmap).
        out = _moe_experts_shardmap(
            x, params["w_gate"], params["w_up"], params["w_down"],
            slot, keep, sw, stok, C, E, cfg)
        out = constrain(out, "btd")
    else:
        src = constrain(
            jnp.take_along_axis(x, stok[..., None], axis=1), "btd")  # [b,nk,D]
        buf = jnp.zeros((b, E * C + 1, D), dt).at[rows, slot].set(src)
        # explicit batch constraint: the batched scatter otherwise leaves
        # the partitioner free to replicate the dispatch buffer over the
        # data axes (16x flops). Expert/F sharding propagates from weights.
        buf = constrain(buf[:, :-1].reshape(b, E, C, D), "becd")
        # ---- grouped expert matmuls (EP over experts when divisible) ----
        g = act(jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(dt)))
        u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(dt))
        eo = jnp.einsum("becf,efd->becd", g * u, params["w_down"].astype(dt))
        # ---- combine back (per-row gather + weighted scatter-add) -------
        gathered = constrain(eo, "becd").reshape(b, E * C, D)
        safe_slot = jnp.where(keep, slot, 0)
        picked = constrain(
            jnp.take_along_axis(gathered, safe_slot[..., None], axis=1), "btd")
        contrib = jnp.where(keep[..., None], picked * sw[..., None], 0.0)
        out = constrain(
            jnp.zeros((b, t, D), dt).at[rows, stok].add(contrib), "btd")

    if cfg.num_shared_experts:
        sh = params["shared"]
        gs = act(x @ sh["w_gate"].astype(dt))
        us = x @ sh["w_up"].astype(dt)
        out = out + (gs * us) @ sh["w_down"].astype(dt)

    return out, frac, probs_mean


def moe(params, x: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    """``moe_verbose`` with the statistics contracted to the standard
    scalar load-balance aux loss."""
    out, frac, probs_mean = moe_verbose(params, x, cfg)
    return out, moe_aux_from_stats(frac, probs_mean)
