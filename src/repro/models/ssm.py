"""Mamba2 / SSD (state-space duality) blocks.

TPU adaptation: we implement the *chunked* SSD algorithm — intra-chunk work
is dense matmuls (MXU-friendly), the inter-chunk recurrence is a short
``lax.scan`` over T/Q chunk states.  A step-by-step ``lax.scan`` over time
would serialise 4096+ elementwise steps and starve the MXU; the chunked dual
form is the TPU-native formulation of the same recurrence.

  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T        (per head, A scalar)
  y_t = C_t . h_t + D_skip * x_t

Shapes: x [B,T,H,P] (P = head dim), B,C [B,T,N] (single group), dt [B,T,H].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.util.scan import xscan
from repro.models.layers import init_rmsnorm, rmsnorm

Array = jax.Array


def init_mamba(key, cfg: ModelConfig):
    """Component-wise projections (TP-friendly: w_z/w_x shard on d_inner
    columns; w_B/w_C/w_dt are tiny and replicated — the packed zxbcdt matrix
    of the reference implementation splits at TP-hostile boundaries)."""
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    s = D ** -0.5
    # dt bias: softplus^-1 of dt in [1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(ks[5], (H,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "w_z": jax.random.normal(ks[0], (D, di), jnp.float32) * s,
        "w_x": jax.random.normal(ks[1], (D, di), jnp.float32) * s,
        "w_B": jax.random.normal(ks[2], (D, N), jnp.float32) * s,
        "w_C": jax.random.normal(ks[3], (D, N), jnp.float32) * s,
        "w_dt": jax.random.normal(ks[4], (D, H), jnp.float32) * s,
        "conv_x": jax.random.normal(
            ks[6], (cfg.conv_kernel, di), jnp.float32) * di ** -0.5,
        "conv_B": jax.random.normal(
            ks[7], (cfg.conv_kernel, N), jnp.float32) * N ** -0.5,
        "conv_C": jax.random.normal(
            jax.random.fold_in(key, 99), (cfg.conv_kernel, N),
            jnp.float32) * N ** -0.5,
        "conv_b_x": jnp.zeros((di,), jnp.float32),
        "conv_b_B": jnp.zeros((N,), jnp.float32),
        "conv_b_C": jnp.zeros((N,), jnp.float32),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D_skip": jnp.ones((H,), jnp.float32),
        "gate_norm": init_rmsnorm(di),
        "out_proj": jax.random.normal(
            jax.random.fold_in(key, 100), (di, D), jnp.float32) * di ** -0.5,
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over time. x: [B,T,C]; w: [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K=4: unrolled shifts beat conv_general on TPU
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out + b


def _segsum(dA: Array) -> Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} dA[..., k] (i>=j),
    -inf below the causal diagonal.  dA: [..., Q]."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]            # cum_i - cum_j
    iidx = jnp.arange(q)
    mask = iidx[:, None] >= iidx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int, h0: Array | None = None):
    """Chunked SSD. x: [B,T,H,P]; dt: [B,T,H]; A: [H]; B,C: [B,T,N].

    Returns (y [B,T,H,P], h_final [B,H,N,P]).
    """
    b, t_orig, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, t_orig)
    pad = (-t_orig) % q
    if pad:  # dt=0 padding: decay exp(0)=1 and zero input -> state-neutral
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    t = t_orig + pad
    nc = t // q

    dA = dt * A  # [B,T,H], negative (f32)
    xdt = (x.astype(jnp.float32) * dt[..., None]).astype(x.dtype)

    def r(v, extra):  # reshape to chunks
        return v.reshape((b, nc, q) + extra)

    xc, dAc = r(xdt, (h, p)), r(dA, (h,))
    Bc, Cc = r(B, (n,)), r(C, (n,))

    cum = jnp.cumsum(dAc, axis=2)                         # [B,nc,Q,H]

    # ---- intra-chunk (dense matmuls) ---------------------------------
    L = jnp.exp(_segsum(dAc.transpose(0, 1, 3, 2)))       # [B,nc,H,Q,Q]
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc,
                        preferred_element_type=jnp.float32)
    scores = scores[:, :, None] * L                       # [B,nc,H,Q,Q]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores.astype(x.dtype), xc)

    # ---- chunk states --------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # [B,nc,Q,H]
    S = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc,
                   decay_to_end.astype(x.dtype), xc)      # [B,nc,H,N,P]

    # ---- inter-chunk recurrence (short scan over nc) -------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # [B,nc,H]

    def step(hprev, inp):
        s_c, d_c = inp
        hnew = hprev * d_c[..., None, None] + s_c
        return hnew, hprev                                 # emit state ENTERING chunk

    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)
    hT, h_in = xscan(step,
                        h0.astype(jnp.float32),
                        (S.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
                         chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                  # [B,nc,H,N,P]

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc, jnp.exp(cum).astype(x.dtype),
                         h_in.astype(x.dtype))
    y = (y_intra + y_inter).reshape(b, t, h, p).astype(x.dtype)
    return y[:, :t_orig], hT


def mamba_forward(params, xin: Array, cfg: ModelConfig,
                  h0: Array | None = None,
                  conv0: Array | None = None):
    """Full-sequence Mamba2 block (post-norm residual handled by caller).

    xin: [B, T, D] (already normed). Returns (out [B,T,D], (h_final, conv_tail)).
    conv_tail packs the last (K-1) pre-conv values of [x | B | C] on the
    channel axis (width d_inner + 2N) for decode stitching.
    """
    dt_ = xin.dtype
    b, t, _ = xin.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    z = xin @ params["w_z"].astype(dt_)
    xr = xin @ params["w_x"].astype(dt_)
    Br = xin @ params["w_B"].astype(dt_)
    Cr = xin @ params["w_C"].astype(dt_)
    dt_raw = xin @ params["w_dt"].astype(dt_)

    def conv(v, w, bias, c0):
        if c0 is not None:
            ext = jnp.concatenate([c0.astype(dt_), v], axis=1)
            return _causal_conv(ext, w.astype(dt_),
                                bias.astype(dt_))[:, c0.shape[1]:]
        return _causal_conv(v, w.astype(dt_), bias.astype(dt_))

    c0x = c0B = c0C = None
    if conv0 is not None:
        c0x, c0B, c0C = (conv0[..., :di], conv0[..., di:di + N],
                         conv0[..., di + N:])
    xs = jax.nn.silu(conv(xr, params["conv_x"], params["conv_b_x"], c0x))
    B = jax.nn.silu(conv(Br, params["conv_B"], params["conv_b_B"], c0B))
    C = jax.nn.silu(conv(Cr, params["conv_C"], params["conv_b_C"], c0C))

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                          # [H]

    x_heads = xs.reshape(b, t, H, P)
    y, hT = ssd_chunked(x_heads, dt, A, B, C, cfg.ssm_chunk, h0)
    y = y + x_heads * params["D_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(b, t, di)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    k = cfg.conv_kernel - 1
    conv_tail = jnp.concatenate(
        [xr[:, -k:, :], Br[:, -k:, :], Cr[:, -k:, :]], axis=-1)
    return out, (hT, conv_tail)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                       jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }


def mamba_decode(params, xin: Array, cfg: ModelConfig, cache: dict):
    """Single-token Mamba2 step. xin: [B, 1, D]. O(1) state update."""
    dt_ = xin.dtype
    b = xin.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    z = xin @ params["w_z"].astype(dt_)
    xr = xin @ params["w_x"].astype(dt_)
    Br = xin @ params["w_B"].astype(dt_)
    Cr = xin @ params["w_C"].astype(dt_)
    dt_raw = xin @ params["w_dt"].astype(dt_)

    xbc = jnp.concatenate([xr, Br, Cr], axis=-1)          # [B,1,di+2N]
    conv_buf = jnp.concatenate([cache["conv"].astype(dt_), xbc], axis=1)
    w = jnp.concatenate([params["conv_x"], params["conv_B"],
                         params["conv_C"]], axis=-1).astype(dt_)
    bias = jnp.concatenate([params["conv_b_x"], params["conv_b_B"],
                            params["conv_b_C"]]).astype(dt_)
    conv_out = jnp.einsum("bkc,kc->bc", conv_buf, w) + bias
    xbc_act = jax.nn.silu(conv_out)[:, None, :]
    xs, B, C = jnp.split(xbc_act, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]  # [B,H]
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                   # [B,H]

    x_heads = xs.reshape(b, H, P).astype(jnp.float32)
    Bv = B[:, 0].astype(jnp.float32)                       # [B,N]
    Cv = C[:, 0].astype(jnp.float32)
    hx = cache["h"] * dA[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bv, dt, x_heads)
    y = jnp.einsum("bn,bhnp->bhp", Cv, hx).astype(dt_)
    y = y + x_heads.astype(dt_) * params["D_skip"].astype(dt_)[None, :, None]
    y = y.reshape(b, 1, di)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    return out, {"h": hx, "conv": conv_buf[:, 1:, :].astype(cache["conv"].dtype)}
