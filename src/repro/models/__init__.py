from repro.models.config import ModelConfig, ShapeCell, SHAPE_CELLS
from repro.models import layers, ssm, blocks, lm

__all__ = ["ModelConfig", "ShapeCell", "SHAPE_CELLS", "layers", "ssm", "blocks", "lm"]
