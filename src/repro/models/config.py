"""Model configuration for all assigned architectures.

One ``ModelConfig`` describes any member of the supported families:
  dense   — llama-style decoder-only transformer (GQA/MQA, SWA optional)
  moe     — dense + mixture-of-experts FFN (top-k routing, shared experts)
  ssm     — Mamba2 / SSD attention-free stack
  hybrid  — Mamba2 backbone + shared (weight-tied) attention blocks (Zamba2)
  encdec  — encoder-decoder transformer (Whisper); frontend stubbed
  vlm     — decoder-only backbone consuming text tokens + precomputed patch
            embeddings (LLaVA-NeXT); vision tower stubbed

The assigned input-shape cells are also defined here (``SHAPE_CELLS``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None        # default: d_model // num_heads
    # §Perf "pad_heads": allocate this many q heads (>= num_heads, grouped
    # per KV head) so head count divides the TP axis; heads beyond
    # num_heads are dead (wo rows masked to zero -> function-identical).
    padded_heads: Optional[int] = None
    qkv_bias: bool = False                # qwen1.5
    swa_window: Optional[int] = None      # sliding-window attention (mistral-like)
    use_rope: bool = True                 # whisper uses sinusoidal embeds instead
    scale_embed: bool = False             # gemma multiplies embeds by sqrt(D)
    rope_theta: float = 10_000.0
    mlp_kind: str = "swiglu"              # swiglu | geglu | gelu
    norm_kind: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = True

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                     # per-expert hidden size
    capacity_factor: float = 1.25

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # --- hybrid (Zamba2) ---
    attn_every: int = 0                   # shared attn block applied every K layers

    # --- encoder-decoder (Whisper) ---
    num_encoder_layers: int = 0
    encoder_seq: int = 1500               # Whisper 30s spectrogram frames

    # --- VLM (LLaVA) ---
    num_patches: int = 0                  # precomputed patch embeddings per image

    # --- numerics ---
    compute_dtype: str = "bfloat16"       # matmul/activation dtype (roofline target)
    param_dtype: str = "float32"          # master weights
    logit_chunk: int = 1024               # sequence chunking for the CE loss head

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(
                self, "head_dim",
                self.d_model // max(self.num_heads, 1) if self.num_heads else 0,
            )

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode memory is sub-quadratic in context (SSM / hybrid).

        Pure full-attention archs skip the long_500k cell (see DESIGN.md).
        Hybrid qualifies: its attention KV is needed only at 1/attn_every
        density and its decode state is O(1) in the Mamba path.
        """
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def gqa_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6*N*D roofline term)."""
        return _count_params(self)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: shared + top-k experts only)."""
        return _count_params(self, active_only=True)


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    D, V = cfg.d_model, cfg.vocab_size
    total = V * D  # embedding
    if not cfg.tie_embeddings:
        total += V * D

    def attn_params() -> int:
        hd = cfg.head_dim
        if cfg.use_mla:
            # q proj, kv down (lora), kv up, rope key, out proj
            qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
            p = D * cfg.num_heads * qk_dim                       # wq
            p += D * (cfg.kv_lora_rank + cfg.qk_rope_dim)        # kv down + k_pe
            p += cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
            p += cfg.num_heads * cfg.v_head_dim * D              # wo
            return p
        p = D * cfg.num_heads * hd + 2 * D * cfg.num_kv_heads * hd
        p += cfg.num_heads * hd * D
        if cfg.qkv_bias:
            p += (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
        return p

    def mlp_params(d_ff: int) -> int:
        if cfg.mlp_kind in ("swiglu", "geglu"):
            return 3 * D * d_ff
        return 2 * D * d_ff

    def moe_layer_params() -> int:
        router = D * cfg.num_experts
        shared = cfg.num_shared_experts * 3 * D * cfg.moe_d_ff
        if active_only:
            routed = cfg.experts_per_token * 3 * D * cfg.moe_d_ff
        else:
            routed = cfg.num_experts * 3 * D * cfg.moe_d_ff
        return router + shared + routed

    def mamba_params() -> int:
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        p = D * (2 * di + 2 * N + H)     # in_proj -> z, x, B, C, dt
        p += cfg.conv_kernel * (di + 2 * N)  # depthwise conv over x, B, C
        p += H * 2                        # A_log, D skip (per head)
        p += di * D                       # out proj
        p += di                           # gate norm
        return p

    norm = 2 * D  # two pre-norms per block (approx; ssm blocks have one)

    if cfg.family in ("dense", "vlm"):
        total += cfg.num_layers * (attn_params() + mlp_params(cfg.d_ff) + norm)
    elif cfg.family == "moe":
        total += cfg.num_layers * (attn_params() + moe_layer_params() + norm)
    elif cfg.family == "ssm":
        total += cfg.num_layers * (mamba_params() + D)
    elif cfg.family == "hybrid":
        total += cfg.num_layers * (mamba_params() + D)
        # one weight-tied attention + mlp block (counted once)
        total += attn_params() + mlp_params(cfg.d_ff) + norm
    elif cfg.family == "encdec":
        enc = cfg.num_encoder_layers * (attn_params() + mlp_params(cfg.d_ff) + norm)
        dec = cfg.num_layers * (2 * attn_params() + mlp_params(cfg.d_ff) + 3 * D)
        total += enc + dec
    total += D  # final norm
    return int(total)


# ---------------------------------------------------------------------------
# Assigned input-shape cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {c.name: c for c in SHAPE_CELLS}


def cell_is_applicable(cfg: ModelConfig, cell: ShapeCell) -> bool:
    """long_500k requires sub-quadratic attention (SSM/hybrid only)."""
    if cell.name == "long_500k":
        return cfg.supports_long_context
    return True
