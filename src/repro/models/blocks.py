"""Per-family block functions: init + full-sequence apply + decode apply.

Uniform interface consumed by both the autodiff path and the TaxoNN
manual-BP engine (core/taxonn.py):

  apply(params, x, cfg, positions) -> (new_x, aux_loss_scalar)
  decode(params, x, cfg, cache, pos) -> (new_x, new_cache)

Residuals and pre-norms are internal to the block; ``new_x`` is the full
residual-stream output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.api import constrain
from repro.kernels import decode_prologue as DP
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Transformer block (dense / moe / vlm backbone; encoder variant)
# ---------------------------------------------------------------------------

def init_transformer_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": L.init_norm(cfg.d_model, cfg),
        "mlp_norm": L.init_norm(cfg.d_model, cfg),
    }
    if cfg.use_mla:
        p["attn"] = L.init_mla(k1, cfg)
    else:
        p["attn"] = L.init_attention(k1, cfg)
    if cfg.family == "moe":
        p["moe"] = L.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    return p


def transformer_block(params, x: Array, cfg: ModelConfig, positions: Array,
                      causal: bool = True, moe_aux_parts: bool = False):
    """``moe_aux_parts=True`` returns the load-balance aux as its two
    batch-mean statistics ``{"frac", "p"}`` instead of the contracted
    scalar — the aux is bilinear in those means, so microbatched callers
    (the stage-sharded pipeline) must accumulate the parts and recombine
    via ``layers.moe_aux_from_stats`` to keep full-batch semantics."""
    x = constrain(x, "btd")
    h = L.apply_norm(params["attn_norm"], x, cfg)
    if cfg.use_mla:
        attn_out = L.mla_attention(params["attn"], h, cfg, positions)
    else:
        attn_out = L.attention(params["attn"], h, cfg, positions, causal=causal)
    x = x + attn_out
    h = L.apply_norm(params["mlp_norm"], x, cfg)
    if cfg.family == "moe":
        if moe_aux_parts:
            mlp_out, frac, probs_mean = L.moe_verbose(params["moe"], h, cfg)
            aux = {"frac": frac, "p": probs_mean}
        else:
            mlp_out, aux = L.moe(params["moe"], h, cfg)
    else:
        # non-moe blocks have no aux statistics; the flag only changes the
        # moe branch (callers set it for cfg.family == "moe" stacks)
        mlp_out, aux = L.mlp(params["mlp"], h, cfg), jnp.float32(0.0)
    x = constrain(x + mlp_out, "btd")
    return x, aux


def init_block_cache(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    if cfg.use_mla:
        return L.init_mla_cache(cfg, batch, max_len, dtype)
    return L.init_kv_cache(cfg, batch, max_len, dtype)


def transformer_block_decode(params, x: Array, cfg: ModelConfig, cache, pos):
    if cfg.use_mla:
        h = L.apply_norm(params["attn_norm"], x, cfg)
        attn_out, cache = L.mla_decode(params["attn"], h, cfg, cache, pos)
    elif DP.prologue_active(cfg, x):
        # §Kernels: fused RMSNorm+QKV+rope prologue — one HBM round-trip
        # for the whole decode prologue, then the shared attention tail
        q, k, v = DP.decode_prologue(
            params["attn_norm"], params["attn"], x, cfg,
            jnp.full((x.shape[0],), pos))
        attn_out, cache = L.attention_decode_tail(
            params["attn"], q, k, v, x.dtype, cfg, cache, pos)
    else:
        h = L.apply_norm(params["attn_norm"], x, cfg)
        attn_out, cache = L.attention_decode(params["attn"], h, cfg, cache, pos)
    x = x + attn_out
    h = L.apply_norm(params["mlp_norm"], x, cfg)
    if cfg.family == "moe":
        mlp_out, _ = L.moe(params["moe"], h, cfg)
    else:
        mlp_out = L.mlp(params["mlp"], h, cfg)
    return x + mlp_out, cache


def transformer_block_prefill(params, x: Array, cfg: ModelConfig,
                              positions: Array, cache_len: int,
                              cache_dtype=jnp.bfloat16):
    """Forward + seed the decode cache from this layer's K/V."""
    x0 = constrain(x, "btd")
    h = L.apply_norm(params["attn_norm"], x0, cfg)
    if cfg.use_mla:
        attn_out, (ckv, kpe) = L.mla_attention(params["attn"], h, cfg,
                                               positions, return_cache=True)
        t = ckv.shape[1]
        cache = {
            "ckv": jnp.pad(ckv, ((0, 0), (0, cache_len - t), (0, 0))).astype(cache_dtype),
            "kpe": jnp.pad(kpe, ((0, 0), (0, cache_len - t), (0, 0))).astype(cache_dtype),
        }
    else:
        attn_out, (k, v) = L.attention(params["attn"], h, cfg, positions,
                                       causal=True, return_kv=True)
        length = cache_len if cfg.swa_window is None else min(
            cfg.swa_window, cache_len)
        cache = {"k": L.fill_ring(k, length).astype(cache_dtype),
                 "v": L.fill_ring(v, length).astype(cache_dtype)}
    x = x0 + attn_out
    h = L.apply_norm(params["mlp_norm"], x, cfg)
    if cfg.family == "moe":
        mlp_out, _ = L.moe(params["moe"], h, cfg)
    else:
        mlp_out = L.mlp(params["mlp"], h, cfg)
    return constrain(x + mlp_out, "btd"), cache


# ---------------------------------------------------------------------------
# Mamba2 block (ssm / hybrid backbone)
# ---------------------------------------------------------------------------

def init_mamba_block(key, cfg: ModelConfig):
    return {"norm": L.init_norm(cfg.d_model, cfg), "mamba": S.init_mamba(key, cfg)}


def mamba_block(params, x: Array, cfg: ModelConfig, positions=None):
    x = constrain(x, "btd")
    h = L.apply_norm(params["norm"], x, cfg)
    out, _ = S.mamba_forward(params["mamba"], h, cfg)
    return constrain(x + out, "btd"), jnp.float32(0.0)


def mamba_block_decode(params, x: Array, cfg: ModelConfig, cache, pos):
    h = L.apply_norm(params["norm"], x, cfg)
    out, cache = S.mamba_decode(params["mamba"], h, cfg, cache)
    return x + out, cache


def mamba_block_prefill(params, x: Array, cfg: ModelConfig, positions=None,
                        cache_dtype=jnp.bfloat16):
    x0 = constrain(x, "btd")
    h = L.apply_norm(params["norm"], x0, cfg)
    out, (hT, conv_tail) = S.mamba_forward(params["mamba"], h, cfg)
    cache = {"h": hT, "conv": conv_tail.astype(cache_dtype)}
    return constrain(x0 + out, "btd"), cache


# ---------------------------------------------------------------------------
# Whisper decoder block (self-attn + cross-attn + mlp)
# ---------------------------------------------------------------------------

def init_decoder_block(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": L.init_norm(cfg.d_model, cfg),
        "self_attn": L.init_attention(k1, cfg),
        "cross_norm": L.init_norm(cfg.d_model, cfg),
        "cross_attn": L.init_attention(k2, cfg),
        "mlp_norm": L.init_norm(cfg.d_model, cfg),
        "mlp": L.init_mlp(k3, cfg),
    }


def _cross_attention(params, x: Array, enc_out: Array, cfg: ModelConfig):
    """Cross-attention: queries from decoder x, keys/values from enc_out."""
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dt))
    k = L._expand_kv(k, cfg.gqa_groups)
    v = L._expand_kv(v, cfg.gqa_groups)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1).astype(dt)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(dt))


def decoder_block(params, x: Array, cfg: ModelConfig, positions: Array,
                  enc_out: Array):
    x = constrain(x, "btd")
    h = L.apply_norm(params["self_norm"], x, cfg)
    x = x + L.attention(params["self_attn"], h, cfg, positions, causal=True)
    h = L.apply_norm(params["cross_norm"], x, cfg)
    x = x + _cross_attention(params["cross_attn"], h, enc_out, cfg)
    h = L.apply_norm(params["mlp_norm"], x, cfg)
    x = constrain(x + L.mlp(params["mlp"], h, cfg), "btd")
    return x, jnp.float32(0.0)


def init_decoder_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int,
                       dtype=jnp.bfloat16):
    """Self-attn KV ring + precomputed cross-attn K/V (filled at prefill)."""
    hd = cfg.head_dim
    return {
        "self": L.init_kv_cache(cfg, batch, max_len, dtype),
        "cross_k": jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dtype),
    }


def decoder_block_decode(params, x: Array, cfg: ModelConfig, cache, pos):
    dt = x.dtype
    h = L.apply_norm(params["self_norm"], x, cfg)
    attn_out, self_cache = L.attention_decode(params["self_attn"], h, cfg,
                                              cache["self"], pos)
    x = x + attn_out
    h = L.apply_norm(params["cross_norm"], x, cfg)
    q = jnp.einsum("btd,dhk->bthk", h, params["cross_attn"]["wq"].astype(dt))
    k = L._expand_kv(cache["cross_k"].astype(dt), cfg.gqa_groups)
    v = L._expand_kv(cache["cross_v"].astype(dt), cfg.gqa_groups)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * cfg.head_dim ** -0.5
    p = jax.nn.softmax(s, axis=-1).astype(dt)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    x = x + jnp.einsum("bthk,hkd->btd", out,
                       params["cross_attn"]["wo"].astype(dt))
    h = L.apply_norm(params["mlp_norm"], x, cfg)
    x = x + L.mlp(params["mlp"], h, cfg)
    return x, {"self": self_cache, "cross_k": cache["cross_k"],
               "cross_v": cache["cross_v"]}


def decoder_block_prefill(params, x: Array, cfg: ModelConfig, positions: Array,
                          enc_out: Array, cache_len: int,
                          cache_dtype=jnp.bfloat16):
    dt = x.dtype
    h = L.apply_norm(params["self_norm"], x, cfg)
    attn_out, (k, v) = L.attention(params["self_attn"], h, cfg, positions,
                                   causal=True, return_kv=True)
    self_cache = {"k": L.fill_ring(k, cache_len).astype(cache_dtype),
                  "v": L.fill_ring(v, cache_len).astype(cache_dtype)}
    x = x + attn_out
    h = L.apply_norm(params["cross_norm"], x, cfg)
    ck = jnp.einsum("bsd,dhk->bshk", enc_out,
                    params["cross_attn"]["wk"].astype(dt))
    cv = jnp.einsum("bsd,dhk->bshk", enc_out,
                    params["cross_attn"]["wv"].astype(dt))
    x = x + _cross_attention(params["cross_attn"], h, enc_out, cfg)
    h = L.apply_norm(params["mlp_norm"], x, cfg)
    x = x + L.mlp(params["mlp"], h, cfg)
    cache = {"self": self_cache, "cross_k": ck.astype(cache_dtype),
             "cross_v": cv.astype(cache_dtype)}
    return x, cache


def fill_cross_cache(params_stacked, enc_out: Array, cfg: ModelConfig,
                     dtype=jnp.bfloat16):
    """Compute cross-attn K/V for every decoder layer from encoder output."""
    def one(p):
        dt = enc_out.dtype
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wv"].astype(dt))
        return k.astype(dtype), v.astype(dtype)
    return jax.vmap(one)(params_stacked)  # leading L axis
