"""Deterministic, step-indexed data pipelines with straggler tolerance.

Key property: ``batch_at(step)`` is a pure function of (seed, step,
shard_id) — resume after restart is exact (no sample skew between hosts),
and any host can reconstruct any other host's shard for recovery.

StragglerTolerantLoader wraps a (possibly slow) producer with a bounded
prefetch queue and a per-step deadline: when a fetch exceeds the deadline
the loader substitutes the previous batch and records a skip — the
step-time tail is bounded by the deadline instead of the slowest host
(the standard straggler-mitigation contract, simulated in-process here).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional

import numpy as np


class SyntheticLMDataset:
    """Deterministic synthetic token stream with learnable structure.

    Tokens follow a noisy Markov chain (x_{t+1} = (a*x_t + b) % V with
    noise), so cross-entropy is reducible and convergence benchmarks are
    meaningful, unlike uniform random labels.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, shard_id: int = 0, num_shards: int = 1,
                 noise: float = 0.1):
        assert global_batch % num_shards == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.shard = shard_id
        self.noise = noise
        self.a = 31
        self.b = 17

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)
        x0 = rng.integers(0, self.vocab, size=(self.local_batch, 1))
        toks = [x0]
        for _ in range(self.seq):
            nxt = (toks[-1] * self.a + self.b) % self.vocab
            flip = rng.random((self.local_batch, 1)) < self.noise
            rand = rng.integers(0, self.vocab, size=(self.local_batch, 1))
            toks.append(np.where(flip, rand, nxt))
        seq = np.concatenate(toks, axis=1).astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


class SyntheticClassificationDataset:
    """Deterministic image-like classification set (the paper's MNIST/SVHN
    stand-in): class templates + Gaussian noise, fixed train/test split."""

    def __init__(self, input_dim: int = 784, num_classes: int = 10,
                 n_train: int = 4096, n_test: int = 1024, seed: int = 0,
                 noise: float = 0.35):
        rng = np.random.default_rng(seed)
        self.templates = rng.standard_normal((num_classes, input_dim)) \
            .astype(np.float32)
        self.num_classes = num_classes

        def make(n, salt):
            r = np.random.default_rng(seed + salt)
            y = r.integers(0, num_classes, size=n)
            x = self.templates[y] + noise * r.standard_normal(
                (n, input_dim)).astype(np.float32)
            return x.astype(np.float32), y.astype(np.int32)

        self.train = make(n_train, 1)
        self.test = make(n_test, 2)

    def train_batches(self, batch: int, steps: int, seed: int = 0
                      ) -> Iterator[tuple]:
        x, y = self.train
        rng = np.random.default_rng(seed)
        for _ in range(steps):
            idx = rng.integers(0, len(y), size=batch)
            yield x[idx], y[idx]


class DataProducerError(RuntimeError):
    """The background fetch raised; re-surfaced on the consumer thread."""

    def __init__(self, step: int, cause: BaseException):
        super().__init__(f"data producer failed at step {step}: {cause!r}")
        self.step = step
        self.cause = cause


class StragglerTolerantLoader:
    """Bounded-queue prefetch with a per-step deadline and step-tagged
    delivery.

    fetch_fn(step) -> batch runs in a background thread; ``get(step)``
    returns within ~deadline_s even if the producer stalls, substituting
    the last good batch and counting a skip.

    Correctness contracts (each drilled in tests/test_fault_tolerance.py):

      * queue entries are tagged with the step they were fetched FOR and
        ``get(step)`` only delivers a matching tag — after a deadline skip
        the late batch eventually lands with a stale tag and is DISCARDED
        (counted in ``stale_drops``), never delivered for the wrong step;
      * a producer exception is propagated to the consumer as
        ``DataProducerError`` on the next ``get`` (and every one after) —
        the alternative is a dead producer and an infinite tail of
        deadline waits silently substituting stale data;
      * ``start_step`` makes the producer fetch from the RESUME point, so
        a restarted run's ``get(start_step)`` is the same batch the
        uninterrupted run saw (the step-indexed dataset makes that exact).
    """

    def __init__(self, fetch_fn: Callable[[int], dict], deadline_s: float = 1.0,
                 prefetch: int = 2, start_step: int = 0):
        self.fetch_fn = fetch_fn
        self.deadline = deadline_s
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self.skips = 0
        self.served = 0
        self.stale_drops = 0
        self._last: Optional[dict] = None
        self._error: Optional[DataProducerError] = None
        self._held: Optional[tuple] = None  # (tag, batch) with tag > request
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer,
                                        args=(start_step,), daemon=True)
        self._thread.start()

    def _producer(self, step: int):
        while not self._stop.is_set():
            try:
                batch = self.fetch_fn(step)
            except BaseException as e:  # noqa: BLE001 - handed to consumer
                item = ("error", step, DataProducerError(step, e))
                while not self._stop.is_set():
                    try:
                        self.q.put(item, timeout=0.1)
                        return
                    except queue.Full:
                        continue
                return
            item = ("batch", step, batch)
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def _take(self, timeout: Optional[float]):
        """One queue pop; raises queue.Empty on timeout, DataProducerError
        on a producer failure (latched: every later get re-raises)."""
        kind, tag, payload = self.q.get(timeout=timeout)
        if kind == "error":
            self._error = payload
            raise payload
        return tag, payload

    def get(self, step: int) -> dict:
        self.served += 1
        if self._error is not None:
            raise self._error
        if self._held is not None and self._held[0] < step:
            self._held = None  # a re-requested range moved past it
        t0 = time.monotonic()
        while True:
            if self._held is not None:
                tag, batch = self._held
                self._held = None
            else:
                remaining = self.deadline - (time.monotonic() - t0)
                try:
                    tag, batch = self._take(timeout=max(remaining, 0.0))
                except queue.Empty:
                    break  # deadline: substitute
            if tag == step:
                self._last = batch
                return batch
            if tag < step:
                # late batch for a step already served (or skipped):
                # reconcile by discarding — delivering it here would feed
                # step N the data of step N-k
                self.stale_drops += 1
                continue
            self._held = (tag, batch)  # future tag: keep for later
            break
        self.skips += 1
        if self._last is None:
            # first batch: must block until the REQUESTED step arrives
            while True:
                tag, batch = self._take(timeout=None)
                if tag == step:
                    self._last = batch
                    return batch
                if tag < step:
                    self.stale_drops += 1
                else:
                    raise RuntimeError(
                        f"get({step}) requested a step before the producer "
                        f"stream (next tag {tag}); check start_step")
        return self._last

    def close(self):
        self._stop.set()
