"""Deterministic, step-indexed data pipelines with straggler tolerance.

Key property: ``batch_at(step)`` is a pure function of (seed, step,
shard_id) — resume after restart is exact (no sample skew between hosts),
and any host can reconstruct any other host's shard for recovery.

StragglerTolerantLoader wraps a (possibly slow) producer with a bounded
prefetch queue and a per-step deadline: when a fetch exceeds the deadline
the loader substitutes the previous batch and records a skip — the
step-time tail is bounded by the deadline instead of the slowest host
(the standard straggler-mitigation contract, simulated in-process here).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np


class SyntheticLMDataset:
    """Deterministic synthetic token stream with learnable structure.

    Tokens follow a noisy Markov chain (x_{t+1} = (a*x_t + b) % V with
    noise), so cross-entropy is reducible and convergence benchmarks are
    meaningful, unlike uniform random labels.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, shard_id: int = 0, num_shards: int = 1,
                 noise: float = 0.1):
        assert global_batch % num_shards == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.shard = shard_id
        self.noise = noise
        self.a = 31
        self.b = 17

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)
        x0 = rng.integers(0, self.vocab, size=(self.local_batch, 1))
        toks = [x0]
        for _ in range(self.seq):
            nxt = (toks[-1] * self.a + self.b) % self.vocab
            flip = rng.random((self.local_batch, 1)) < self.noise
            rand = rng.integers(0, self.vocab, size=(self.local_batch, 1))
            toks.append(np.where(flip, rand, nxt))
        seq = np.concatenate(toks, axis=1).astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


class SyntheticClassificationDataset:
    """Deterministic image-like classification set (the paper's MNIST/SVHN
    stand-in): class templates + Gaussian noise, fixed train/test split."""

    def __init__(self, input_dim: int = 784, num_classes: int = 10,
                 n_train: int = 4096, n_test: int = 1024, seed: int = 0,
                 noise: float = 0.35):
        rng = np.random.default_rng(seed)
        self.templates = rng.standard_normal((num_classes, input_dim)) \
            .astype(np.float32)
        self.num_classes = num_classes

        def make(n, salt):
            r = np.random.default_rng(seed + salt)
            y = r.integers(0, num_classes, size=n)
            x = self.templates[y] + noise * r.standard_normal(
                (n, input_dim)).astype(np.float32)
            return x.astype(np.float32), y.astype(np.int32)

        self.train = make(n_train, 1)
        self.test = make(n_test, 2)

    def train_batches(self, batch: int, steps: int, seed: int = 0
                      ) -> Iterator[tuple]:
        x, y = self.train
        rng = np.random.default_rng(seed)
        for _ in range(steps):
            idx = rng.integers(0, len(y), size=batch)
            yield x[idx], y[idx]


class StragglerTolerantLoader:
    """Bounded-queue prefetch with a per-step deadline.

    fetch_fn(step) -> batch runs in a background thread; ``get(step)``
    returns within ~deadline_s even if the producer stalls, substituting
    the last good batch and counting a skip.
    """

    def __init__(self, fetch_fn: Callable[[int], dict], deadline_s: float = 1.0,
                 prefetch: int = 2):
        self.fetch_fn = fetch_fn
        self.deadline = deadline_s
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self.skips = 0
        self.served = 0
        self._last: Optional[dict] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = 0
        while not self._stop.is_set():
            batch = self.fetch_fn(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self, step: int) -> dict:
        self.served += 1
        try:
            _, batch = self.q.get(timeout=self.deadline)
            self._last = batch
            return batch
        except queue.Empty:
            self.skips += 1
            if self._last is None:  # first batch: must block
                _, batch = self.q.get()
                self._last = batch
            return self._last

    def close(self):
        self._stop.set()
