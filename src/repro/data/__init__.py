from repro.data.pipeline import (
    SyntheticLMDataset,
    SyntheticClassificationDataset,
    StragglerTolerantLoader,
)

__all__ = ["SyntheticLMDataset", "SyntheticClassificationDataset",
           "StragglerTolerantLoader"]
