from repro.data.pipeline import (
    SyntheticLMDataset,
    SyntheticClassificationDataset,
    StragglerTolerantLoader,
    DataProducerError,
)

__all__ = ["SyntheticLMDataset", "SyntheticClassificationDataset",
           "StragglerTolerantLoader", "DataProducerError"]
