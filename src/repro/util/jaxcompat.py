"""Backfill the explicit-mesh JAX API onto older jax releases.

The codebase programs against the sharding-in-types era surface:

    jax.make_mesh(shape, names, axis_types=...)   # axis_types kwarg
    jax.set_mesh(mesh)                            # context manager
    jax.sharding.AxisType.{Auto,Explicit,Manual}
    jax.sharding.get_abstract_mesh()
    jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)

On jax>=0.6 these exist natively and this module is a no-op.  On the
pinned 0.4.x toolchain we map each onto its older equivalent:

  * ``set_mesh``   -> the classic ``with mesh:`` resource-env context
  * ``get_abstract_mesh`` -> the physical mesh of that resource env (it has
    the same ``.shape`` mapping and is accepted by ``shard_map``)
  * ``shard_map``  -> ``jax.experimental.shard_map.shard_map`` with
    ``check_vma`` translated to ``check_rep``
  * ``make_mesh``  -> drop the ``axis_types`` kwarg (0.4.x is all-Auto:
    every array is GSPMD-partitionable, which is exactly what Auto means)

Import this module before any mesh-using code runs.  It is imported by
``repro/__init__``-free namespace consumers via ``sitecustomize`` (any
process with ``src`` on PYTHONPATH) and by ``tests/conftest.py``.
Idempotent: safe to import any number of times.
"""
from __future__ import annotations

import enum
import functools

import jax
import jax.sharding


def _physical_mesh():
    """The mesh installed by ``with mesh:`` (empty mesh when outside)."""
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


def install() -> None:
    # --- AxisType ---------------------------------------------------------
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    # --- make_mesh(axis_types=...) ---------------------------------------
    # Probe the signature only: calling make_mesh would initialize the
    # backend before launchers get a chance to set XLA_FLAGS.
    import inspect
    try:
        native_axis_types = "axis_types" in inspect.signature(
            jax.make_mesh).parameters
    except (TypeError, ValueError):
        native_axis_types = True  # unknown signature; leave untouched

    if not native_axis_types and not getattr(jax.make_mesh, "_repro_compat",
                                             False):
        orig_make_mesh = jax.make_mesh

        @functools.wraps(orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types  # 0.4.x semantics are all-Auto already
            return orig_make_mesh(axis_shapes, axis_names, devices=devices)

        make_mesh._repro_compat = True
        jax.make_mesh = make_mesh

    # --- set_mesh ---------------------------------------------------------
    if not hasattr(jax, "set_mesh"):
        def set_mesh(mesh):
            """Context manager installing ``mesh`` as the ambient mesh.

            A ``Mesh`` is its own context manager in 0.4.x; entering it sets
            the resource env that ``get_abstract_mesh`` (below) reads.
            """
            return mesh

        jax.set_mesh = set_mesh

    # --- get_abstract_mesh ------------------------------------------------
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        def get_abstract_mesh():
            return _physical_mesh()

        jax.sharding.get_abstract_mesh = get_abstract_mesh

    # --- shard_map --------------------------------------------------------
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      check_vma=True, **kw):
            check_rep = kw.pop("check_rep", check_vma)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=bool(check_rep),
                              **kw)

        jax.shard_map = shard_map

    # --- pallas: pltpu.CompilerParams rename ------------------------------
    try:
        from jax.experimental.pallas import tpu as pltpu
        if not hasattr(pltpu, "CompilerParams") and hasattr(
                pltpu, "TPUCompilerParams"):
            pltpu.CompilerParams = pltpu.TPUCompilerParams
    except Exception:  # pragma: no cover - pallas not present on this build
        pass


install()
