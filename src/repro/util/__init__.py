from repro.util.scan import xscan, unrolled_scans_ctx

__all__ = ["xscan", "unrolled_scans_ctx"]
