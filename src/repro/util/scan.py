"""Scan wrapper with a context-controlled unroll mode.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, not x trip-count,
so rolled ``lax.scan`` silently under-reports FLOPs/bytes/collectives by the
scan length.  All layer/chunk scans in this codebase go through ``xscan``;
the dry-run's cost pass re-lowers reduced-depth configs under
``unrolled_scans_ctx()`` so every op is materialised and counted, then
extrapolates linearly in depth (see launch/dryrun.py).

Production lowering keeps scans rolled (small HLO, fast 512-device
compiles); only the cost pass unrolls.
"""
from __future__ import annotations

import contextlib
import contextvars

from jax import lax

_UNROLL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "unroll_scans", default=False)


@contextlib.contextmanager
def unrolled_scans_ctx(on: bool = True):
    token = _UNROLL.set(on)
    try:
        yield
    finally:
        _UNROLL.reset(token)


def xscan(f, init, xs, length=None, reverse=False):
    return lax.scan(f, init, xs, length=length, reverse=reverse,
                    unroll=True if _UNROLL.get() else 1)
