from repro.ckpt.checkpoint import (
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    latest_valid_step,
    list_steps,
    verify_checkpoint,
    AsyncCheckpointer,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "latest_valid_step", "list_steps", "verify_checkpoint",
           "AsyncCheckpointer"]
