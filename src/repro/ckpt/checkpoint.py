"""Fault-tolerant checkpointing: atomic, async, verified, elastic-reshardable.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.msgpack     # pytree structure, shapes, dtypes, crc32s, extra
        arr_000.npy ...      # one file per leaf (host-local full arrays)
    <dir>/LATEST             # atomic pointer file (renamed into place)

Guarantees (each one backed by a drill in tests/test_fault_injection.py /
tests/test_recovery_drills.py, not just this docstring):

  * atomicity — written to ``step_X.tmp-<pid>`` then os.rename'd after
    fsyncing every file AND the directory; a crash mid-write never corrupts
    LATEST, and a crash between the data rename and the pointer rename
    leaves LATEST on the previous (still valid) checkpoint.
  * integrity — the manifest records a crc32 per leaf; ``verify_checkpoint``
    checks file presence, sizes and checksums without deserialising, and
    ``restore_checkpoint`` verifies by default.
  * recovery — when LATEST (or the requested step) fails verification,
    restore WARNS LOUDLY and falls back to the newest checkpoint that
    passes, walking history until one does (``fallback=False`` to opt out;
    an explicitly requested ``step=`` never falls back silently).
  * transient-failure tolerance — the whole write attempt retries with
    capped exponential backoff (``retries`` x ``backoff_s``), so an
    injected/real EIO on a leaf write, an fsync hiccup or a rename failure
    costs a retry, not the checkpoint.
  * elasticity — arrays are stored mesh-agnostic (logical shapes); restore
    applies whatever shardings the *current* mesh prescribes via
    jax.device_put, so a job can restart on a different device count.
  * async — AsyncCheckpointer snapshots to host memory synchronously
    (cheap) and writes in a background thread, overlapping with training;
    ``close()`` (or the context manager, or the atexit hook) flushes the
    final in-flight write and re-raises any background error — a daemon
    thread alone would silently drop the last checkpoint at interpreter
    exit.
  * retention — keep_n oldest checkpoints are pruned after a successful
    write (never prunes the one being written).

Fault injection: ``save_checkpoint``/``AsyncCheckpointer`` accept
``fault=cb``; the callback (see ``repro.ft.FaultPlan.ckpt_fault``) is
invoked at each hook point — ``cb("io"|"fsync"|"rename", step)`` — and
simulates a failure by raising.  Production runs pass nothing.

On a real multi-host pod each host writes only addressable shards of its
process-local data (same manifest format, `shard_<proc>` suffix); the
single-process container exercises the full-array path.
"""
from __future__ import annotations

import atexit
import os
import pathlib
import shutil
import sys
import threading
import time
import warnings
import zlib
from typing import Any, Callable, List, Optional

import jax
import msgpack
import numpy as np

PyTree = Any
FaultCb = Optional[Callable[[str, int], None]]

MANIFEST_VERSION = 2  # v2 added per-leaf crc32 + nbytes


def _flatten_with_paths(tree):
    leaves = []
    for kp, leaf in jax.tree_util.tree_leaves_with_path(tree):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        leaves.append((path, leaf))
    return leaves


def _fsync_file(path: pathlib.Path):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: pathlib.Path):
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _warn(msg: str):
    """Loud on both channels: warnings for in-process callers/tests,
    stderr for subprocess drills grepping driver output."""
    warnings.warn(msg, RuntimeWarning, stacklevel=3)
    print(f"[ckpt] WARNING: {msg}", file=sys.stderr, flush=True)


def _write_attempt(directory: pathlib.Path, step: int, leaves, manifest_extra,
                   keep_n: int, fault: FaultCb) -> pathlib.Path:
    """One full write attempt: tmp dir -> leaves -> manifest -> fsync ->
    rename -> LATEST.  Raises on any failure; the caller retries."""
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    manifest = {"version": MANIFEST_VERSION, "step": step,
                "time": time.time(), "extra": manifest_extra, "leaves": []}
    for i, (path, arr) in enumerate(leaves):
        fname = f"arr_{i:05d}.npy"
        if fault is not None:
            fault("io", step)
        np.save(tmp / fname, arr, allow_pickle=False)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "nbytes": int(arr.nbytes),
             "crc32": zlib.crc32(arr.tobytes())})
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))

    # durability before visibility: the rename must not land before the
    # bytes it points at
    if fault is not None:
        fault("fsync", step)
    for p in tmp.iterdir():
        _fsync_file(p)
    _fsync_dir(tmp)

    if final.exists():
        shutil.rmtree(final)
    if fault is not None:
        fault("rename", step)
    os.rename(tmp, final)
    _fsync_dir(directory)

    # atomic LATEST pointer
    ptr_tmp = directory / f"LATEST.tmp-{os.getpid()}"
    ptr_tmp.write_text(final.name)
    _fsync_file(ptr_tmp)
    os.rename(ptr_tmp, directory / "LATEST")
    _fsync_dir(directory)

    _prune(directory, keep_n)
    return final


def save_checkpoint(directory, step: int, tree: PyTree, *,
                    extra: Optional[dict] = None, keep_n: int = 3,
                    fault: FaultCb = None, retries: int = 3,
                    backoff_s: float = 0.05,
                    max_backoff_s: float = 2.0) -> pathlib.Path:
    """Synchronous atomic verified save. Returns the final checkpoint path.

    Transient IO failures (leaf write, fsync, rename) are retried up to
    ``retries`` extra attempts with capped exponential backoff; the final
    failure propagates."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    # materialise leaves ONCE so retries rewrite identical bytes
    leaves = [(p, np.asarray(leaf)) for p, leaf in _flatten_with_paths(tree)]

    attempt = 0
    while True:
        try:
            return _write_attempt(directory, step, leaves, extra or {},
                                  keep_n, fault)
        except OSError as e:
            attempt += 1
            if attempt > retries:
                raise
            delay = min(backoff_s * (2 ** (attempt - 1)), max_backoff_s)
            _warn(f"save step {step} attempt {attempt}/{retries} failed "
                  f"({e}); retrying in {delay:.2f}s")
            time.sleep(delay)


def _prune(directory: pathlib.Path, keep_n: int):
    ckpts = sorted(p for p in directory.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and ".tmp" not in p.name)
    for old in ckpts[:-keep_n]:
        shutil.rmtree(old, ignore_errors=True)


def list_steps(directory) -> List[int]:
    """All on-disk checkpoint steps, ascending (no validity check)."""
    directory = pathlib.Path(directory)
    if not directory.is_dir():
        return []
    out = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and ".tmp" not in p.name:
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
    return sorted(out)


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    ptr = directory / "LATEST"
    if not ptr.exists():
        # a crash between the data rename and the pointer rename leaves a
        # complete checkpoint with no pointer; surface it rather than
        # claiming the directory is empty
        steps = list_steps(directory)
        return steps[-1] if steps else None
    name = ptr.read_text().strip()
    if not (directory / name / "manifest.msgpack").exists():
        steps = list_steps(directory)
        return steps[-1] if steps else None
    return int(name.split("_")[1])


def verify_checkpoint(directory, step: int) -> List[str]:
    """Integrity check WITHOUT deserialising: manifest readable, every leaf
    file present with the manifest's byte size and crc32.  Returns the list
    of problems (empty == valid)."""
    cdir = pathlib.Path(directory) / f"step_{step:08d}"
    problems: List[str] = []
    mpath = cdir / "manifest.msgpack"
    if not mpath.exists():
        return [f"{cdir.name}: missing manifest"]
    try:
        manifest = msgpack.unpackb(mpath.read_bytes())
    except Exception as e:  # noqa: BLE001 - any unpack failure = corrupt
        return [f"{cdir.name}: unreadable manifest ({e})"]
    for entry in manifest.get("leaves", []):
        fpath = cdir / entry["file"]
        if not fpath.exists():
            problems.append(f"{cdir.name}/{entry['file']}: missing")
            continue
        if "crc32" not in entry:
            continue  # v1 manifest: presence is all we can check
        try:
            arr = np.load(fpath, allow_pickle=False)
        except Exception as e:  # noqa: BLE001
            problems.append(f"{cdir.name}/{entry['file']}: unreadable ({e})")
            continue
        if int(arr.nbytes) != int(entry.get("nbytes", arr.nbytes)):
            problems.append(
                f"{cdir.name}/{entry['file']}: size {arr.nbytes} != "
                f"manifest {entry['nbytes']}")
        elif zlib.crc32(arr.tobytes()) != entry["crc32"]:
            problems.append(
                f"{cdir.name}/{entry['file']}: crc32 mismatch "
                f"(bit rot or torn write)")
    return problems


def latest_valid_step(directory) -> Optional[int]:
    """Newest step that passes ``verify_checkpoint`` (LATEST-first order)."""
    for step in _candidate_steps(directory):
        if not verify_checkpoint(directory, step):
            return step
    return None


def _candidate_steps(directory) -> List[int]:
    """Restore order: the LATEST pointer's step first, then every other
    on-disk step, newest first."""
    steps = sorted(list_steps(directory), reverse=True)
    head = latest_step(directory)
    if head in steps:
        steps.remove(head)
        steps.insert(0, head)
    return steps


def restore_checkpoint(directory, template: PyTree, *, step: Optional[int] = None,
                       shardings: Optional[PyTree] = None, verify: bool = True,
                       fallback: bool = True):
    """Restore into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding, same structure) reshard
    the arrays onto the CURRENT mesh — this is the elastic-restart path: the
    checkpoint stores logical arrays; placement is decided at restore time.

    With ``verify`` (default) each candidate's checksums are checked before
    deserialising; with ``fallback`` (default, only when ``step`` is not
    pinned) a failing candidate is skipped WITH A LOUD WARNING and the next
    newest is tried — so a bit-flipped or torn LATEST costs one checkpoint
    interval, not the run.  A pinned ``step=`` that fails verification
    raises instead (the caller asked for that exact state).

    Returns (tree, step, extra).
    """
    directory = pathlib.Path(directory)
    if step is not None:
        candidates = [step]
        allow_fallback = False
    else:
        candidates = _candidate_steps(directory)
        allow_fallback = fallback
        if not candidates:
            raise FileNotFoundError(f"no checkpoint in {directory}")

    errors: List[str] = []
    for i, cand in enumerate(candidates):
        if verify:
            problems = verify_checkpoint(directory, cand)
            if problems:
                msg = (f"checkpoint step {cand} failed verification: "
                       + "; ".join(problems))
                if not allow_fallback:
                    raise ValueError(msg)
                _warn(msg + " — falling back to the previous checkpoint")
                errors.append(msg)
                continue
        try:
            tree, got_step, extra = _load_checkpoint(
                directory, cand, template, shardings)
        except (OSError, KeyError) as e:
            # ValueError (template shape mismatch) propagates: that is a
            # CALLER bug every candidate shares, not checkpoint damage
            if not allow_fallback:
                raise
            msg = f"checkpoint step {cand} failed to load: {e}"
            _warn(msg + " — falling back to the previous checkpoint")
            errors.append(msg)
            continue
        if i > 0:
            _warn(f"recovered from checkpoint step {cand} after "
                  f"{i} newer candidate(s) failed")
        return tree, got_step, extra
    raise FileNotFoundError(
        f"no valid checkpoint in {directory}; tried {candidates}: "
        + " | ".join(errors))


def _load_checkpoint(directory: pathlib.Path, step: int, template: PyTree,
                     shardings: Optional[PyTree]):
    cdir = directory / f"step_{step:08d}"
    manifest = msgpack.unpackb((cdir / "manifest.msgpack").read_bytes())

    by_path = {e["path"]: e for e in manifest["leaves"]}
    tmpl_leaves = _flatten_with_paths(template)
    shard_leaves = (_flatten_with_paths(shardings) if shardings is not None
                    else [(p, None) for p, _ in tmpl_leaves])
    shard_map = dict(shard_leaves)

    out = []
    for path, tmpl in tmpl_leaves:
        entry = by_path.get(path)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = np.load(cdir / entry["file"], allow_pickle=False)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"{path}: checkpoint shape {arr.shape} != template {tmpl.shape}")
        sh = shard_map.get(path)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
    return tree, manifest["step"], manifest.get("extra", {})


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread.

    ``save`` blocks only for the device->host copy; the previous write is
    joined first (at most one outstanding write, bounding host memory).

    Lifecycle: the writer thread is a daemon, so WITHOUT an explicit join
    the interpreter would exit mid-write and silently drop the final
    checkpoint.  ``close()`` joins the in-flight write and re-raises any
    background error; it runs automatically via the context-manager exit
    and an ``atexit`` hook (atexit fires before daemon threads are killed),
    so even a driver that forgets ``wait()`` keeps its last checkpoint —
    only a hard kill (os._exit / SIGKILL) skips it, which is exactly the
    crash the on-disk atomicity story covers."""

    def __init__(self, directory, keep_n: int = 3, fault: FaultCb = None):
        self.directory = pathlib.Path(directory)
        self.keep_n = keep_n
        self.fault = fault
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self.last_error: Optional[Exception] = None
        self._atexit = atexit.register(self._atexit_close)

    def save(self, step: int, tree: PyTree, extra: Optional[dict] = None):
        if self._closed:
            raise RuntimeError("AsyncCheckpointer is closed")
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                extra=extra, keep_n=self.keep_n,
                                fault=self.fault)
            except Exception as e:  # noqa: BLE001 - surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def close(self):
        """Flush the in-flight write and surface its error; idempotent."""
        if self._closed:
            return
        self._closed = True
        atexit.unregister(self._atexit_close)
        self.wait()

    def _atexit_close(self):
        try:
            self.close()
        except Exception as e:  # noqa: BLE001 - atexit must not re-raise
            print(f"[ckpt] WARNING: final checkpoint write failed at "
                  f"interpreter exit: {e}", file=sys.stderr, flush=True)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        # don't mask an in-flight training exception with a flush error
        if exc_type is not None:
            try:
                self.close()
            except Exception as e:  # noqa: BLE001
                print(f"[ckpt] WARNING: checkpoint flush failed during "
                      f"exception unwind: {e}", file=sys.stderr, flush=True)
            return False
        self.close()
        return False
