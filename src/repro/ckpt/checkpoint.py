"""Fault-tolerant checkpointing: atomic, async, elastic-reshardable.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.msgpack     # pytree structure, shapes, dtypes, metadata
        arr_000.npy ...      # one file per leaf (host-local full arrays)
    <dir>/LATEST             # atomic pointer file (renamed into place)

Guarantees:
  * atomicity — written to ``step_X.tmp-<pid>`` then os.rename'd; a crash
    mid-write never corrupts LATEST.
  * elasticity — arrays are stored mesh-agnostic (logical shapes); restore
    applies whatever shardings the *current* mesh prescribes via
    jax.device_put, so a job can restart on a different device count.
  * async — AsyncCheckpointer snapshots to host memory synchronously
    (cheap) and writes in a background thread, overlapping with training.
  * retention — keep_n oldest checkpoints are pruned after a successful
    write (never prunes the one being written).

On a real multi-host pod each host writes only addressable shards of its
process-local data (same manifest format, `shard_<proc>` suffix); the
single-process container exercises the full-array path.
"""
from __future__ import annotations

import os
import pathlib
import shutil
import threading
import time
from typing import Any, Optional

import jax
import msgpack
import numpy as np

PyTree = Any


def _flatten_with_paths(tree):
    leaves = []
    for kp, leaf in jax.tree_util.tree_leaves_with_path(tree):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        leaves.append((path, leaf))
    return leaves


def save_checkpoint(directory, step: int, tree: PyTree, *,
                    extra: Optional[dict] = None, keep_n: int = 3) -> pathlib.Path:
    """Synchronous atomic save. Returns the final checkpoint path."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "time": time.time(), "extra": extra or {},
                "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr, allow_pickle=False)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))

    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    # atomic LATEST pointer
    ptr_tmp = directory / f"LATEST.tmp-{os.getpid()}"
    ptr_tmp.write_text(final.name)
    os.rename(ptr_tmp, directory / "LATEST")

    _prune(directory, keep_n)
    return final


def _prune(directory: pathlib.Path, keep_n: int):
    ckpts = sorted(p for p in directory.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and ".tmp" not in p.name)
    for old in ckpts[:-keep_n]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    ptr = directory / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (directory / name / "manifest.msgpack").exists():
        return None
    return int(name.split("_")[1])


def restore_checkpoint(directory, template: PyTree, *, step: Optional[int] = None,
                       shardings: Optional[PyTree] = None):
    """Restore into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding, same structure) reshard
    the arrays onto the CURRENT mesh — this is the elastic-restart path: the
    checkpoint stores logical arrays; placement is decided at restore time.

    Returns (tree, step, extra).
    """
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    cdir = directory / f"step_{step:08d}"
    manifest = msgpack.unpackb((cdir / "manifest.msgpack").read_bytes())

    by_path = {e["path"]: e for e in manifest["leaves"]}
    tmpl_leaves = _flatten_with_paths(template)
    shard_leaves = (_flatten_with_paths(shardings) if shardings is not None
                    else [(p, None) for p, _ in tmpl_leaves])
    shard_map = dict(shard_leaves)

    out = []
    for path, tmpl in tmpl_leaves:
        entry = by_path.get(path)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = np.load(cdir / entry["file"], allow_pickle=False)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"{path}: checkpoint shape {arr.shape} != template {tmpl.shape}")
        sh = shard_map.get(path)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
    return tree, manifest["step"], manifest.get("extra", {})


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread.

    ``save`` blocks only for the device->host copy; the previous write is
    joined first (at most one outstanding write, bounding host memory)."""

    def __init__(self, directory, keep_n: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def save(self, step: int, tree: PyTree, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                extra=extra, keep_n=self.keep_n)
            except Exception as e:  # noqa: BLE001 - surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
