"""Deterministic fault injection for recovery drills.

TaxoNN's target environment — retraining on embedded devices in the field —
treats power loss, preemption and flaky storage as the NORMAL case, so the
training loop's recovery story has to be provable, not aspirational.  This
module supplies the reproducible half of that proof: a seeded ``FaultPlan``
describing exactly which faults fire at exactly which steps, so a CI drill
that kills a run mid-step and restarts it replays the same failure every
time.

A plan is parsed from a compact spec string (the ``--fault-plan`` train
flag, or the ``REPRO_FAULT_PLAN`` env knob so subprocess drills need no
argv plumbing).  Events are ``;``-separated:

    crash@12            hard-kill the process when the loop reaches step 12
                        (os._exit — no atexit flush, no daemon join: the
                        closest a test can get to SIGKILL semantics)
    crash@rand:8-20     seeded-random kill step in [8, 20) — drawn from the
                        plan seed, so the drill is random ACROSS seeds but
                        reproducible for one
    io@8x2              the checkpoint save at data step 8 fails its first
                        2 leaf-write attempts with OSError (transient —
                        the save-retry loop must absorb it)
    fsync@8x2           same, but the failure fires at fsync time
    rename@8            the tmp->final rename fails once at step 8
    flip@10             after the step-10 checkpoint lands, flip one bit of
                        one array file in it (which file/bit is drawn from
                        the plan seed) — the restore path must detect the
                        checksum mismatch and fall back
    stall@5:0.6         the data fetch for step 5 stalls 0.6 s (straggler;
                        the loader's deadline must bound it)
    seed=7              plan seed (default 0)

The plan object is pure policy; mechanism lives at three hook points:

  * ``ckpt_fault(event, step)`` is passed to ``ckpt.save_checkpoint`` /
    ``AsyncCheckpointer`` as their ``fault=`` callable and raises OSError
    when an io/fsync/rename event fires,
  * ``wrap_fetch(fetch_fn)`` wraps the data pipeline's fetch with the
    stall events,
  * ``check_crash(step)`` / ``corrupt_checkpoint(dir, step)`` are called
    by the train loop directly.

Everything is keyed by the DATA step (the step index the training loop
sees), never wall-clock, so a drill is bitwise-reproducible.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import re
import sys
from typing import Callable, Dict, List, Optional

import numpy as np

# distinct from any python/pytest/XLA failure code so drills can assert the
# crash they injected is the crash that happened
FAULT_EXIT_CODE = 41

ENV_KNOB = "REPRO_FAULT_PLAN"

_EVENT_RE = re.compile(
    r"^(?P<kind>crash|io|fsync|rename|flip|stall)@"
    r"(?P<at>rand:\d+-\d+|\d+)"
    r"(?:x(?P<count>\d+))?"
    r"(?::(?P<seconds>\d+(?:\.\d+)?))?$")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str            # crash | io | fsync | rename | flip | stall
    step: int            # resolved data step the event fires at
    count: int = 1       # consecutive failures for io/fsync/rename
    seconds: float = 0.0  # stall duration


class FaultPlan:
    """A resolved, seeded schedule of injected faults.

    Stateful only in the transient-failure counters (an ``io@8x2`` event
    must fail exactly twice and then let the retry succeed), which is why
    one plan instance must be shared by every hook point of one run.
    """

    def __init__(self, events: List[FaultEvent], seed: int = 0,
                 spec: str = ""):
        self.events = list(events)
        self.seed = int(seed)
        self.spec = spec
        # (kind, step) -> remaining failures; mutated as faults fire
        self._budget: Dict[tuple, int] = {
            (e.kind, e.step): e.count for e in self.events
            if e.kind in ("io", "fsync", "rename")}
        self.fired: List[tuple] = []   # (kind, step) log for tests/logs

    # -- construction -------------------------------------------------------

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        spec = (spec or "").strip()
        if not spec:
            return cls([], 0, spec)
        seed = 0
        raw = []
        for token in filter(None, (t.strip() for t in spec.split(";"))):
            if token.startswith("seed="):
                seed = int(token[5:])
                continue
            m = _EVENT_RE.match(token)
            if not m:
                raise ValueError(
                    f"bad fault-plan token {token!r} (grammar: kind@step, "
                    f"crash@rand:lo-hi, io@step xN, stall@step:seconds, "
                    f"seed=N)")
            raw.append(m)
        rng = np.random.default_rng(seed)
        events = []
        for m in raw:
            at = m.group("at")
            if at.startswith("rand:"):
                lo, hi = (int(x) for x in at[5:].split("-"))
                if hi <= lo:
                    raise ValueError(f"empty rand range in {m.group(0)!r}")
                step = int(rng.integers(lo, hi))
            else:
                step = int(at)
            events.append(FaultEvent(
                kind=m.group("kind"), step=step,
                count=int(m.group("count") or 1),
                seconds=float(m.group("seconds") or 0.0)))
        return cls(events, seed, spec)

    @classmethod
    def from_env(cls, flag_value: Optional[str] = None) -> Optional["FaultPlan"]:
        """Flag wins over env; empty/absent spec -> no plan (None)."""
        spec = flag_value if flag_value else os.environ.get(ENV_KNOB, "")
        plan = cls.parse(spec)
        return plan if plan.events else None

    # -- introspection ------------------------------------------------------

    def describe(self) -> str:
        if not self.events:
            return "no faults"
        parts = []
        for e in sorted(self.events, key=lambda e: (e.step, e.kind)):
            p = f"{e.kind}@{e.step}"
            if e.count > 1:
                p += f"x{e.count}"
            if e.seconds:
                p += f":{e.seconds:g}s"
            parts.append(p)
        return f"seed={self.seed} " + " ".join(parts)

    def crash_step(self) -> Optional[int]:
        steps = [e.step for e in self.events if e.kind == "crash"]
        return min(steps) if steps else None

    def _events_of(self, kind: str, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.kind == kind and e.step == step]

    # -- hook points --------------------------------------------------------

    def check_crash(self, step: int) -> None:
        """Hard-kill the process if a crash event fires at ``step``.

        ``os._exit`` skips atexit handlers, finally blocks and daemon-thread
        joins — the point of the drill is proving recovery from a kill that
        flushed NOTHING."""
        if any(e.step == step for e in self.events if e.kind == "crash"):
            print(f"[fault] injected crash at step {step} "
                  f"(exit {FAULT_EXIT_CODE})", file=sys.stderr, flush=True)
            sys.stderr.flush()
            sys.stdout.flush()
            os._exit(FAULT_EXIT_CODE)

    def ckpt_fault(self, event: str, step: int) -> None:
        """Checkpoint-layer hook: raise OSError while the (kind, step)
        failure budget lasts.  ``event`` is "io" | "fsync" | "rename"."""
        remaining = self._budget.get((event, step), 0)
        if remaining > 0:
            self._budget[(event, step)] = remaining - 1
            self.fired.append((event, step))
            raise OSError(
                f"injected {event} failure at step {step} "
                f"({remaining - 1} more to come)")

    def wrap_fetch(self, fetch_fn: Callable[[int], dict]
                   ) -> Callable[[int], dict]:
        """Wrap a data-pipeline fetch with the plan's stall events."""
        stalls = {e.step: e.seconds for e in self.events if e.kind == "stall"}
        if not stalls:
            return fetch_fn

        def fetch(step: int) -> dict:
            secs = stalls.get(step, 0.0)
            if secs:
                self.fired.append(("stall", step))
                import time
                time.sleep(secs)
            return fetch_fn(step)
        return fetch

    def flip_steps(self) -> List[int]:
        return sorted(e.step for e in self.events if e.kind == "flip")

    def corrupt_checkpoint(self, directory, step: int) -> Optional[str]:
        """Flip one bit of one array file in ``<dir>/step_<step>`` (drawn
        from the plan seed).  Returns the corrupted file name, or None if
        the checkpoint does not exist.  The manifest keeps the ORIGINAL
        checksum, so the restore path must detect the mismatch."""
        if not self._events_of("flip", step):
            return None
        return flip_one_bit(directory, step,
                            seed=(self.seed * 1_000_003 + step))


def flip_one_bit(directory, step: int, *, seed: int = 0) -> Optional[str]:
    """Seeded single-bit corruption of one ``arr_*.npy`` in a checkpoint —
    shared by FaultPlan and the drill tests (which corrupt directly)."""
    cdir = pathlib.Path(directory) / f"step_{step:08d}"
    if not cdir.is_dir():
        return None
    arrs = sorted(p for p in cdir.iterdir() if p.name.startswith("arr_"))
    if not arrs:
        return None
    rng = np.random.default_rng(seed)
    target = arrs[int(rng.integers(len(arrs)))]
    data = bytearray(target.read_bytes())
    # skip the .npy header so the flip corrupts PAYLOAD bytes (a header
    # flip would fail np.load outright, which is the easy case)
    off = 128 if len(data) > 136 else max(0, len(data) - 1)
    pos = int(rng.integers(off, len(data)))
    data[pos] ^= 1 << int(rng.integers(8))
    target.write_bytes(bytes(data))
    print(f"[fault] flipped bit {pos} of {target.name} in {cdir.name}",
          file=sys.stderr, flush=True)
    return target.name
