from repro.ft.faults import (
    ENV_KNOB,
    FAULT_EXIT_CODE,
    FaultEvent,
    FaultPlan,
    flip_one_bit,
)

__all__ = ["ENV_KNOB", "FAULT_EXIT_CODE", "FaultEvent", "FaultPlan",
           "flip_one_bit"]
