import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the real step function (TaxoNN train step /
prefill / decode) against ShapeDtypeStruct inputs under the production mesh,
prints memory_analysis() (proves it fits) and cost_analysis() (FLOPs/bytes
for the roofline), parses the optimized HLO for collective bytes, and writes
one JSON record per cell to --out (incremental: existing records are skipped
unless --force).

Usage:
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k --mesh single
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCH_NAMES, get_config, input_specs, param_specs, SHAPE_CELLS,
    SHAPES_BY_NAME, cell_is_applicable,
)
from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.steps import default_bits, init_train_state
from repro.dist.api import (activation_sharding_ctx, make_default_rules,
                            perf_options_ctx)
from repro.dist.hlo_analysis import analyze_compiled
from repro.dist.sharding import (
    batch_pspecs, decode_state_pspecs, opt_pspecs, param_pspecs, to_named,
    replicated,
)
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models.config import ModelConfig
from repro.optim import Hyper, OptimizerConfig
from repro.serving import decode_step, prefill
from repro.util.scan import unrolled_scans_ctx


def model_flops_global(cfg: ModelConfig, cell) -> float:
    """MODEL_FLOPS: 6*N*D train, 2*N*D forward-only; MoE uses active params."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    # decode: one new token per sequence
    return 2.0 * n * cell.global_batch


def build_cell(cfg: ModelConfig, cell, mesh, pipe=None):
    """Returns (fn, arg_specs, in_shardings) for the cell's step kind.

    ``pipe`` = (schedule_name, stages, microbatches) builds the TRAIN step
    with the stage-sharded pipeline execution path (dist.pipeline); the
    cost pass stays pipeline-free (reduced depths need not divide).
    """
    specs = input_specs(cfg, cell.name)
    p_specs = param_specs(cfg)
    p_sh = to_named(param_pspecs(cfg, p_specs, mesh), mesh)

    if cell.kind == "train":
        ocfg = OptimizerConfig(kind="sgd")
        policy = QuantPolicy(grad_scale=128.0)  # paper-faithful: quant ON
        opts = StepOptions(engine="taxonn")
        if pipe is not None:
            opts = opts.replace(pipeline_schedule=pipe[0],
                                pipeline_stages=pipe[1],
                                num_microbatches=pipe[2])
        step = make_train_step(cfg, policy, ocfg, opts)
        opt_specs = jax.eval_shape(lambda p: init_train_state(p, ocfg), p_specs)
        opt_sh = to_named(opt_pspecs(
            cfg, opt_specs, param_pspecs(cfg, p_specs, mesh), mesh), mesh)
        bits = default_bits(cfg, enabled=True)
        bits_specs = jax.eval_shape(lambda: bits)
        hyper_specs = jax.eval_shape(
            lambda: Hyper(lr=jnp.float32(1e-3), step=jnp.int32(0)))
        batch_sh = to_named(batch_pspecs(specs, mesh), mesh)

        def fn(params, opt_state, batch, hyper, bits_):
            return step(params, opt_state, batch, hyper, bits_)

        args = (p_specs, opt_specs, specs, hyper_specs, bits_specs)
        shardings = (p_sh, opt_sh, batch_sh,
                     replicated(hyper_specs, mesh),
                     replicated(bits_specs, mesh))
        return fn, args, shardings, (0, 1)  # donate params + opt state

    if cell.kind == "prefill":
        def fn(params, batch):
            return prefill(params, cfg, batch, max_len=cell.seq_len)
        batch_sh = to_named(batch_pspecs(specs, mesh), mesh)
        return fn, (p_specs, specs), (p_sh, batch_sh), ()

    # decode
    state_specs = specs["state"]
    tok_specs = specs["tokens"]

    def fn(params, state, tokens):
        return decode_step(params, cfg, state, tokens)

    state_sh = to_named(decode_state_pspecs(cfg, state_specs, mesh), mesh)
    tok_sh = to_named(batch_pspecs(tok_specs, mesh), mesh)
    return (fn, (p_specs, state_specs, tok_specs), (p_sh, state_sh, tok_sh),
            (1,))  # donate the decode state (cache update in place)


def cost_units(cfg: ModelConfig) -> int:
    """Depth units the cost pass extrapolates over (hybrid scans groups)."""
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    return cfg.num_layers


def reduced_depth(cfg: ModelConfig, k: int) -> ModelConfig:
    changes = {"num_layers": k}
    if cfg.family == "hybrid":
        changes["num_layers"] = k * cfg.attn_every
    if cfg.family == "encdec":
        changes["num_encoder_layers"] = k
    return dataclasses.replace(cfg, **changes)


def cost_pass(cfg: ModelConfig, cell, mesh, rules) -> dict:
    """Exact per-step cost via reduced-depth UNROLLED compiles + linear
    extrapolation in depth.

    XLA's cost_analysis counts while-loop bodies once, so the production
    (scanned) artifact under-reports FLOPs/bytes/collectives by the scan
    length.  We re-lower the same cell at depth k=2 and k=4 with every scan
    unrolled (see util/scan.py), giving exact counts m(k), then use that
    m(k) is affine in depth: m(L) = m(2) + (m(4)-m(2))/2 * (L-2).
    """
    units_full = cost_units(cfg)
    recs = {}
    for k in (2, 4):
        rcfg = reduced_depth(cfg, k)
        with jax.set_mesh(mesh), activation_sharding_ctx(rules), \
                unrolled_scans_ctx():
            fn, args, shardings, donate = build_cell(rcfg, cell, mesh)
            compiled = jax.jit(fn, in_shardings=shardings,
                               donate_argnums=donate).lower(*args).compile()
        recs[k] = analyze_compiled(compiled, mesh.size)
        del compiled

    def extrap(get) -> float:
        m2, m4 = get(recs[2]), get(recs[4])
        return float(m2 + (m4 - m2) / 2.0 * (units_full - 2))

    flops = extrap(lambda r: r["flops_per_device"])
    hbm = extrap(lambda r: r["hbm_bytes_per_device"])
    moved = extrap(lambda r: r["collectives"]["moved_bytes_per_device"])
    counts = {}
    for kind in set(recs[2]["collectives"]["counts"]) | set(
            recs[4]["collectives"]["counts"]):
        counts[kind] = round(extrap(
            lambda r, kk=kind: r["collectives"]["counts"].get(kk, 0)))
    from repro.dist.hlo_analysis import roofline_terms
    return {
        "method": "unrolled depth-2/4 extrapolation",
        "units_full": units_full,
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm,
        "collective_moved_bytes_per_device": moved,
        "collective_counts": counts,
        "terms": roofline_terms(flops, hbm, moved),
        "probe_points": {str(k): {
            "flops": recs[k]["flops_per_device"],
            "hbm": recs[k]["hbm_bytes_per_device"],
            "moved": recs[k]["collectives"]["moved_bytes_per_device"],
        } for k in (2, 4)},
    }


def run_cell(arch: str, cell_name: str, multi_pod: bool, out_dir: pathlib.Path,
             force: bool = False, verbose: bool = True,
             opts: tuple = (), pipe=None) -> dict:
    mesh_tag = "multipod_2x16x16" if multi_pod else "pod_16x16"
    opt_tag = ("__" + "-".join(sorted(opts))) if opts else ""
    rec_path = out_dir / f"{arch}__{cell_name}__{mesh_tag}{opt_tag}.json"
    if rec_path.exists() and not force:
        return json.loads(rec_path.read_text())

    cfg = get_config(arch)
    if "pad_heads" in opts and cfg.num_heads:
        m = 16  # model-axis size of the production mesh
        if cfg.num_heads % m:
            hkv, g = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
            gp = g
            while (hkv * gp) % m:
                gp += 1
            if gp / g <= 1.5:  # padding-overhead cap
                cfg = dataclasses.replace(cfg, padded_heads=hkv * gp)
    cell = SHAPES_BY_NAME[cell_name]
    record = {"arch": arch, "cell": cell_name, "mesh": mesh_tag,
              "kind": cell.kind, "family": cfg.family,
              "opts": sorted(opts),
              "padded_heads": cfg.padded_heads}

    if not cell_is_applicable(cfg, cell):
        record["status"] = "skipped"
        record["reason"] = ("long-context decode requires sub-quadratic "
                            "attention; full-attention arch (DESIGN.md §5)")
        rec_path.write_text(json.dumps(record, indent=2))
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rules = make_default_rules(batch_axes(mesh),
                               seq_parallel="seq_parallel" in opts)
    t0 = time.time()
    try:
        with perf_options_ctx(opts), jax.set_mesh(mesh), \
                activation_sharding_ctx(rules):
            fn, args, shardings, donate = build_cell(cfg, cell, mesh,
                                                     pipe=pipe)
            lowered = jax.jit(fn, in_shardings=shardings,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        analysis = analyze_compiled(compiled, n_dev)
        mf_global = model_flops_global(cfg, cell)
        mf_dev = mf_global / n_dev
        record.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "model_flops_global": mf_global,
            "model_flops_per_device": mf_dev,
            "overlap_fraction": analysis["overlap"]["overlap_fraction"],
            "scanned_artifact": analysis,   # memory truth; costs count scan bodies once
        })
        if pipe is not None and cell.kind == "train":
            from repro.dist.pipeline import get_schedule
            sched_obj = get_schedule(pipe[0])
            record["pipe_bubble"] = sched_obj.bubble_fraction(
                pipe[1], pipe[2])
            record["pipe"] = {"schedule": pipe[0], "stages": pipe[1],
                              "microbatches": pipe[2],
                              **sched_obj.summary(pipe[1], pipe[2])}
        # --- exact cost pass (unrolled reduced-depth extrapolation) -------
        t1 = time.time()
        with perf_options_ctx(opts):
            cost = cost_pass(cfg, cell, mesh, rules)
        record["cost_pass_s"] = round(time.time() - t1, 1)
        hlo_flops = cost["flops_per_device"]
        record["cost"] = cost
        record["useful_flops_ratio"] = (
            mf_dev / hlo_flops if hlo_flops else None)
        if verbose:
            ma = analysis.get("memory_analysis", {})
            t = cost["terms"]
            print(f"[{arch} x {cell_name} x {mesh_tag}] OK "
                  f"compile={t_compile:.0f}s cost={record['cost_pass_s']:.0f}s "
                  f"flops/dev={hlo_flops:.3e} "
                  f"useful={record['useful_flops_ratio'] and round(record['useful_flops_ratio'],2)} "
                  f"temp={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"dom={t['dominant']} "
                  f"[c={t['compute_s']*1e3:.1f} m={t['memory_s']*1e3:.1f} "
                  f"x={t['collective_s']*1e3:.1f}]ms", flush=True)
    except Exception as e:  # noqa: BLE001 - record the failure, keep sweeping
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[{arch} x {cell_name} x {mesh_tag}] FAIL {type(e).__name__}: "
                  f"{str(e)[:200]}", flush=True)
    rec_path.write_text(json.dumps(record, indent=2))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=[c.name for c in SHAPE_CELLS])
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--opts", default="",
                    help="comma-separated perf options (seq_parallel, "
                         "pad_heads, moe_rowcombine) — see §Perf")
    ap.add_argument("--pipeline-schedule", default="none",
                    choices=["none", "gpipe", "1f1b", "interleaved"],
                    help="build TRAIN cells with stage-sharded pipeline "
                         "execution (every model family — hybrid/encdec/"
                         "moe shared operands included; records "
                         "pipe_bubble + the schedule summary; layer count "
                         "must divide into --pipe-stages)")
    ap.add_argument("--pipe-stages", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=8)
    args = ap.parse_args()
    opts = tuple(o for o in args.opts.split(",") if o)
    pipe = (None if args.pipeline_schedule == "none" else
            (args.pipeline_schedule, args.pipe_stages, args.microbatches))

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = ARCH_NAMES if (args.all or not args.arch) else (args.arch,)
    cells = ([c.name for c in SHAPE_CELLS]
             if (args.all or not args.shape) else (args.shape,))
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for cell in cells:
            for multi in meshes:
                rec = run_cell(arch, cell, multi, out_dir, force=args.force,
                               opts=opts, pipe=pipe)
                s = rec["status"]
                n_ok += s == "ok"
                n_skip += s == "skipped"
                n_fail += s == "error"
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped (by design), "
          f"{n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
