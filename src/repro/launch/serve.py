"""Serving driver: continuous-batching decode over the slot scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 8 --max-new 16 [--mode paged|contiguous]

Demonstrates the production serving path behind the PR-8 API: a
``ServeConfig`` + ``EngineHooks.for_model`` pair drives either the paged
block-pool scheduler (chunked prefill, prefix sharing, COW) or the legacy
contiguous per-slot cache, with per-request latency accounting.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.launch.train import _reduce
from repro.models import lm
from repro.serving import (BatchScheduler, EngineHooks, Request, ServeConfig,
                           paged_supported)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "paged", "contiguous"],
                    help="auto: paged for the GQA-KV families, contiguous "
                         "otherwise (MLA/SWA/SSM/hybrid/encdec)")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prefill token budget per tick (paged mode; "
                         "default: block size)")
    ap.add_argument("--cache-dtype", default=None,
                    choices=["bfloat16", "float32", "int8"],
                    help="KV storage dtype (default: the compute dtype)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop at this token id (default: run to max-new)")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["auto", "off", "emulate", "int8"],
                    help="decode-hook kernel backend: non-off enables the "
                         "fused decode-prologue kernel (default: unset, "
                         "unfused decode)")
    ap.add_argument("--profile", type=int, default=0, metavar="N",
                    help="capture a jax.profiler trace of the first N "
                         "scheduler ticks (trace directory printed at exit)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = _reduce(cfg)
    params = lm.init_params(jax.random.key(0), cfg)

    mode = args.mode
    if mode == "auto":
        mode = "paged" if paged_supported(cfg) else "contiguous"
    cache_dtype = args.cache_dtype or (
        "bfloat16" if cfg.compute_dtype == "bfloat16" else "float32")
    serve = ServeConfig(num_slots=args.slots, eos_id=args.eos_id,
                        max_len=args.max_len, mode=mode,
                        block_size=args.block_size,
                        prefill_chunk=args.prefill_chunk,
                        cache_dtype=cache_dtype,
                        kernel_backend=args.kernel_backend)
    print(f"[serve] {cfg.name} ({cfg.family}) slots={args.slots} "
          f"mode={mode} cache={cache_dtype} "
          f"kernel_backend={args.kernel_backend or 'unset'}", flush=True)

    if mode == "paged":
        # prime the kernel tune cache for this serve's decode shapes (paged
        # attention + fused prologue) so the first decode tick traces
        # against stable decisions instead of deriving them mid-trace
        from repro.kernels.ops import prime_tune_cache, serve_tune_shapes
        tuned = prime_tune_cache(serve_tune_shapes(
            cfg, num_blocks=serve.resolved_num_blocks,
            block_size=serve.block_size,
            max_blocks_per_seq=serve.max_blocks_per_seq))
        hits = sum(1 for d in tuned.values() if d is not None)
        print(f"[serve] kernel tune cache primed: {hits}/{len(tuned)} "
              f"shape(s) fit VMEM", flush=True)

    sched = BatchScheduler(serve, EngineHooks.for_model(params, cfg, serve))

    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = []
    for i in range(args.requests):
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=(args.prompt_len,)).astype(np.int32),
            max_new_tokens=args.max_new))
        sched.submit(reqs[-1])
    trace_dir = None
    if args.profile > 0:
        trace_dir = tempfile.mkdtemp(prefix="repro-trace-serve-")
        jax.profiler.start_trace(trace_dir)
        try:
            for _ in range(args.profile):
                if sched.step() == 0 and not sched.pending:
                    break
        finally:
            jax.profiler.stop_trace()
    sched.run_until_drained()
    finished = [r for r in reqs if r.done]
    dt = time.time() - t0
    tok = sum(len(r.generated) for r in finished)
    extra = ""
    if mode == "paged":
        extra = (f", {sched.stats['prefix_hits']} prefix hits, "
                 f"{sched.stats['cow_copies']} COW copies")
    print(f"[serve] {len(finished)}/{args.requests} requests, {tok} tokens "
          f"in {dt:.1f}s ({tok/dt:.1f} tok/s, {sched.steps_run} decode steps"
          f"{extra})", flush=True)
    for r in finished[:3]:
        print(f"  req {r.uid}: {r.generated[:8]}...", flush=True)
    if trace_dir:
        print(f"[serve] profiler trace ({args.profile} tick(s)): {trace_dir}",
              flush=True)
    return finished


if __name__ == "__main__":
    main()
