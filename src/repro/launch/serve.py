"""Serving driver: continuous-batching decode over the slot scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 8 --max-new 16

Demonstrates the production serving path: prefill per admitted request,
slot-based continuous batching, jitted decode step with donated cache
state, per-request latency accounting.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.launch.train import _reduce
from repro.models import lm
from repro.serving import (BatchScheduler, Request, decode_step,
                           init_decode_state, prefill)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = _reduce(cfg)
    params = lm.init_params(jax.random.key(0), cfg)
    print(f"[serve] {cfg.name} ({cfg.family}) slots={args.slots}", flush=True)

    cache_dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32

    def prefill_one(tokens):
        return prefill(params, cfg, {"tokens": jnp.asarray(tokens)},
                       args.max_len, cache_dtype)

    decode_fn = jax.jit(
        lambda state, toks: decode_step(params, cfg, state, toks),
        donate_argnums=(0,))

    def merge_fn(state, slot_state, i):
        def wr(dst, src):
            return dst.at[:, i].set(src[:, 0])
        return {"caches": jax.tree.map(wr, state["caches"],
                                       slot_state["caches"]),
                "pos": slot_state["pos"]}

    init_state = init_decode_state(cfg, args.slots, args.max_len, cache_dtype)
    sched = BatchScheduler(args.slots, prefill_one, decode_fn, merge_fn,
                           init_state)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        sched.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=(args.prompt_len,)).astype(np.int32),
            max_new_tokens=args.max_new))
    finished = sched.run_until_drained()
    dt = time.time() - t0
    tok = sum(len(r.generated) for r in finished)
    print(f"[serve] {len(finished)}/{args.requests} requests, {tok} tokens "
          f"in {dt:.1f}s ({tok/dt:.1f} tok/s, {sched.steps_run} decode steps)",
          flush=True)
    for r in finished[:3]:
        print(f"  req {r.uid}: {r.generated[:8]}...", flush=True)
    return finished


if __name__ == "__main__":
    main()
