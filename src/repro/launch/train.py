"""Production training driver: elastic mesh, checkpoint/restart, straggler-
tolerant data loading, fault-injection drills, TaxoNN engine.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 200 --reduced --ckpt-dir /tmp/run1 [--resume]

Elasticity: the mesh is built from whatever devices exist at START-UP
(``--data X --model Y`` or auto); checkpoints store logical arrays, so a
job checkpointed on one topology restarts on another (restore reshards via
the new mesh's shardings).  Fault tolerance: atomic verified async
checkpoints every ``--ckpt-every`` steps carrying the full resume payload
(data step, transport-cache decisions — see
``core.steps.capture_resume_extra``); on restart the step-indexed data
pipeline resumes exactly and a same-topology restart is BITWISE identical
to the uninterrupted run.  ``--fault-plan`` (or ``REPRO_FAULT_PLAN``)
injects deterministic faults — crash-at-step, checkpoint IO/fsync/rename
failures, straggler stalls, post-save bit flips — for reproducible
recovery drills (see ``repro.ft``); a restart past a corrupted LATEST
falls back to the newest valid checkpoint with a loud warning.
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import AsyncCheckpointer, restore_checkpoint, latest_step
from repro.configs import ARCH_NAMES, get_config
from repro.core import QuantPolicy, StepOptions, make_train_step
from repro.core.steps import (apply_resume_extra, capture_resume_extra,
                              default_bits, init_train_state)
from repro.data import SyntheticLMDataset, StragglerTolerantLoader
from repro.dist.api import activation_sharding_ctx, make_default_rules
from repro.dist.pipeline import get_schedule
from repro.dist.sharding import param_pspecs, to_named
from repro.ft import FaultPlan
from repro.launch.mesh import batch_axes, make_debug_mesh, pipe_axis_size
from repro.models import lm
from repro.optim import Hyper, OptimizerConfig, cosine_schedule


def reduced_for_cpu(cfg):
    from test_support_reduce import reduce_config  # pragma: no cover
    return reduce_config(cfg)


def _reduce(cfg):
    """Small same-family twin for CPU runs (--reduced)."""
    changes = dict(num_layers=min(cfg.num_layers, 4), d_model=128,
                   vocab_size=512, compute_dtype="float32")
    if cfg.num_heads:
        kv = cfg.num_kv_heads if cfg.num_kv_heads == cfg.num_heads else 2
        changes.update(num_heads=4, num_kv_heads=min(kv, 4), head_dim=32)
    if cfg.d_ff:
        changes.update(d_ff=256)
    if cfg.family == "moe":
        changes.update(num_experts=4, experts_per_token=2, moe_d_ff=64)
    if cfg.use_mla:
        changes.update(kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
                       v_head_dim=32)
    if cfg.family in ("ssm", "hybrid"):
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.family == "hybrid":
        changes.update(num_layers=4, attn_every=2)
    if cfg.family == "encdec":
        changes.update(num_encoder_layers=2, encoder_seq=32)
    if cfg.family == "vlm":
        changes.update(num_patches=8)
    return dataclasses.replace(cfg, **changes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="momentum",
                    choices=["sgd", "momentum", "momentum8", "adam"])
    ap.add_argument("--quantize", action="store_true",
                    help="enable the TaxoNN per-layer (I,F) schedule")
    ap.add_argument("--bit-anneal", default=None, metavar="SPEC",
                    help="progressive bitwidth-annealing schedule, e.g. "
                         "'0:off,100:16,400:12': comma-separated STEP:VALUE "
                         "milestones where VALUE is an F-bit floor applied "
                         "on top of the per-layer schedule ('off' = "
                         "quantization disabled until the next milestone); "
                         "bits stay traced data so the ramp costs zero "
                         "recompiles and resume continues it bitwise (see "
                         "repro.search.anneal)")
    ap.add_argument("--bit-search", type=int, default=0, metavar="GROUPS",
                    help="run a per-layer-group (I,F) sensitivity sweep on "
                         "this arch before training (GROUPS contiguous "
                         "layer groups; 0 = off) and train with the "
                         "selected plan; the BitPlan + its serving int8 "
                         "export are saved next to the checkpoints (or "
                         "under artifacts/)")
    ap.add_argument("--bit-target", type=float, default=0.1,
                    help="--bit-search loss-delta target vs the f32 "
                         "baseline probe")
    ap.add_argument("--bit-probe-steps", type=int, default=24,
                    help="--bit-search training steps per probe")
    ap.add_argument("--engine", default="taxonn",
                    choices=["taxonn", "autodiff"])
    ap.add_argument("--kernel-backend", default="auto",
                    choices=["auto", "off", "emulate", "int8"],
                    help="dense-unit datapath (auto = off on CPU, int8 on "
                         "TPU)")
    ap.add_argument("--compress-dw", action="store_true",
                    help="route per-layer dW through the int8 block-scaled "
                         "wire format inside the backward scan")
    ap.add_argument("--stochastic", action="store_true",
                    help="stochastic rounding for the quantized G chain "
                         "(and updates with --quantize-updates); noise is "
                         "keyed per (layer, global batch row), so the scan "
                         "and pipeline paths make identical draws")
    ap.add_argument("--quantize-updates", action="store_true",
                    help="strict paper mode: quantize q(alpha*dW) in the "
                         "layer's gradient (I,F) format before the update")
    ap.add_argument("--overlap", default="off", choices=["off", "on"],
                    help="comm-optimized backward scan: ring-transport dW "
                         "leaves software-pipeline --overlap-depth scan "
                         "steps deep so the in-flight hops overlap the "
                         "next layers' G-step compute, blocking-transport "
                         "leaves land same-iteration updates (fused psum, "
                         "or the sharded sgd update on scatter leaves); "
                         "each bucket's transport comes from the per-size "
                         "autotuner unless --transport forces one")
    ap.add_argument("--overlap-depth", type=int, default=2,
                    help="in-flight dW reduces per layer stream with "
                         "--overlap on (clamped to the layer count; only "
                         "ring-transport leaves defer)")
    ap.add_argument("--transport", default="auto",
                    choices=["auto", "ring", "psum", "scatter"],
                    help="dW all-reduce transport: auto consults the "
                         "measured per-bucket cache (primed at start-up "
                         "for this model's dW sizes; REPRO_TRANSPORT "
                         "overrides everything); ring/psum/scatter force "
                         "one (scatter = native reduce-scatter whose 1/g "
                         "chunk gets the sharded optimizer update)")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced twin of the arch")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault-injection spec for recovery "
                         "drills (falls back to REPRO_FAULT_PLAN), e.g. "
                         "'crash@12;io@8x2;stall@5:0.5;flip@10;seed=7' or "
                         "'crash@rand:8-20;seed=3' — see repro.ft.FaultPlan")
    ap.add_argument("--data", type=int, default=0,
                    help="data-axis size (0 = all devices)")
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--pipe", type=int, default=0,
                    help="pipe-axis size (0 = no pipe axis in the mesh)")
    ap.add_argument("--pipeline-schedule", default="none",
                    choices=["none", "gpipe", "1f1b", "interleaved"],
                    help="pipe-axis pipeline schedule; with stages > 1 the "
                         "engine's blocks stack EXECUTES stage-sharded "
                         "through repro.dist.pipeline for EVERY model "
                         "family (hybrid/encdec shared operands replicate "
                         "or slice per stage, moe aux statistics reduce "
                         "post-drain; layers and batch must divide into "
                         "stages and microbatches)")
    ap.add_argument("--virtual-stages", type=int, default=2,
                    help="virtual stages per pipe device (interleaved "
                         "schedule only)")
    ap.add_argument("--microbatches", type=int, default=8,
                    help="microbatches per step for the pipeline schedule")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--deadline-s", type=float, default=5.0)
    ap.add_argument("--profile", type=int, default=0, metavar="N",
                    help="capture a jax.profiler trace of the first N steps "
                         "(trace directory printed at exit)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = _reduce(cfg)

    n_dev = len(jax.devices())
    n_data = args.data or max(1, n_dev // (args.model * max(args.pipe, 1)))
    mesh = make_debug_mesh(n_data, args.model, pipe=args.pipe)
    rules = make_default_rules(batch_axes(mesh))
    print(f"[train] {cfg.name} ({cfg.family}) on mesh {dict(mesh.shape)} "
          f"params~{cfg.param_count()/1e6:.1f}M", flush=True)

    pipe_sched = None
    if args.pipeline_schedule != "none":
        pipe_sched = get_schedule(
            args.pipeline_schedule,
            num_virtual=(args.virtual_stages
                         if args.pipeline_schedule == "interleaved" else None))
        n_stages = pipe_axis_size(mesh) * pipe_sched.num_virtual
        mode = ("stage-sharded execution" if n_stages > 1
                else "cost model only (1 stage)")
        print(f"[train] pipeline {pipe_sched.name} ({mode}): "
              f"{pipe_sched.summary(n_stages, args.microbatches)}", flush=True)

    ocfg = OptimizerConfig(kind=args.optimizer, grad_clip=1.0)
    policy = (QuantPolicy(grad_scale=64.0) if args.quantize
              else QuantPolicy.off())
    policy = dataclasses.replace(policy, kernel_backend=args.kernel_backend,
                                 compress_dw=args.compress_dw,
                                 overlap=args.overlap,
                                 overlap_depth=args.overlap_depth,
                                 dw_transport=args.transport,
                                 stochastic=args.stochastic,
                                 quantize_updates=args.quantize_updates,
                                 bit_anneal=args.bit_anneal)
    bits = default_bits(cfg, enabled=args.quantize)

    if args.bit_search:
        from repro.search import export as bit_export
        from repro.search.sensitivity import SweepConfig, run_sweep_lm
        if not args.quantize:
            print("[train] note: --bit-search without --quantize — the "
                  "sweep runs quantized probes but training stays fp32",
                  flush=True)
        sweep = SweepConfig(num_groups=args.bit_search,
                            target=args.bit_target,
                            probe_steps=args.bit_probe_steps,
                            batch=args.global_batch, lr=args.lr)
        t_sweep = time.time()
        bit_plan = run_sweep_lm(cfg, ocfg, sweep, seq_len=args.seq_len,
                                log=lambda s: print(f"[bit-search] {s}",
                                                    flush=True))
        print(f"[train] bit-search ({bit_plan.probes} probes, "
              f"{time.time() - t_sweep:.1f}s): {bit_plan.describe()}",
              flush=True)
        out_dir = args.ckpt_dir or "artifacts"
        bit_plan.save(f"{out_dir}/bit_plan.json")
        serve_plan = bit_export.to_serve_plan(bit_plan)
        bit_export.save_serve_plan(serve_plan, f"{out_dir}/bit_plan_serve.json")
        parity = bit_export.verify_train_serve_parity(bit_plan)
        print(f"[train] train<->serve int8 parity: "
              f"{'OK' if parity['ok'] else 'VIOLATED'} {parity}", flush=True)
        bits["blocks"] = bit_plan.to_bit_schedule(enabled=args.quantize)
    sched = cosine_schedule(args.lr, warmup=max(10, args.steps // 20),
                            total=args.steps)

    params = lm.init_params(jax.random.key(0), cfg)
    opt_state = init_train_state(params, ocfg)
    start_step = 0

    plan = FaultPlan.from_env(args.fault_plan)
    if plan is not None:
        print(f"[train] fault plan: {plan.describe()}", flush=True)

    # restore BEFORE transport priming: the checkpoint's resume payload
    # carries the killed run's measured transport decisions, and installing
    # them first keeps the resumed collective schedule (and its numerics)
    # identical instead of re-measuring on a possibly noisier machine
    p_sh = to_named(param_pspecs(cfg, params, mesh), mesh)
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), ckpt_step, extra = restore_checkpoint(
            args.ckpt_dir, (params, opt_state),
            shardings=(p_sh, None) if args.model > 1 else None)
        start_step = apply_resume_extra(extra, cfg, ckpt_step,
                                        anneal=args.bit_anneal)
        print(f"[train] resumed from step {start_step}", flush=True)

    if args.overlap == "on" and args.transport == "auto" and n_data > 1:
        # measure ring-vs-psum EAGERLY for this model's per-layer dW leaf
        # sizes so the traced step consults real decisions, not the
        # platform model (inside jit no measurement can run); restored
        # checkpoint decisions above are cache hits and are NOT re-measured
        from repro.dist.async_collectives import prime_transport_cache
        leaf_bytes = sorted({
            int(np.asarray(jnp.asarray(x).shape).prod() // cfg.num_layers) * 4
            for x in jax.tree.leaves(params["blocks"])})
        decided = prime_transport_cache(leaf_bytes, n_data,
                                        compressed=args.compress_dw)
        picks = ", ".join(f"{b // 1024}kb->{t}" for b, t in decided.items())
        print(f"[train] transport autotuner (g={n_data}): {picks}",
              flush=True)

    # prime the kernel tune cache for this run's matmul shapes, same
    # rationale as the transport cache: the traced step consults stable
    # decisions, and entries restored from the checkpoint above are cache
    # hits (kept with their restored: provenance, never re-derived)
    from repro.kernels.ops import prime_tune_cache, train_tune_shapes
    tuned = prime_tune_cache(train_tune_shapes(cfg, args.global_batch,
                                               args.seq_len))
    hits = sum(1 for d in tuned.values() if d is not None)
    print(f"[train] kernel tune cache primed: {hits}/{len(tuned)} shape(s) "
          f"fit VMEM", flush=True)

    ckpt = (AsyncCheckpointer(args.ckpt_dir,
                              fault=plan.ckpt_fault if plan else None)
            if args.ckpt_dir else None)

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq_len, args.global_batch)
    fetch = plan.wrap_fetch(ds.batch_at) if plan else ds.batch_at
    loader = StragglerTolerantLoader(fetch, deadline_s=args.deadline_s,
                                     start_step=start_step)

    step_fn = jax.jit(
        make_train_step(
            cfg, policy, ocfg,
            StepOptions(
                engine=args.engine,
                pipeline_schedule=pipe_sched,
                pipeline_stages=(pipe_axis_size(mesh) * pipe_sched.num_virtual
                                 if pipe_sched else None),
                num_microbatches=args.microbatches if pipe_sched else None,
                bit_anneal=args.bit_anneal)),
        donate_argnums=(0, 1))

    def ckpt_extra(next_step):
        return capture_resume_extra(cfg, next_step, loader=loader,
                                    user_extra={"loss": losses[-1]},
                                    anneal=args.bit_anneal)

    def maybe_flip(next_step):
        # bit-flip drills corrupt a LANDED checkpoint: join the async write
        # first, then flip (the manifest keeps the original crc, so a later
        # restore must detect the mismatch and fall back)
        if plan is not None and next_step in plan.flip_steps():
            ckpt.wait()
            plan.corrupt_checkpoint(args.ckpt_dir, next_step)

    losses = []
    trace_dir, tracing = None, False
    if args.profile > 0:
        trace_dir = tempfile.mkdtemp(prefix="repro-trace-train-")
    t0 = time.time()
    try:
        with jax.set_mesh(mesh), activation_sharding_ctx(rules):
            for step in range(start_step, args.steps):
                if trace_dir and step == start_step:
                    jax.profiler.start_trace(trace_dir)
                    tracing = True
                if plan is not None:
                    plan.check_crash(step)
                batch = {k: jnp.asarray(v)
                         for k, v in loader.get(step).items()}
                # the synthetic LM loader only makes tokens/labels; encdec
                # and vlm need their modality-side inputs too (deterministic
                # per step, so checkpoint-resume replays the same stream)
                bsz = batch["tokens"].shape[0]
                if cfg.family == "encdec" and "frames" not in batch:
                    batch["frames"] = jax.random.normal(
                        jax.random.fold_in(jax.random.key(2), step),
                        (bsz, cfg.encoder_seq, cfg.d_model), jnp.float32)
                if cfg.family == "vlm" and "patch_embeds" not in batch:
                    batch["patch_embeds"] = jax.random.normal(
                        jax.random.fold_in(jax.random.key(3), step),
                        (bsz, cfg.num_patches, cfg.d_model), jnp.float32)
                hyper = Hyper(lr=jnp.float32(sched(step)),
                              step=jnp.int32(step))
                rng = (jax.random.fold_in(jax.random.key(1), step)
                       if args.stochastic else None)
                params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                     hyper, bits, rng)
                losses.append(float(metrics["loss"]))
                if tracing and step - start_step + 1 >= args.profile:
                    jax.profiler.stop_trace()
                    tracing = False
                if step % args.log_every == 0 or step == args.steps - 1:
                    dt = time.time() - t0
                    print(f"step {step:5d} loss {losses[-1]:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {sched(step):.2e} {dt:.1f}s "
                          f"data_skips={loader.skips}", flush=True)
                if ckpt and step and step % args.ckpt_every == 0:
                    ckpt.save(step + 1, (params, opt_state),
                              extra=ckpt_extra(step + 1))
                    maybe_flip(step + 1)
        if ckpt:
            ckpt.save(args.steps, (params, opt_state),
                      extra=ckpt_extra(args.steps))
            ckpt.wait()
            maybe_flip(args.steps)
    finally:
        # close() flushes the final in-flight write and surfaces any
        # background error even when the loop raises; only an injected
        # crash (os._exit) skips it — by design
        if tracing:
            jax.profiler.stop_trace()
        if ckpt:
            ckpt.close()
        loader.close()
    if trace_dir:
        print(f"[train] profiler trace ({args.profile} step(s)): {trace_dir}",
              flush=True)
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f} smoothed)",
          flush=True)
    return losses


if __name__ == "__main__":
    main()
