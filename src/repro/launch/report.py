"""Render EXPERIMENTS.md tables from dry-run records.

    PYTHONPATH=src python -m repro.launch.report [--out results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import pathlib


def fmt_bytes(b):
    if b >= 2 ** 30:
        return f"{b/2**30:.2f}GiB"
    if b >= 2 ** 20:
        return f"{b/2**20:.1f}MiB"
    return f"{b/2**10:.0f}KiB"


def load(out_dir):
    recs = [json.loads(pathlib.Path(f).read_text())
            for f in sorted(glob.glob(f"{out_dir}/*.json"))]
    return recs


CELL_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _frac(v) -> str:
    return "—" if v is None else f"{v:.2f}"


def render_dryrun_table(recs) -> str:
    """One row per dry-run record.  ``overlap`` is
    hlo_analysis.overlap_fraction of the scanned artifact (compute
    scheduled inside collective latency windows) and ``pipe bubble`` the
    modeled schedule bubble when the cell was built with stage-sharded
    pipeline execution — both surfaced here, not only in train-step
    metrics."""
    lines = [
        "| arch | cell | mesh | status | compile | args/dev | temp/dev | overlap | pipe bubble | collectives (scanned artifact) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    def key(r):
        return (r["arch"], CELL_ORDER.index(r["cell"]), r["mesh"])
    for r in sorted(recs, key=key):
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['cell']} | {r['mesh']} | "
                         f"skip (by design) | — | — | — | — | — | — |")
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['cell']} | {r['mesh']} | "
                         f"ERROR | — | — | — | — | — | {r['error'][:60]} |")
            continue
        ma = r["scanned_artifact"]["memory_analysis"]
        coll = r["scanned_artifact"]["collectives"]["counts"]
        cstr = " ".join(f"{k}:{v}" for k, v in sorted(coll.items())) or "none"
        ov = r.get("overlap_fraction")
        if ov is None:
            ov = r.get("scanned_artifact", {}).get("overlap", {}).get(
                "overlap_fraction")
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.0f}s | "
            f"{fmt_bytes(ma.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(ma.get('temp_size_in_bytes', 0))} | "
            f"{_frac(ov)} | {_frac(r.get('pipe_bubble'))} | {cstr} |")
    return "\n".join(lines)


def render_roofline_table(recs) -> str:
    lines = [
        "| arch | cell | compute | memory | collective | dominant | bound | MODEL_FLOPs/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    def key(r):
        return (r["arch"], CELL_ORDER.index(r["cell"]))
    for r in sorted([r for r in recs if r["mesh"] == "pod_16x16"], key=key):
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['cell']} | — | — | — | — | — | — | "
                         f"{'skip: sub-quadratic attn required' if r['status']=='skipped' else 'ERROR'} |")
            continue
        t = r["cost"]["terms"]

        def ms(x):
            return f"{x*1e3:.1f}ms" if x >= 1e-4 else f"{x*1e6:.0f}us"
        note = ""
        uf = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['cell']} | {ms(t['compute_s'])} | "
            f"{ms(t['memory_s'])} | {ms(t['collective_s'])} | "
            f"**{t['dominant']}** | {ms(t['step_time_lower_bound_s'])} | "
            f"{uf:.2f} | {note} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--which", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    recs = load(args.out)
    if args.which in ("dryrun", "both"):
        print("## Dry-run records\n")
        print(render_dryrun_table(recs))
        print()
    if args.which in ("roofline", "both"):
        print("## Roofline (single-pod 16x16, per device, per step)\n")
        print(render_roofline_table(recs))


if __name__ == "__main__":
    main()
