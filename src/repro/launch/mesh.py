"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: "pod" and "data" are batch/data-parallel (gradient reduction spans
    both); "model" is the tensor/expert-parallel axis.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, pod: int = 0):
    """Small mesh for in-process tests (device count permitting)."""
    if pod:
        return jax.make_mesh((pod, n_data, n_model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
