"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.

Axes: "pod" and "data" are batch/data-parallel (gradient reduction spans
both); "model" is the tensor/expert-parallel axis; "pipe" is the pipeline
axis the ``repro.dist.pipeline`` schedules place their stages on (one
stage — or ``num_virtual`` round-robin virtual stages — per pipe device).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def _check_pipe(pipe: int, chips: int, per_pipe_model: int) -> int:
    if pipe < 1:
        raise ValueError(f"pipe axis size must be >= 1, got {pipe}")
    if chips % (pipe * per_pipe_model):
        raise ValueError(
            f"pipe={pipe} does not divide the pod: need pipe * {per_pipe_model}"
            f" to divide {chips} chips")
    return chips // (pipe * per_pipe_model)


def make_production_mesh(*, multi_pod: bool = False, pipe: int = 1):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    With ``pipe > 1`` the data axis cedes devices to a leading "pipe"
    dimension (stages replicate nothing, so the product of axis sizes must
    still equal the pod): ("pipe", "data", "model") single-pod or
    ("pod", "pipe", "data", "model") two-pod.
    """
    if pipe == 1:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    else:
        n_data = _check_pipe(pipe, 256, 16)
        shape = (2, pipe, n_data, 16) if multi_pod else (pipe, n_data, 16)
        axes = (("pod", "pipe", "data", "model") if multi_pod
                else ("pipe", "data", "model"))
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, pod: int = 0,
                    pipe: int = 0):
    """Small mesh for in-process tests (device count permitting)."""
    shape, axes = (n_data, n_model), ("data", "model")
    if pipe:
        shape, axes = (pipe,) + shape, ("pipe",) + axes
    if pod:
        shape, axes = (pod,) + shape, ("pod",) + axes
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def pipe_axis_size(mesh) -> int:
    """Number of pipeline-stage devices (1 when the mesh has no pipe axis)."""
    if mesh is None:
        return 1
    return dict(mesh.shape).get("pipe", 1)
