"""whisper-tiny [audio]: enc-dec transformer backbone; conv frontend is a
STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]  4L enc + 4L dec, d_model=384 6H d_ff=1536
vocab=51865, 1500 encoder frames (30 s), GELU MLP, LayerNorm, sinusoidal
positions (no RoPE)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,
    num_encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    mlp_kind="gelu",
    norm_kind="layernorm",
    use_rope=False,
)
