"""Architecture registry + input specs for every (arch x shape) cell.

``get_config(name)`` returns the exact assigned ModelConfig;
``input_specs(cfg, cell)`` returns ShapeDtypeStruct stand-ins for every
input of the step that cell lowers (train_step / prefill / serve_step) —
weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import (
    ModelConfig, ShapeCell, SHAPE_CELLS, SHAPES_BY_NAME, cell_is_applicable,
)

ARCH_MODULES: Dict[str, str] = {
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "gemma-7b": "gemma_7b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "yi-34b": "yi_34b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "mixtral-8x7b": "mixtral_8x7b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-370m": "mamba2_370m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-2.7b": "zamba2_2_7b",
}

ARCH_NAMES = tuple(ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")
    return mod.CONFIG


def param_specs(cfg: ModelConfig):
    """Abstract parameter shapes (no allocation)."""
    from repro.models import lm
    return jax.eval_shape(
        lambda k: lm.init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, cell_name: str) -> dict:
    """Model-input stand-ins for the given shape cell.

    train  -> {tokens, labels, (frames|patch_embeds)}
    prefill-> {tokens, (frames|patch_embeds)}
    decode -> {tokens [B,1], state: full decode-cache pytree specs}
    """
    cell = SHAPES_BY_NAME[cell_name]
    if not cell_is_applicable(cfg, cell):
        raise ValueError(
            f"{cfg.name} x {cell_name}: long-context decode needs "
            "sub-quadratic attention (SSM/hybrid only) — skipped by design")
    b, t = cell.global_batch, cell.seq_len

    def text_extras(tlen):
        batch = {}
        if cfg.family == "encdec":
            batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["patch_embeds"] = _sds((b, cfg.num_patches, cfg.d_model),
                                         jnp.float32)
            tlen = tlen - cfg.num_patches  # total context = seq_len
        return batch, tlen

    if cell.kind == "train":
        extras, tl = text_extras(t)
        return {"tokens": _sds((b, tl), jnp.int32),
                "labels": _sds((b, tl), jnp.int32), **extras}

    if cell.kind == "prefill":
        extras, tl = text_extras(t)
        return {"tokens": _sds((b, tl), jnp.int32), **extras}

    if cell.kind == "decode":
        from repro.serving import init_decode_state
        state = jax.eval_shape(
            lambda: init_decode_state(cfg, b, t, jnp.bfloat16))
        return {"tokens": _sds((b, 1), jnp.int32), "state": state}

    raise ValueError(cell.kind)


__all__ = [
    "ARCH_MODULES", "ARCH_NAMES", "get_config", "param_specs", "input_specs",
    "ModelConfig", "ShapeCell", "SHAPE_CELLS", "SHAPES_BY_NAME",
    "cell_is_applicable",
]
