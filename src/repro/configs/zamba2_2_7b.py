"""zamba2-2.7b [hybrid]: Mamba2 backbone + one weight-tied (shared)
attention block applied every 6 layers. [arXiv:2411.15242; hf]
54L d_model=2560 32H (kv=32) d_ff=10240 ssm_state=64 vocab=32000.
54 layers / attn_every=6 -> 9 groups, each = shared attn block + 6 mamba."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
    mlp_kind="swiglu",
)
