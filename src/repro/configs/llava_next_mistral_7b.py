"""llava-next-mistral-7b [vlm]: mistral-7b backbone; anyres vision tower is
a STUB (input_specs provides precomputed patch embeddings, 576 per tile).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    num_patches=576,
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
)
