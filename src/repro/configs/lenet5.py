"""The paper's own evaluation network: LeNet-class 5-layer model used for
the MNIST / CIFAR10 / SVHN experiments (Fig. 5, Table I).

We reproduce it as a 5-layer MLP classifier driven by the same TaxoNN engine
primitives (forward_stack / backward_stack) — see benchmarks/convergence.py.
The per-layer (I,F) design points from Table I are in
``repro.quant.fixed_point.paper_schedule``.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LeNetConfig:
    name: str = "lenet5"
    input_dim: int = 784          # 28x28 (MNIST/SVHN); 1024*3 for CIFAR10
    hidden: int = 256
    num_layers: int = 5
    num_classes: int = 10


CONFIG = LeNetConfig()
