"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + 64 routed experts top-6
with 2 shared experts, expert d_ff=1408. [arXiv:2405.04434; hf]
27L d_model=2048 16H vocab=102400.

Per the assigned pool header we use 64 routed experts top-6 (the "160
routed" aside describes full V2, not Lite — see DESIGN.md §5).  All layers
are MoE (the real model's single dense first layer is not in the assigned
config)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=102_400,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mlp_kind="swiglu",
)
