"""h2o-danube3-4b [dense]: llama+mistral mix with SWA.
[arXiv:2401.16818; unverified]  24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000.  head_dim = 3840/32 = 120 (not 128-aligned; noted in the
roofline analysis).  Sliding window 4096 (danube family default)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    swa_window=4096,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
)
