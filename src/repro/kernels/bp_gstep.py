"""bp_gstep: the TaxoNN G-chain step as one fused kernel.

    G_i = q_g( (G_{i+1} @ W_{i+1}^T) * f'(Z_i) )          (paper Eq. 8)

One VMEM-resident pass fuses the backward matmul, the activation-derivative
multiply (the paper's derivation unit), and the low-bit re-quantization of
the outgoing G — the intermediate (G @ W^T) never round-trips HBM.  This is
the TDM insight transplanted: the scarce resource on TPU is HBM bandwidth,
so the four TaxoNN multiplier time-slots become one fused VMEM pipeline.

Shapes: G [T, Dout], W [Din, Dout] (forward orientation), Z [T, Din]
(pre-activation of layer i).  Output G_i [T, Din].
Grid (T/bm, Din/bn, Dout/bk); W^T is expressed through the BlockSpec index
map (no materialised transpose).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import act_deriv, kq


def _kernel(g_ref, w_ref, z_ref, o_ref, *, n_k: int, g_bits, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # G block [bm, bk] @ (W block [bn, bk])^T -> [bm, bn]
    acc = jax.lax.dot_general(
        g_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _finish():
        fprime = act_deriv(z_ref[...].astype(jnp.float32), act)
        y = o_ref[...] * fprime
        if g_bits is not None:
            y = kq(y, *g_bits)
        o_ref[...] = y


def bp_gstep(g: jax.Array, w: jax.Array, z: jax.Array, *,
             g_bits=(2, 12), act: str = "relu",
             bm: int = 128, bn: int = 128, bk: int = 128,
             interpret: bool = False) -> jax.Array:
    """g: [T, Dout]; w: [Din, Dout]; z: [T, Din]. Returns G_i [T, Din] f32."""
    t, dout = g.shape
    din, dout2 = w.shape
    assert dout == dout2 and z.shape == (t, din)
    bm, bn, bk = min(bm, t), min(bn, din), min(bk, dout)
    assert t % bm == 0 and din % bn == 0 and dout % bk == 0
    n_k = dout // bk

    grid = (t // bm, din // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, g_bits=g_bits, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # G
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),   # W (transposed via dot dims)
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),   # Z
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, din), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(g, w, z)
