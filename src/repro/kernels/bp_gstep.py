"""bp_gstep: the TaxoNN G-chain step as one fused kernel.

    G_i = q_g( (G_{i+1} @ W_{i+1}^T) * f'(Z_i) )          (paper Eq. 8)

One VMEM-resident pass fuses the backward matmul, the activation-derivative
multiply (the paper's derivation unit), and the low-bit re-quantization of
the outgoing G — the intermediate (G @ W^T) never round-trips HBM.  This is
the TDM insight transplanted: the scarce resource on TPU is HBM bandwidth,
so the four TaxoNN multiplier time-slots become one fused VMEM pipeline.

Datapaths (see fxp_matmul.py): ``emulate`` computes the MAC at f32;
``int8`` takes G and W as int8 payloads, runs the MAC as int8 x int8 ->
int32 on the MXU with an exact int32 VMEM accumulator, and applies the
combined scale s_g * s_w once before the f' multiply.

Shapes: G [T, Dout], W [Din, Dout] (forward orientation), Z [T, Din]
(pre-activation of layer i; ``z=None`` with act="identity" skips the
derivative input entirely).  Output G_i [T, Din].
Grid (T/bm, Din/bn, Dout/bk); W^T is expressed through the BlockSpec index
map (no materialised transpose).

``double_buffer=True`` streams the G and W blocks HBM -> 2-slot VMEM via
explicit prefetch DMAs (grid step k waits the copy started at k-1 and
prefetches k+1 — see fxp_matmul's module docstring); Z keeps its implicit
blocked fetch (read once at the final k step).  Numerics identical.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import act_deriv, db_step, int8_dot, maybe_kq

# dot dims for G block [bm, bk] @ (W block [bn, bk])^T -> [bm, bn]
_GW_DIMS = (((1,), (1,)), ((), ()))


def _kernel(g_ref, w_ref, z_ref, o_ref, *, n_k: int, g_bits, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jax.lax.dot_general(g_ref[...], w_ref[...], _GW_DIMS,
                              preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _finish():
        y = o_ref[...]
        if z_ref is not None:
            y = y * act_deriv(z_ref[...].astype(jnp.float32), act)
        o_ref[...] = maybe_kq(y, g_bits)


def _kernel_int8(g_ref, w_ref, z_ref, meta_ref, o_ref, acc_ref, *,
                 n_k: int, g_bits, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += int8_dot(g_ref[...], w_ref[...], _GW_DIMS)

    @pl.when(k == n_k - 1)
    def _finish():
        y = acc_ref[...].astype(jnp.float32) * meta_ref[0]
        if z_ref is not None:
            y = y * act_deriv(z_ref[...].astype(jnp.float32), act)
        o_ref[...] = maybe_kq(y, g_bits)


def _db_dmas(g_hbm, w_hbm, gbuf, wbuf, sem, bm, bn, bk):
    i, j = pl.program_id(0), pl.program_id(1)

    def dma_g(slot, kk):
        return pltpu.make_async_copy(
            g_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * bk, bk)],
            gbuf.at[slot], sem.at[0, slot])

    def dma_w(slot, kk):
        return pltpu.make_async_copy(
            w_hbm.at[pl.ds(j * bn, bn), pl.ds(kk * bk, bk)],
            wbuf.at[slot], sem.at[1, slot])

    return (dma_g, dma_w)


def _kernel_db(g_hbm, w_hbm, z_ref, o_ref, gbuf, wbuf, sem, *, n_k: int,
               bm: int, bn: int, bk: int, g_bits, act: str):
    k = pl.program_id(2)
    dmas = _db_dmas(g_hbm, w_hbm, gbuf, wbuf, sem, bm, bn, bk)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    slot = db_step(k, n_k, dmas)
    o_ref[...] += jax.lax.dot_general(gbuf[slot], wbuf[slot], _GW_DIMS,
                                      preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        y = o_ref[...]
        if z_ref is not None:
            y = y * act_deriv(z_ref[...].astype(jnp.float32), act)
        o_ref[...] = maybe_kq(y, g_bits)


def _kernel_db_int8(g_hbm, w_hbm, z_ref, meta_ref, o_ref, gbuf, wbuf,
                    acc_ref, sem, *, n_k: int, bm: int, bn: int, bk: int,
                    g_bits, act: str):
    k = pl.program_id(2)
    dmas = _db_dmas(g_hbm, w_hbm, gbuf, wbuf, sem, bm, bn, bk)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    slot = db_step(k, n_k, dmas)
    acc_ref[...] += int8_dot(gbuf[slot], wbuf[slot], _GW_DIMS)

    @pl.when(k == n_k - 1)
    def _finish():
        y = acc_ref[...].astype(jnp.float32) * meta_ref[0]
        if z_ref is not None:
            y = y * act_deriv(z_ref[...].astype(jnp.float32), act)
        o_ref[...] = maybe_kq(y, g_bits)


def bp_gstep(g: jax.Array, w: jax.Array, z: Optional[jax.Array], *,
             g_bits=(2, 12), act: str = "relu",
             bm: int = 128, bn: int = 128, bk: int = 128,
             interpret: bool = False,
             datapath: str = "emulate",
             scale: Optional[jax.Array] = None,
             double_buffer: bool = False) -> jax.Array:
    """g: [T, Dout]; w: [Din, Dout]; z: [T, Din] or None. Returns [T, Din] f32.

    int8 datapath: g/w are int8 payloads, ``scale`` = s_g * s_w.
    double_buffer: explicit 2-slot DMA prefetch for the G/W blocks.
    """
    t, dout = g.shape
    din, dout2 = w.shape
    assert dout == dout2
    if z is None:
        assert act == "identity", act
    else:
        assert z.shape == (t, din)
    bm, bn, bk = min(bm, t), min(bn, din), min(bk, dout)
    assert t % bm == 0 and din % bn == 0 and dout % bk == 0
    n_k = dout // bk

    grid = (t // bm, din // bn, n_k)
    g_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))       # G
    w_spec = pl.BlockSpec((bn, bk), lambda i, j, k: (j, k))       # W (T via dot dims)
    z_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))       # Z
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    any_spec = pl.BlockSpec(memory_space=pl.ANY)
    params = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
    out_shape = jax.ShapeDtypeStruct((t, din), jnp.float32)

    if double_buffer:
        # slots keep the operands' own dtype so the MAC sees exactly what
        # the implicit-pipeline kernel sees (bf16 in -> bf16 MXU products)
        db_scratch = [pltpu.VMEM((2, bm, bk), g.dtype),
                      pltpu.VMEM((2, bn, bk), w.dtype)]
        db_sem = [pltpu.SemaphoreType.DMA((2, 2))]

    if datapath == "int8":
        assert g.dtype == jnp.int8 and w.dtype == jnp.int8, (g.dtype, w.dtype)
        assert scale is not None, "int8 datapath needs the combined scale"
        meta = jnp.asarray(scale, jnp.float32).reshape(1)
        if double_buffer:
            in_specs = [any_spec, any_spec]
            args = [g, w]
            if z is not None:
                in_specs.append(z_spec)
                args.append(z)
            in_specs.append(any_spec)
            args.append(meta)

            def kern_db8(*refs):
                if z is not None:
                    g_r, w_r, z_r, m_r, o_r, gb, wb, a_r, sm = refs
                else:
                    g_r, w_r, m_r, o_r, gb, wb, a_r, sm = refs
                    z_r = None
                _kernel_db_int8(g_r, w_r, z_r, m_r, o_r, gb, wb, a_r, sm,
                                n_k=n_k, bm=bm, bn=bn, bk=bk, g_bits=g_bits,
                                act=act)

            return pl.pallas_call(
                kern_db8, grid=grid, in_specs=in_specs, out_specs=o_spec,
                out_shape=out_shape,
                scratch_shapes=db_scratch + [pltpu.VMEM((bm, bn), jnp.int32)]
                + db_sem,
                compiler_params=params, interpret=interpret,
            )(*args)
        in_specs = [g_spec, w_spec]
        args = [g, w]
        if z is not None:
            in_specs.append(z_spec)
            args.append(z)
        in_specs.append(any_spec)
        args.append(meta)

        def kern(*refs):
            if z is not None:
                g_r, w_r, z_r, m_r, o_r, a_r = refs
            else:
                g_r, w_r, m_r, o_r, a_r = refs
                z_r = None
            _kernel_int8(g_r, w_r, z_r, m_r, o_r, a_r, n_k=n_k,
                         g_bits=g_bits, act=act)

        return pl.pallas_call(
            kern, grid=grid, in_specs=in_specs, out_specs=o_spec,
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
            compiler_params=params, interpret=interpret,
        )(*args)

    assert datapath == "emulate", datapath
    if double_buffer:
        in_specs = [any_spec, any_spec]
        args = [g, w]
        if z is not None:
            in_specs.append(z_spec)
            args.append(z)

        def kern_db(*refs):
            if z is not None:
                g_r, w_r, z_r, o_r, gb, wb, sm = refs
            else:
                g_r, w_r, o_r, gb, wb, sm = refs
                z_r = None
            _kernel_db(g_r, w_r, z_r, o_r, gb, wb, sm, n_k=n_k, bm=bm,
                       bn=bn, bk=bk, g_bits=g_bits, act=act)

        return pl.pallas_call(
            kern_db, grid=grid, in_specs=in_specs, out_specs=o_spec,
            out_shape=out_shape, scratch_shapes=db_scratch + db_sem,
            compiler_params=params, interpret=interpret,
        )(*args)
    in_specs = [g_spec, w_spec]
    args = [g, w]
    if z is not None:
        in_specs.append(z_spec)
        args.append(z)

    def kern(*refs):
        if z is not None:
            g_r, w_r, z_r, o_r = refs
        else:
            g_r, w_r, o_r = refs
            z_r = None
        _kernel(g_r, w_r, z_r, o_r, n_k=n_k, g_bits=g_bits, act=act)

    return pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=o_spec,
        out_shape=out_shape, compiler_params=params, interpret=interpret,
    )(*args)
