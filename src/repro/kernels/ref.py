"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import act_deriv, act_fn, kq


def fxp_matmul_ref(x, w, *, xa_bits=(4, 10), w_bits=(2, 12),
                   out_bits=(4, 10), act="identity"):
    xq = kq(x, *xa_bits)
    wq = kq(w, *w_bits)
    y = act_fn(jnp.dot(xq, wq, preferred_element_type=jnp.float32), act)
    if out_bits is not None:
        y = kq(y, *out_bits)
    return y


def bp_gstep_ref(g, w, z, *, g_bits=(2, 12), act="relu"):
    gi = jnp.dot(g.astype(jnp.float32), w.astype(jnp.float32).T,
                 preferred_element_type=jnp.float32)
    gi = gi * act_deriv(z.astype(jnp.float32), act)
    if g_bits is not None:
        gi = kq(gi, *g_bits)
    return gi


def sgd_dw_update_ref(x, g, w, lr, *, w_bits=None):
    dw = jnp.dot(x.astype(jnp.float32).T, g.astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    w_new = w.astype(jnp.float32) - lr * dw
    if w_bits is not None:
        w_new = kq(w_new, *w_bits)
    return w_new
