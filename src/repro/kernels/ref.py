"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel has two oracles: the f32 (I,F)-emulation reference (``*_ref``)
and the int8-datapath reference (``*_int8_ref``) that quantizes the
operands onto their (I,F)-derived int8 grids, runs the MAC at int32, and
rescales — bit-identical (up to f32 rescale rounding) to what the int8
kernels compute, so property tests can assert tight tolerances.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import act_deriv, act_fn, int8_dot, maybe_kq
from repro.quant.int8 import quantize_int8_auto


def fxp_matmul_ref(x, w, *, xa_bits=(4, 10), w_bits=(2, 12),
                   out_bits=(4, 10), act="identity"):
    xq = maybe_kq(x.astype(jnp.float32), xa_bits)
    wq = maybe_kq(w.astype(jnp.float32), w_bits)
    y = act_fn(jnp.dot(xq, wq, preferred_element_type=jnp.float32), act)
    return maybe_kq(y, out_bits)


def bp_gstep_ref(g, w, z, *, g_bits=(2, 12), act="relu"):
    gi = jnp.dot(g.astype(jnp.float32), w.astype(jnp.float32).T,
                 preferred_element_type=jnp.float32)
    if z is not None:
        gi = gi * act_deriv(z.astype(jnp.float32), act)
    return maybe_kq(gi, g_bits)


def sgd_dw_update_ref(x, g, w, lr, *, w_bits=None):
    dw = jnp.dot(x.astype(jnp.float32).T, g.astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    if w is None:
        return maybe_kq(dw, w_bits)
    w_new = w.astype(jnp.float32) - lr * dw
    return maybe_kq(w_new, w_bits)


def bp_fused_unit_ref(g, w, x, z, lr, *, g_bits=(2, 12), w_bits=(2, 12),
                      w_out_bits=None, act="relu"):
    """The TDM frame as three sequential jnp ops (Eq. 8 + Eq. 9 + Eq. 1)."""
    gf = g.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    wq = maybe_kq(wf, w_bits)
    go = jnp.dot(gf, wq.T, preferred_element_type=jnp.float32)
    go = maybe_kq(go * act_deriv(z.astype(jnp.float32), act), g_bits)
    dw = jnp.dot(x.astype(jnp.float32).T, gf,
                 preferred_element_type=jnp.float32)
    w_new = maybe_kq(wf - lr * dw, w_out_bits)
    return go, w_new


# ---------------------------------------------------------------------------
# int8-datapath oracles (operands on the (I,F)-derived int8 grid, int32 MACs)
# ---------------------------------------------------------------------------

def fxp_matmul_int8_ref(x, w, *, xa_bits=(4, 10), w_bits=(2, 12),
                        out_bits=(4, 10), act="identity"):
    qx, sx = quantize_int8_auto(x, xa_bits)
    qw, sw = quantize_int8_auto(w, w_bits)
    y = int8_dot(qx, qw).astype(jnp.float32) * (sx * sw)
    return maybe_kq(act_fn(y, act), out_bits)


def bp_gstep_int8_ref(g, w, z, *, g_in_bits=(2, 12), w_bits=(2, 12),
                      g_bits=(2, 12), act="relu"):
    qg, sg = quantize_int8_auto(g, g_in_bits)
    qw, sw = quantize_int8_auto(w, w_bits)
    gi = int8_dot(qg, qw.T).astype(jnp.float32) * (sg * sw)
    if z is not None:
        gi = gi * act_deriv(z.astype(jnp.float32), act)
    return maybe_kq(gi, g_bits)


def sgd_dw_update_int8_ref(x, g, w, lr, *, xa_bits=(4, 10),
                           g_in_bits=(2, 12), w_bits=None):
    qx, sx = quantize_int8_auto(x, xa_bits)
    qg, sg = quantize_int8_auto(g, g_in_bits)
    dw = int8_dot(qx.T, qg).astype(jnp.float32) * (sx * sg)
    if w is None:
        return maybe_kq(dw, w_bits)
    return maybe_kq(w.astype(jnp.float32) - lr * dw, w_bits)


def bp_fused_unit_int8_ref(g, w, x, z, lr, *, g_in_bits=(2, 12),
                           xa_bits=(4, 10), g_bits=(2, 12), w_bits=(2, 12),
                           w_out_bits=None, act="relu"):
    qg, sg = quantize_int8_auto(g, g_in_bits)
    qx, sx = quantize_int8_auto(x, xa_bits)
    qw, sw = quantize_int8_auto(w, w_bits)
    go = int8_dot(qg, qw.T).astype(jnp.float32) * (sg * sw)
    go = maybe_kq(go * act_deriv(z.astype(jnp.float32), act), g_bits)
    dw = int8_dot(qx.T, qg).astype(jnp.float32) * (sx * sg)
    w_new = maybe_kq(w.astype(jnp.float32) - lr * dw, w_out_bits)
    return go, w_new
