"""fxp_matmul: fixed-point (I,F) quantized matmul + fused activation.

The TaxoNN PE datapath's forward op: y = f(q_a(X) @ q_w(W)).  Two datapaths
share one tiling:

  * ``datapath="emulate"`` — the MAC emulated at f32 with in-kernel (I,F)
    round-to-nearest (kq) and a f32 accumulator.  This is the CPU/interpret
    reference path and the pre-int8 behaviour.
  * ``datapath="int8"``    — X and W arrive as int8 payloads (the
    block-scaled storage format of ``repro.quant.int8``); the MAC runs as
    ``dot(int8, int8) -> int32`` on the MXU with an exact int32 VMEM
    accumulator (the paper's wide accumulator registers), and the combined
    scale ``s_x * s_w`` is applied once at the final k step — followed by
    the fused activation and optional output re-quantization.

Tiling: grid (M/bm, N/bn, K/bk); X block [bm,bk] and W block [bk,bn] live
in VMEM; the [bm,bn] accumulator lives across the k steps (revisiting
semantics: k is the innermost, "arbitrary" dimension).  Block defaults are
MXU-aligned (multiples of 128 on the contracted dims).

``double_buffer=True`` switches the operand fetch to an EXPLICIT
double-buffered DMA datapath (NeuroTrainer's memory/compute overlap at the
kernel level): X and W stay in HBM (``memory_space=ANY``) and each grid
step k prefetches block k+1 into the second slot of a 2-deep VMEM scratch
while the MXU consumes slot k%2 — the DMA started at step k is waited at
step k+1, one grid step of overlap per operand block.  Numerics are
IDENTICAL to the implicit-pipeline path (same blocks, same MAC order);
``kernels.ops.tune_blocks(double_buffer=True)`` budgets the 2x VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import act_fn, db_step, int8_dot, maybe_kq


def _kernel(x_ref, w_ref, o_ref, *, n_k: int, xa_bits, w_bits, out_bits,
            act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = maybe_kq(x_ref[...].astype(jnp.float32), xa_bits)
    wq = maybe_kq(w_ref[...].astype(jnp.float32), w_bits)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _finish():
        y = act_fn(o_ref[...], act)
        y = maybe_kq(y, out_bits)
        o_ref[...] = y


def _kernel_int8(x_ref, w_ref, meta_ref, o_ref, acc_ref, *, n_k: int,
                 out_bits, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += int8_dot(x_ref[...], w_ref[...])

    @pl.when(k == n_k - 1)
    def _finish():
        # one rescale out of the wide accumulator, then the fused activation
        y = act_fn(acc_ref[...].astype(jnp.float32) * meta_ref[0], act)
        y = maybe_kq(y, out_bits)
        o_ref[...] = y


def _db_dmas(x_hbm, w_hbm, xbuf, wbuf, sem, bm, bn, bk):
    """Block-(i,·,·)/(·,j,·) DMA constructors for the double-buffered path."""
    i, j = pl.program_id(0), pl.program_id(1)

    def dma_x(slot, kk):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * bk, bk)],
            xbuf.at[slot], sem.at[0, slot])

    def dma_w(slot, kk):
        return pltpu.make_async_copy(
            w_hbm.at[pl.ds(kk * bk, bk), pl.ds(j * bn, bn)],
            wbuf.at[slot], sem.at[1, slot])

    return (dma_x, dma_w)


def _kernel_db(x_hbm, w_hbm, o_ref, xbuf, wbuf, sem, *, n_k: int,
               bm: int, bn: int, bk: int, xa_bits, w_bits, out_bits,
               act: str):
    k = pl.program_id(2)
    dmas = _db_dmas(x_hbm, w_hbm, xbuf, wbuf, sem, bm, bn, bk)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    slot = db_step(k, n_k, dmas)           # next block rides the DMA while
    xq = maybe_kq(xbuf[slot].astype(jnp.float32), xa_bits)  # MXU eats this one
    wq = maybe_kq(wbuf[slot].astype(jnp.float32), w_bits)
    o_ref[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        y = act_fn(o_ref[...], act)
        o_ref[...] = maybe_kq(y, out_bits)


def _kernel_db_int8(x_hbm, w_hbm, meta_ref, o_ref, xbuf, wbuf, acc_ref, sem,
                    *, n_k: int, bm: int, bn: int, bk: int, out_bits,
                    act: str):
    k = pl.program_id(2)
    dmas = _db_dmas(x_hbm, w_hbm, xbuf, wbuf, sem, bm, bn, bk)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    slot = db_step(k, n_k, dmas)
    acc_ref[...] += int8_dot(xbuf[slot], wbuf[slot])

    @pl.when(k == n_k - 1)
    def _finish():
        y = act_fn(acc_ref[...].astype(jnp.float32) * meta_ref[0], act)
        o_ref[...] = maybe_kq(y, out_bits)


def fxp_matmul(x: jax.Array, w: jax.Array, *,
               xa_bits=(4, 10), w_bits=(2, 12), out_bits=(4, 10),
               act: str = "identity",
               bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = False,
               datapath: str = "emulate",
               scale: Optional[jax.Array] = None,
               double_buffer: bool = False) -> jax.Array:
    """x: [M, K]; w: [K, N]. Returns f32 [M, N].

    emulate: x/w f32 or bf16, quantized in-kernel by (xa_bits, w_bits)
             (``None`` bits = passthrough).
    int8:    x/w int8 payloads; ``scale`` is the combined dequant scale
             s_x * s_w (traced f32 scalar or Python float).
    double_buffer: operands stream HBM -> 2-slot VMEM scratch via explicit
             prefetch DMAs (see module docstring); numerics identical.
    """
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, \
        (m, n, kdim, bm, bn, bk)
    n_k = kdim // bk

    grid = (m // bm, n // bn, n_k)
    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    any_spec = pl.BlockSpec(memory_space=pl.ANY)
    params = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
    out_shape = jax.ShapeDtypeStruct((m, n), jnp.float32)

    if datapath == "int8":
        assert x.dtype == jnp.int8 and w.dtype == jnp.int8, (x.dtype, w.dtype)
        assert scale is not None, "int8 datapath needs the combined scale"
        meta = jnp.asarray(scale, jnp.float32).reshape(1)
        if double_buffer:
            return pl.pallas_call(
                functools.partial(_kernel_db_int8, n_k=n_k, bm=bm, bn=bn,
                                  bk=bk, out_bits=out_bits, act=act),
                grid=grid,
                in_specs=[any_spec, any_spec, any_spec],
                out_specs=o_spec,
                out_shape=out_shape,
                scratch_shapes=[pltpu.VMEM((2, bm, bk), jnp.int8),
                                pltpu.VMEM((2, bk, bn), jnp.int8),
                                pltpu.VMEM((bm, bn), jnp.int32),
                                pltpu.SemaphoreType.DMA((2, 2))],
                compiler_params=params,
                interpret=interpret,
            )(x, w, meta)
        return pl.pallas_call(
            functools.partial(_kernel_int8, n_k=n_k, out_bits=out_bits,
                              act=act),
            grid=grid,
            in_specs=[x_spec, w_spec, any_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
            compiler_params=params,
            interpret=interpret,
        )(x, w, meta)

    assert datapath == "emulate", datapath
    if double_buffer:
        return pl.pallas_call(
            functools.partial(_kernel_db, n_k=n_k, bm=bm, bn=bn, bk=bk,
                              xa_bits=xa_bits, w_bits=w_bits,
                              out_bits=out_bits, act=act),
            grid=grid,
            in_specs=[any_spec, any_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((2, bm, bk), x.dtype),
                            pltpu.VMEM((2, bk, bn), w.dtype),
                            pltpu.SemaphoreType.DMA((2, 2))],
            compiler_params=params,
            interpret=interpret,
        )(x, w)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, xa_bits=xa_bits, w_bits=w_bits,
                          out_bits=out_bits, act=act),
        grid=grid,
        in_specs=[x_spec, w_spec],
        out_specs=o_spec,
        out_shape=out_shape,
        compiler_params=params,
        interpret=interpret,
    )(x, w)
