"""fxp_matmul: fixed-point (I,F) quantized matmul + fused activation.

The TaxoNN PE datapath's forward op: y = f(q_a(X) @ q_w(W)), with the
MAC emulated at fixed point and a f32 (wide-register) accumulator.

Tiling: grid (M/bm, N/bn, K/bk); X block [bm,bk] and W block [bk,bn] live
in VMEM; the [bm,bn] output block accumulates in f32 across the k steps
(revisiting semantics: k is the innermost, "arbitrary" dimension).  Block
defaults are MXU-aligned (multiples of 128 on the contracted dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import act_fn, kq


def _kernel(x_ref, w_ref, o_ref, *, n_k: int, xa_bits, w_bits, out_bits,
            act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = kq(x_ref[...], *xa_bits)
    wq = kq(w_ref[...], *w_bits)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _finish():
        y = act_fn(o_ref[...], act)
        if out_bits is not None:
            y = kq(y, *out_bits)
        o_ref[...] = y


def fxp_matmul(x: jax.Array, w: jax.Array, *,
               xa_bits=(4, 10), w_bits=(2, 12), out_bits=(4, 10),
               act: str = "identity",
               bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = False) -> jax.Array:
    """x: [M, K] f32/bf16; w: [K, N]. Returns f32 [M, N]."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, \
        (m, n, kdim, bm, bn, bk)
    n_k = kdim // bk

    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, xa_bits=xa_bits, w_bits=w_bits,
                          out_bits=out_bits, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
