"""Fused decode-prologue kernel: RMSNorm + QKV projection + RoPE in one
``pallas_call`` — one HBM round-trip for the whole decode prologue.

The unfused decode prologue (``models.layers.apply_norm`` then
``_project_qkv``) writes the normed residual back to HBM, re-reads it for
each of the three projections, and re-reads q/k again for the rotation —
exactly the per-layer data-flow staging TaxoNN's time-multiplexed frame
collapses.  Here ONE grid step takes the whole slot batch: decode rows
are [B, D] with small B (the slot count), so batching them into a single
VMEM-resident matmul frame uses the MXU where B row-at-a-time gemvs
would not — the body norms all residual rows, runs the three projections
against the resident QKV weights, adds biases, and rotates q/k in place;
v is never rope'd, matching ``_project_qkv``.

The math is op-for-op the unfused path's (rmsnorm formula, dt-cast
weights, rope half-rotation), shared between the kernel body and the
jitted ``_ref`` fallback at the same batched shapes — same ops at the
same shapes is what makes kernel and ref BITWISE identical in interpret
mode (a [1, D] row-at-a-time dot would round differently from the
batched dot), and both bitwise identical to ``apply_norm`` +
``_project_qkv`` under jit (tested in tests/test_decode_prologue).

The int8 datapath variant rides ``quant/int8.py``'s grid: weights carry
per-tensor absmax scales (quantized once outside the call), the normed
activation row is quantized per-row, the MACs run int8 x int8 -> int32
(``common.int8_dot``), and one rescale lands the dt output before bias +
rope.  Its contract is bitwise vs ``_ref_int8`` (not vs the f32 path).

``decode_prologue`` picks kernel vs ref with ``ops.tune_prologue``: the
kernel when the weight-resident VMEM budget admits the model's head
geometry, the jnp fallback otherwise — semantics identical either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ops as kops
from repro.kernels.common import int8_dot
from repro.quant.int8 import quantize_int8, quantize_int8_absmax


# ---------------------------------------------------------------------------
# Shared row math — the bitwise contract between kernel body and ref
# ---------------------------------------------------------------------------

def _rms_rows(x2, nscale, eps: float):
    """Row-wise rmsnorm, op-for-op ``models.layers.rmsnorm``.  x2: [R, D];
    nscale: [1, D] f32 (the norm's scale param)."""
    dtype = x2.dtype
    xf = x2.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * nscale).astype(dtype)


def _rope_rows(x3, positions, theta: float):
    """Half-rotation rope, op-for-op ``models.layers.apply_rope`` with the
    T=1 axis squeezed.  x3: [R, H, hd]; positions: [R]."""
    hd = x3.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [R, hd/2]
    cos = jnp.cos(angles)[:, None, :]                          # [R, 1, hd/2]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = jnp.split(x3.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x3.dtype)


def _prologue_rows(x2, nscale, wq2, wk2, wv2, biases, positions, *,
                   use_rope: bool, theta: float, eps: float,
                   h: int, hkv: int, hd: int):
    """norm -> 3 projections -> bias -> rope over R token rows.  Weights
    arrive 2D ([D, H*hd]) and are dt-cast exactly like ``_project_qkv``."""
    dt = x2.dtype
    xn = _rms_rows(x2, nscale, eps)
    q = jnp.dot(xn, wq2.astype(dt)).reshape(-1, h, hd)
    k = jnp.dot(xn, wk2.astype(dt)).reshape(-1, hkv, hd)
    v = jnp.dot(xn, wv2.astype(dt)).reshape(-1, hkv, hd)
    if biases is not None:
        bq, bk, bv = biases
        q = q + bq.astype(dt)
        k = k + bk.astype(dt)
        v = v + bv.astype(dt)
    if use_rope:
        q = _rope_rows(q, positions, theta)
        k = _rope_rows(k, positions, theta)
    return q, k, v


def _prologue_rows_int8(x2, nscale, qwq, qwk, qwv, wscales, biases,
                        positions, *, use_rope: bool, theta: float,
                        eps: float, h: int, hkv: int, hd: int):
    """Int8 datapath: per-row absmax quant of the normed activation, int32
    MACs against the per-tensor-scaled int8 weights, one rescale."""
    dt = x2.dtype
    xn = _rms_rows(x2, nscale, eps)
    xf = xn.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)                       # [R]
    sx = jnp.where(amax > 0, amax / 127.0, jnp.float32(1.0))
    qx = quantize_int8(xf, sx[:, None])

    def proj(qw, sw, heads):
        acc = int8_dot(qx, qw).astype(jnp.float32)
        return (acc * (sx[:, None] * sw)).astype(dt).reshape(-1, heads, hd)

    q = proj(qwq, wscales[0, 0], h)
    k = proj(qwk, wscales[0, 1], hkv)
    v = proj(qwv, wscales[0, 2], hkv)
    if biases is not None:
        bq, bk, bv = biases
        q = q + bq.astype(dt)
        k = k + bk.astype(dt)
        v = v + bv.astype(dt)
    if use_rope:
        q = _rope_rows(q, positions, theta)
        k = _rope_rows(k, positions, theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Kernel bodies (one grid step per decode slot)
# ---------------------------------------------------------------------------

def _kernel(pos_ref, x_ref, ns_ref, wq_ref, wk_ref, wv_ref, *rest,
            int8: bool, qkv_bias: bool, use_rope: bool, theta: float,
            eps: float, h: int, hkv: int, hd: int):
    # ONE grid step for the whole slot batch: decode rows are [B, D] with
    # small B (the slot count), so batching them into a single MXU matmul
    # frame beats B separate gemvs — and running the ref's exact batched op
    # sequence is what keeps kernel and ref BITWISE identical (a [1, D]
    # row-at-a-time dot rounds differently from the batched dot).
    i = 0
    biases = None
    if qkv_bias:
        biases = (rest[0][...], rest[1][...], rest[2][...])
        i = 3
    if int8:
        wscales = rest[i][...]
        i += 1
    oq_ref, ok_ref, ov_ref = rest[i], rest[i + 1], rest[i + 2]
    pos = pos_ref[...]                                         # [B]
    if int8:
        q, k, v = _prologue_rows_int8(
            x_ref[...], ns_ref[...], wq_ref[...], wk_ref[...], wv_ref[...],
            wscales, biases, pos, use_rope=use_rope, theta=theta, eps=eps,
            h=h, hkv=hkv, hd=hd)
    else:
        q, k, v = _prologue_rows(
            x_ref[...], ns_ref[...], wq_ref[...], wk_ref[...], wv_ref[...],
            biases, pos, use_rope=use_rope, theta=theta, eps=eps,
            h=h, hkv=hkv, hd=hd)
    oq_ref[...] = q
    ok_ref[...] = k
    ov_ref[...] = v


# ---------------------------------------------------------------------------
# jnp fallbacks — the same row math batched over all slots
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "use_rope", "theta", "eps", "h", "hkv", "hd"))
def _ref(x2, nscale, wq2, wk2, wv2, biases, positions, *, use_rope: bool,
         theta: float, eps: float, h: int, hkv: int, hd: int):
    return _prologue_rows(x2, nscale, wq2, wk2, wv2, biases, positions,
                          use_rope=use_rope, theta=theta, eps=eps,
                          h=h, hkv=hkv, hd=hd)


@functools.partial(jax.jit, static_argnames=(
    "use_rope", "theta", "eps", "h", "hkv", "hd"))
def _ref_int8(x2, nscale, qwq, qwk, qwv, wscales, biases, positions, *,
              use_rope: bool, theta: float, eps: float, h: int, hkv: int,
              hd: int):
    return _prologue_rows_int8(x2, nscale, qwq, qwk, qwv, wscales, biases,
                               positions, use_rope=use_rope, theta=theta,
                               eps=eps, h=h, hkv=hkv, hd=hd)


def _call_kernel(x2, nscale, wq2, wk2, wv2, wscales, biases, positions, *,
                 int8: bool, use_rope: bool, theta: float, eps: float,
                 h: int, hkv: int, hd: int):
    b, d = x2.shape
    dt = x2.dtype

    def full(x):
        nd = x.ndim
        return pl.BlockSpec(x.shape, lambda i, *_, _nd=nd: (0,) * _nd)

    in_specs = [full(x2), full(nscale), full(wq2), full(wk2), full(wv2)]
    args = [x2, nscale, wq2, wk2, wv2]
    if biases is not None:
        in_specs += [full(bb) for bb in biases]
        args += list(biases)
    if int8:
        in_specs += [full(wscales)]
        args += [wscales]
    body = functools.partial(_kernel, int8=int8, qkv_bias=biases is not None,
                             use_rope=use_rope, theta=theta, eps=eps,
                             h=h, hkv=hkv, hd=hd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=in_specs,
        out_specs=[full(jax.ShapeDtypeStruct((b, h, hd), dt)),
                   full(jax.ShapeDtypeStruct((b, hkv, hd), dt)),
                   full(jax.ShapeDtypeStruct((b, hkv, hd), dt))],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, h, hd), dt),
                   jax.ShapeDtypeStruct((b, hkv, hd), dt),
                   jax.ShapeDtypeStruct((b, hkv, hd), dt)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=kops._on_cpu(),
    )(positions.astype(jnp.int32), *args)


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------

def prologue_supported(cfg) -> bool:
    """Head geometries the fused prologue covers: rmsnorm front (layernorm
    archs keep the unfused path), standard GQA/MHA heads (no MLA latent
    projections), lane-aligned head dim."""
    return (cfg.norm_kind == "rmsnorm" and not cfg.use_mla
            and cfg.num_heads > 0 and cfg.head_dim % 8 == 0
            and cfg.d_model % 8 == 0)


def prologue_active(cfg, x) -> bool:
    """Whether the decode step should ride the fused prologue: supported
    geometry, the kernel datapath enabled (``KernelBackend`` != off), and a
    single-token row (prefill chunks keep the batched unfused path)."""
    return (prologue_supported(cfg) and kops.current_backend() != "off"
            and x.shape[1] == 1)


def decode_prologue(norm_params, attn_params, x, cfg, positions):
    """Fused RMSNorm + QKV + rope for one decode token per slot.

    x: [B, 1, D] residual stream; positions: [B] int32 (each slot's
    absolute token position); norm/attn params are the block's unfused
    parameter dicts (weights are reshaped, never copied out of the tree).
    Returns (q [B,1,H,hd], k [B,1,Hkv,hd], v [B,1,Hkv,hd]) — exactly what
    ``apply_norm`` + ``_project_qkv`` produce, in one HBM round-trip.
    """
    b, t, d = x.shape
    assert t == 1, x.shape
    wq, wk, wv = attn_params["wq"], attn_params["wk"], attn_params["wv"]
    _, h, hd = wq.shape
    hkv = wk.shape[1]
    wq2 = wq.reshape(d, h * hd)
    wk2 = wk.reshape(d, hkv * hd)
    wv2 = wv.reshape(d, hkv * hd)
    nscale = norm_params["scale"].reshape(1, d)
    biases = None
    if cfg.qkv_bias:
        biases = (attn_params["bq"], attn_params["bk"], attn_params["bv"])
    pos = positions.astype(jnp.int32)
    x2 = x[:, 0, :]
    stat = dict(use_rope=bool(cfg.use_rope), theta=float(cfg.rope_theta),
                eps=float(cfg.norm_eps), h=h, hkv=hkv, hd=hd)

    int8 = kops.current_backend() == "int8"
    itemsize = 1 if int8 else x.dtype.itemsize
    fits = kops.tune_prologue(d, h, hkv, hd, itemsize=itemsize)
    if int8:
        qwq, swq = quantize_int8_absmax(wq2)
        qwk, swk = quantize_int8_absmax(wk2)
        qwv, swv = quantize_int8_absmax(wv2)
        wscales = jnp.stack([swq, swk, swv]).reshape(1, 3)
        if fits is None:
            q, k, v = _ref_int8(x2, nscale, qwq, qwk, qwv, wscales, biases,
                                pos, **stat)
        else:
            q, k, v = _call_kernel(x2, nscale, qwq, qwk, qwv, wscales,
                                   biases, pos, int8=True, **stat)
    else:
        if fits is None:
            q, k, v = _ref(x2, nscale, wq2, wk2, wv2, biases, pos, **stat)
        else:
            q, k, v = _call_kernel(x2, nscale, wq2, wk2, wv2, None, biases,
                                   pos, int8=False, **stat)
    return q[:, None], k[:, None], v[:, None]
