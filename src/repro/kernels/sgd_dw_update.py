"""sgd_dw_update: fused dW computation + in-place SGD step.

    W <- q_w( W - lr * (X^T @ G) )           (paper Eq. 9 + Eq. 1, step 4)

The gradient tensor dW = X^T G is accumulated in VMEM across the token
blocks and folded into the weight update in the same kernel — dW never
exists in HBM.  This is the TaxoNN fused-update property (gradient
lifetime = one PE pass) expressed at the memory-hierarchy level that
matters on TPU.

Datapaths: ``emulate`` accumulates the outer product at f32; ``int8`` takes
X and G as int8 payloads (the activation and gradient storage formats), runs
the MAC as int8 x int8 -> int32 with an exact int32 VMEM accumulator, and
rescales by s_x * s_g once at the final step, where the master-weight f32
update happens.

``w=None`` turns the kernel into its dW-only form (returns X^T @ G, no
update) — the shape emitted to ``custom_vjp`` backward rules and the int8
tile source for the compressed dW all-reduce.

Shapes: X [T, Din], G [T, Dout], W [Din, Dout] -> [Din, Dout].
Grid (Din/bm, Dout/bn, T/bk): the contraction is over tokens.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import int8_dot, maybe_kq

# (X block [bk, bm])^T @ G block [bk, bn] -> [bm, bn]
_XG_DIMS = (((0,), (0,)), ((), ()))


def _kernel(x_ref, g_ref, w_ref, lr_ref, o_ref, *, n_k: int, w_bits):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jax.lax.dot_general(x_ref[...], g_ref[...], _XG_DIMS,
                              preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _finish():
        if w_ref is None:
            o_ref[...] = maybe_kq(o_ref[...], w_bits)
        else:
            w_new = w_ref[...].astype(jnp.float32) - lr_ref[0] * o_ref[...]
            o_ref[...] = maybe_kq(w_new, w_bits)


def _kernel_int8(x_ref, g_ref, w_ref, meta_ref, o_ref, acc_ref, *,
                 n_k: int, w_bits):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += int8_dot(x_ref[...], g_ref[...], _XG_DIMS)

    @pl.when(k == n_k - 1)
    def _finish():
        dw = acc_ref[...].astype(jnp.float32) * meta_ref[0]  # s_x * s_g
        if w_ref is None:
            o_ref[...] = maybe_kq(dw, w_bits)
        else:
            w_new = w_ref[...].astype(jnp.float32) - meta_ref[1] * dw
            o_ref[...] = maybe_kq(w_new, w_bits)


def sgd_dw_update(x: jax.Array, g: jax.Array, w: Optional[jax.Array], lr,
                  *, w_bits=None,
                  bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = False,
                  datapath: str = "emulate",
                  scale: Optional[jax.Array] = None) -> jax.Array:
    """x: [T, Din]; g: [T, Dout]; w: [Din, Dout] or None; lr scalar.

    Returns W - lr * x^T g (optionally re-quantized to (I,F)), or the raw
    dW = x^T g when ``w is None``.  int8 datapath: x/g are int8 payloads,
    ``scale`` = s_x * s_g.
    """
    t, din = x.shape
    t2, dout = g.shape
    assert t == t2
    if w is not None:
        assert w.shape == (din, dout)
    bm, bn, bk = min(bm, din), min(bn, dout), min(bk, t)
    assert din % bm == 0 and dout % bn == 0 and t % bk == 0
    n_k = t // bk

    grid = (din // bm, dout // bn, n_k)
    x_spec = pl.BlockSpec((bk, bm), lambda i, j, k: (k, i))   # X
    g_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))   # G
    w_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))   # W
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    any_spec = pl.BlockSpec(memory_space=pl.ANY)
    params = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
    out_shape = jax.ShapeDtypeStruct((din, dout), jnp.float32)

    if datapath == "int8":
        assert x.dtype == jnp.int8 and g.dtype == jnp.int8, (x.dtype, g.dtype)
        assert scale is not None, "int8 datapath needs the combined scale"
        meta = jnp.stack([jnp.asarray(scale, jnp.float32),
                          jnp.asarray(lr, jnp.float32)])
        in_specs = [x_spec, g_spec]
        args = [x, g]
        if w is not None:
            in_specs.append(w_spec)
            args.append(w)
        in_specs.append(any_spec)
        args.append(meta)

        def kern(*refs):
            if w is not None:
                x_r, g_r, w_r, m_r, o_r, a_r = refs
            else:
                x_r, g_r, m_r, o_r, a_r = refs
                w_r = None
            _kernel_int8(x_r, g_r, w_r, m_r, o_r, a_r, n_k=n_k, w_bits=w_bits)

        return pl.pallas_call(
            kern, grid=grid, in_specs=in_specs, out_specs=o_spec,
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
            compiler_params=params, interpret=interpret,
        )(*args)

    assert datapath == "emulate", datapath
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    in_specs = [x_spec, g_spec]
    args = [x, g]
    if w is not None:
        in_specs.append(w_spec)
        args.append(w)
    in_specs.append(any_spec)
    args.append(lr_arr)

    def kern(*refs):
        if w is not None:
            x_r, g_r, w_r, lr_r, o_r = refs
        else:
            x_r, g_r, lr_r, o_r = refs
            w_r = None
        _kernel(x_r, g_r, w_r, lr_r, o_r, n_k=n_k, w_bits=w_bits)

    return pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=o_spec,
        out_shape=out_shape, compiler_params=params, interpret=interpret,
    )(*args)
